//! Cross-module property tests: invariants that must hold across the
//! composition of subsystems (cache + TBE, classifier + calibration,
//! simulator determinism, JSON fuzz, quantizer round-trip monotonicity).
//! These run without artifacts (pure Rust state machines).

use std::sync::Arc;

use thinkv::baselines::PolicyKind;
use thinkv::compress::tbe::{Tbe, TbeConfig};
use thinkv::compress::tbq::{PrecisionAssignment, Tbq};
use thinkv::kvcache::{
    BlockPool, CacheConfig, CtCache, Fp32Backend, Fp32Cache, KvBackend, PrefixIndex,
    QuantBackend, SnapshotPayload, Thought,
};
use thinkv::metrics::Breakdown;
use thinkv::model::ModelConfig;
use thinkv::quant::{dequant_groups, quant_groups, Precision, GROUP_SIZE};
use thinkv::runtime::{DecodeOut, PrefillOut};
use thinkv::sim::harness::{EvictKind, Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, Trace};
use thinkv::thought::{calibrate, Classifier, ClassifierConfig};
use thinkv::util::json;
use thinkv::util::prop;
use thinkv::util::rng::Rng;

fn small_cfg(capacity: usize) -> CacheConfig {
    CacheConfig { layers: 2, capacity, block_size: 8, hkv: 1, dh: 16, buf_slots: 16 }
}

/// Drive a CtCache + TBE through a random thought stream; at every step the
/// cache invariants, the budget (after enforcement), and the min-retention
/// floor must hold.
#[test]
fn ct_cache_with_tbe_full_lifecycle_invariants() {
    prop::check(20, |g| {
        let budget = *g.pick(&[48usize, 96, 160]);
        let cfg = small_cfg(512);
        let mut cache = CtCache::new(cfg.clone());
        let mut tbe = Tbe::new(TbeConfig::new(budget));
        let tbq = Tbq::new(PrecisionAssignment::r4e4t2());
        let mut seg = cache.open_segment(Thought::Reasoning, 0);
        let mut seg_thought = Thought::Reasoning;
        let steps = g.usize(80, 400);
        let mut rng = Rng::new(g.usize(0, 1 << 30) as u64);
        for pos in 0..steps {
            // segment refresh every 32 tokens with a random label
            if pos % 32 == 0 && pos > 0 {
                let closing = seg_thought;
                seg_thought = *g.pick(&Thought::ALL);
                if closing == Thought::Transition {
                    tbe.on_transition_end(&mut cache, seg);
                }
                seg = cache.open_segment(seg_thought, pos);
            }
            let n = cfg.layers * cfg.kv_dim();
            let mut k = vec![0f32; n];
            let mut v = vec![0f32; n];
            rng.fill_normal_f32(&mut k, 0.0, 1.0);
            rng.fill_normal_f32(&mut v, 0.0, 1.0);
            let full = cache.push_token(&k, &v, pos, seg, seg_thought);
            if full {
                let psi = |t: Thought| tbq.psi(t);
                if cache.flush_buffer(&psi).is_err() {
                    tbe.ensure_budget(&mut cache);
                    cache
                        .flush_buffer(&psi)
                        .map_err(|e| format!("flush after TBE still failed: {e}"))?;
                }
            }
            tbe.tick();
            if cache.live_tokens() + cache.buf_fill() > budget {
                tbe.ensure_budget(&mut cache);
            }
            cache.check_invariants()?;
            // segments older than the active one keep >= min retention
            // *if* they ever had that many tokens
            for s in &cache.segments[..cache.segments.len().saturating_sub(1)] {
                let live = cache.tables[0].segment_slots(s.id).len();
                let span = s.end_pos.saturating_sub(s.start_pos);
                if span >= 4 && s.evict_level > 0 && live < 4 && live != 0 {
                    return Err(format!(
                        "segment {} annealed below min retention: {live}",
                        s.id
                    ));
                }
            }
        }
        // budget must be enforceable at the end
        tbe.ensure_budget(&mut cache);
        let floor = cache.segments.len() * 4 + cache.cfg.buf_slots;
        if cache.live_tokens() > budget.max(floor) {
            return Err(format!(
                "budget {budget} not enforced: live {}",
                cache.live_tokens()
            ));
        }
        Ok(())
    });
}

/// Quantize→dequantize error must be monotone in precision for every input.
#[test]
fn quant_roundtrip_error_monotone_in_precision() {
    prop::check(100, |g| {
        let d = *g.pick(&[16usize, 32, 64, 128]);
        let scale = g.f32(0.01, 30.0);
        let x = g.vec_normal_f32(d, 0.0, scale);
        let mut err = Vec::new();
        for p in [Precision::Fp8, Precision::Nvfp4, Precision::Ternary] {
            let mut codes = vec![0u8; d];
            let mut scales = vec![0f32; d / GROUP_SIZE];
            let mut deq = vec![0f32; d];
            quant_groups(&x, p, &mut codes, &mut scales);
            dequant_groups(&codes, &scales, p, &mut deq);
            err.push(
                x.iter().zip(&deq).map(|(a, b)| (a - b).abs()).sum::<f32>() / d as f32,
            );
        }
        if err[0] <= err[1] + 1e-6 && err[1] <= err[2] + 1e-6 {
            Ok(())
        } else {
            Err(format!("non-monotone errors {err:?}"))
        }
    });
}

/// The classifier must label pure-regime windows correctly for any
/// thresholds produced by calibration on tri-modal data.
#[test]
fn calibration_then_classification_roundtrip() {
    prop::check(10, |g| {
        let mut rng = Rng::new(g.usize(0, 1 << 30) as u64);
        // build tri-modal calibration series
        let series: Vec<Vec<Vec<f64>>> = (0..4)
            .map(|_| {
                (0..4)
                    .map(|_| {
                        (0..240)
                            .map(|i| {
                                let mean = [0.25, 0.55, 0.85][i % 3];
                                rng.normal_with(mean, 0.04).clamp(0.0, 1.0)
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let cal = calibrate(&series, 3, 4, 0.12);
        if cal.thresholds.len() != 2 {
            return Err(format!("thresholds {:?}", cal.thresholds));
        }
        let mut c = Classifier::new(ClassifierConfig {
            layers: cal.layers.clone(),
            thresholds: cal.thresholds.clone(),
            refresh: 8,
        });
        for (mean, want) in [
            (0.25, Thought::Execution),
            (0.55, Thought::Reasoning),
            (0.85, Thought::Transition),
        ] {
            for _ in 0..8 {
                let row: Vec<f64> = (0..8)
                    .map(|_| rng.normal_with(mean, 0.02).clamp(0.0, 1.0))
                    .collect();
                c.push_step(&row);
            }
            let got = c.refresh();
            if got != want {
                return Err(format!("mean {mean} classified {got:?}, want {want:?}"));
            }
        }
        Ok(())
    });
}

/// The whole simulation harness must be deterministic for a fixed seed.
#[test]
fn sim_harness_deterministic() {
    let ds = DatasetProfile::aime();
    for m in [
        Method::ThinKv(ThinKvSim::default()),
        Method::Evict(EvictKind::Rkv),
        Method::FullKv,
    ] {
        let run = || {
            let trace = Trace::generate(&ds, 99, 0.15);
            let r = run_method(
                &trace,
                &m,
                &SimConfig { budget: 256, seed: 9, stride: 4, rollouts: 16 },
            );
            (r.pass1, r.mem_frac, r.recall10, r.evict_events)
        };
        assert_eq!(run(), run(), "{m:?} not deterministic");
    }
}

/// Accuracy must be (weakly) monotone in budget for ThinKV on a fixed trace.
#[test]
fn thinkv_accuracy_monotone_in_budget() {
    let ds = DatasetProfile::aime();
    let trace = Trace::generate(&ds, 5, 0.5);
    let mut last = -1.0;
    for budget in [32usize, 128, 1024, 8192] {
        let r = run_method(
            &trace,
            &Method::ThinKv(ThinKvSim::default()),
            &SimConfig { budget, seed: 1, stride: 4, rollouts: 200 },
        );
        assert!(
            r.p_correct >= last - 0.05,
            "accuracy dropped with bigger budget: {last} -> {} at {budget}",
            r.p_correct
        );
        last = r.p_correct;
    }
}

/// JSON fuzz: any value tree we can build must round-trip exactly.
#[test]
fn json_fuzz_roundtrip() {
    fn build(g: &mut prop::Gen, depth: usize) -> json::Json {
        if depth == 0 || g.chance(0.4) {
            match g.usize(0, 3) {
                0 => json::Json::Null,
                1 => json::Json::Bool(g.bool()),
                2 => json::Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
                _ => json::Json::Str(
                    (0..g.usize(0, 12))
                        .map(|_| *g.pick(&['a', 'é', '"', '\\', '\n', 'z', '0']))
                        .collect(),
                ),
            }
        } else if g.bool() {
            json::Json::Arr((0..g.usize(0, 5)).map(|_| build(g, depth - 1)).collect())
        } else {
            let mut o = json::Json::obj();
            for i in 0..g.usize(0, 5) {
                o.set(&format!("k{i}"), build(g, depth - 1));
            }
            o
        }
    }
    prop::check(200, |g| {
        let v = build(g, 3);
        let s = v.to_string();
        let back = json::parse(&s).map_err(|e| format!("parse failed on {s}: {e}"))?;
        if back == v {
            // pretty form must round-trip too
            let back2 = json::parse(&v.to_string_pretty())
                .map_err(|e| format!("pretty parse failed: {e}"))?;
            if back2 == v {
                return Ok(());
            }
        }
        Err(format!("roundtrip mismatch for {s}"))
    });
}

/// Trace generation: statistics must respect the dataset profile for any
/// seed (lengths, mixes, segment contiguity).
#[test]
fn trace_profile_statistics_hold() {
    prop::check(20, |g| {
        let ds = match g.usize(0, 3) {
            0 => DatasetProfile::aime(),
            1 => DatasetProfile::livecodebench(),
            2 => DatasetProfile::math500(),
            _ => DatasetProfile::gsm8k(),
        };
        let t = Trace::generate(&ds, g.usize(0, 1 << 20) as u64, 0.25);
        if t.token_thought.len() != t.total_len() {
            return Err("thought labels length".into());
        }
        for w in t.segments.windows(2) {
            if w[0].end() != w[1].start {
                return Err("segments not contiguous".into());
            }
        }
        let bd = t.thought_breakdown();
        if (bd[0] + bd[1] + bd[2] - 100.0).abs() > 1e-6 {
            return Err(format!("breakdown sums to {}", bd[0] + bd[1] + bd[2]));
        }
        // every anchor is a transition
        if t.segments.iter().any(|s| s.anchor && s.thought != Thought::Transition) {
            return Err("anchor on non-transition".into());
        }
        Ok(())
    });
}

/// BlockPool under concurrent reserve/release interleavings: usage never
/// exceeds capacity, the peak watermark is monotone and bounded, and
/// free + used == capacity once every thread has returned its bytes.
#[test]
fn block_pool_concurrent_interleavings_respect_capacity() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let capacity = 64 * 1024u64;
    let pool = Arc::new(BlockPool::new(capacity));
    let stop = Arc::new(AtomicBool::new(false));

    // watcher: the peak watermark may only grow, and never past capacity
    let watcher = {
        let pool = Arc::clone(&pool);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || -> Result<(), String> {
            let mut last = 0u64;
            while !stop.load(Ordering::SeqCst) {
                let p = pool.peak();
                if p < last {
                    return Err(format!("peak regressed {last} -> {p}"));
                }
                if p > capacity {
                    return Err(format!("peak {p} exceeds capacity {capacity}"));
                }
                last = p;
                std::thread::yield_now();
            }
            Ok(())
        })
    };

    let mut workers = Vec::new();
    for t in 0..8u64 {
        let pool = Arc::clone(&pool);
        workers.push(std::thread::spawn(move || -> Result<(), String> {
            let mut rng = Rng::new(0xB10C + t);
            let mut held: Vec<u64> = Vec::new();
            for _ in 0..4000 {
                if rng.chance(0.55) || held.is_empty() {
                    let amt = rng.below(512) as u64 + 1;
                    if pool.reserve(amt) {
                        held.push(amt);
                    }
                } else {
                    let amt = held.pop().expect("non-empty");
                    pool.release(amt);
                }
                let used = pool.used();
                if used > capacity {
                    return Err(format!("used {used} exceeds capacity {capacity}"));
                }
            }
            // quiescence: give everything back
            for amt in held {
                pool.release(amt);
            }
            Ok(())
        }));
    }
    for w in workers {
        w.join().expect("worker").expect("capacity invariant");
    }
    stop.store(true, Ordering::SeqCst);
    watcher.join().expect("watcher").expect("peak invariant");

    assert_eq!(pool.used(), 0, "all reservations returned");
    assert_eq!(pool.free() + pool.used(), capacity);
    assert!(pool.peak() > 0 && pool.peak() <= capacity);
}

/// Eviction policies must never evict below the requested target or return
/// out-of-set positions, whatever attention history they saw.
#[test]
fn eviction_policies_respect_contract() {
    use thinkv::baselines::eviction::*;
    prop::check(30, |g| {
        let n = g.usize(10, 120);
        let live: Vec<usize> = (0..n).collect();
        let target = g.usize(1, n);
        let mut policies: Vec<Box<dyn EvictionPolicy>> = vec![
            Box::new(H2O::new()),
            Box::new(Rkv::new()),
            Box::new(LazyEviction::new()),
            Box::new(RaaS::new()),
            Box::new(StreamingLlm::new(g.usize(0, 4))),
        ];
        for step in 0..g.usize(3, 25) {
            let attn: Vec<(usize, f32)> = live
                .iter()
                .map(|&p| (p, g.f32(0.0, 1.0)))
                .collect();
            for p in policies.iter_mut() {
                p.observe(&PosAttn { step, attn: attn.clone() });
            }
        }
        for p in policies.iter_mut() {
            let ev = p.select_evictions(&live, target);
            if ev.len() > n - target.min(n) {
                return Err(format!("{} evicted too many: {}", p.name(), ev.len()));
            }
            let set: std::collections::BTreeSet<_> = ev.iter().collect();
            if set.len() != ev.len() {
                return Err(format!("{} duplicates", p.name()));
            }
            if ev.iter().any(|e| !live.contains(e)) {
                return Err(format!("{} invalid position", p.name()));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Suspend-to-host snapshot fidelity (ISSUE 2)
// ---------------------------------------------------------------------------

fn tiny_model() -> ModelConfig {
    ModelConfig {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        d_head: 16,
        d_ffn: 64,
        rope_base: 10000.0,
        buf_slots: 8,
        prefill_len: 16,
        obs_window: 4,
        group_size: GROUP_SIZE,
    }
}

/// Synthetic decode-step output (no engine): random K/V plus a positive
/// attention row of the right span.
fn fake_decode(rng: &mut Rng, m: &ModelConfig, span: usize) -> DecodeOut {
    let kvd = m.n_kv_heads * m.d_head;
    let mut new_k = vec![0f32; m.n_layers * kvd];
    let mut new_v = vec![0f32; m.n_layers * kvd];
    rng.fill_normal_f32(&mut new_k, 0.0, 1.0);
    rng.fill_normal_f32(&mut new_v, 0.0, 1.0);
    let mut probs = vec![0f32; m.n_layers * m.n_heads * span];
    rng.fill_normal_f32(&mut probs, 0.5, 0.2);
    for p in probs.iter_mut() {
        *p = p.abs();
    }
    DecodeOut { logits: vec![0.0; m.vocab], new_k, new_v, probs }
}

fn fake_prefill(rng: &mut Rng, m: &ModelConfig) -> PrefillOut {
    let n = m.n_layers * m.prefill_len * m.n_kv_heads * m.d_head;
    let mut k = vec![0f32; n];
    let mut v = vec![0f32; n];
    rng.fill_normal_f32(&mut k, 0.0, 1.0);
    rng.fill_normal_f32(&mut v, 0.0, 1.0);
    PrefillOut { logits: vec![0.0; m.vocab], k, v, obs: vec![0.0; m.n_layers * m.prefill_len] }
}

/// snapshot -> restore must round-trip a QuantBackend bit-exactly under
/// randomized decode/evict histories (codes, scales, tags, eviction
/// masks, B_buf residue, segment + classifier + TBE state), and the
/// restored backend must evolve identically to the original when both
/// absorb the same continuation steps.
#[test]
fn quant_backend_snapshot_roundtrip_bit_exact() {
    prop::check(10, |g| {
        let m = tiny_model();
        let cfg = CacheConfig {
            layers: m.n_layers,
            capacity: 128,
            block_size: 8,
            hkv: m.n_kv_heads,
            dh: m.d_head,
            buf_slots: m.buf_slots,
        };
        let span = cfg.capacity + cfg.buf_slots;
        let budget = *g.pick(&[40usize, 48, 64]);
        let mk = || {
            QuantBackend::new(
                CtCache::new(cfg.clone()),
                Tbq::new(PrecisionAssignment::r4e4t2()),
                Some(Tbe::new(TbeConfig::new(budget))),
                Classifier::new(ClassifierConfig {
                    layers: vec![0, 1],
                    thresholds: vec![0.42, 0.7],
                    refresh: 8,
                }),
                None,
            )
        };
        let mut rng = Rng::new(g.usize(0, 1 << 30) as u64);
        let mut bd = Breakdown::default();
        let mut backend = mk();
        backend.write_prefill(&fake_prefill(&mut rng, &m), m.prefill_len);
        let mut pos = m.prefill_len;
        for _ in 0..g.usize(5, 60) {
            let out = fake_decode(&mut rng, &m, span);
            backend.make_room(pos, &mut bd).map_err(|e| format!("make_room: {e}"))?;
            backend.absorb(&out, pos, &m, &mut bd).map_err(|e| format!("absorb: {e}"))?;
            pos += 1;
        }

        // bit-exact: restoring the image into a fresh backend and
        // re-snapshotting must reproduce the identical image
        let snap_a = backend.snapshot().map_err(|e| e.to_string())?;
        if snap_a.device_bytes != backend.bytes_used() {
            return Err("device_bytes must record bytes_used at capture".into());
        }
        if snap_a.bytes != backend.snapshot_bytes() {
            return Err("snapshot_bytes must price the snapshot exactly".into());
        }
        let mut resumed = mk();
        resumed
            .restore(backend.snapshot().map_err(|e| e.to_string())?)
            .map_err(|e| format!("restore: {e}"))?;
        if resumed.bytes_used() != backend.bytes_used() {
            return Err("restored footprint drifted".into());
        }
        if resumed.live_tokens() != backend.live_tokens() {
            return Err("restored live tokens drifted".into());
        }
        let snap_b = resumed.snapshot().map_err(|e| e.to_string())?;
        let (SnapshotPayload::Quant(qa), SnapshotPayload::Quant(qb)) =
            (&snap_a.payload, &snap_b.payload)
        else {
            return Err("wrong payload kind".into());
        };
        if qa != qb {
            return Err("snapshot image not bit-exact after restore".into());
        }

        // behavioral: identical continuation inputs -> identical states
        // (TBE timing counters excluded: they are wall-clock)
        for _ in 0..10 {
            let out = fake_decode(&mut rng, &m, span);
            for b in [&mut backend, &mut resumed] {
                b.make_room(pos, &mut bd).map_err(|e| format!("cont make_room: {e}"))?;
                b.absorb(&out, pos, &m, &mut bd).map_err(|e| format!("cont absorb: {e}"))?;
            }
            pos += 1;
        }
        let fin_a = backend.snapshot().map_err(|e| e.to_string())?;
        let fin_b = resumed.snapshot().map_err(|e| e.to_string())?;
        let (SnapshotPayload::Quant(fa), SnapshotPayload::Quant(fb)) =
            (&fin_a.payload, &fin_b.payload)
        else {
            return Err("wrong payload kind".into());
        };
        let mut fa = (**fa).clone();
        let mut fb = (**fb).clone();
        if let Some(s) = fa.tbe_stats.as_mut() {
            s.nanos = 0;
        }
        if let Some(s) = fb.tbe_stats.as_mut() {
            s.nanos = 0;
        }
        if fa != fb {
            return Err("original and resumed backends diverged".into());
        }
        Ok(())
    });
}

/// Shared-prefix snapshot fidelity (prefix sharing x suspend-to-host):
/// a backend whose prefill **attached** a cross-session shared prefix
/// must (a) hold the exact same cache content as an unshared twin,
/// billed delta-only; (b) suspend and restore bit-identically with the
/// attachment re-linked; and (c) never perturb the publisher's cache
/// through any of it.
#[test]
fn shared_prefix_backend_snapshot_roundtrip_bit_exact() {
    prop::check(8, |g| {
        let m = tiny_model();
        let cfg = CacheConfig {
            layers: m.n_layers,
            capacity: 128,
            block_size: 8,
            hkv: m.n_kv_heads,
            dh: m.d_head,
            buf_slots: m.buf_slots,
        };
        let span = cfg.capacity + cfg.buf_slots;
        // no TBE and a huge refresh: the shared region stays read-only
        // for the whole history (CoW behavior is covered elsewhere)
        let mk = || {
            QuantBackend::new(
                CtCache::new(cfg.clone()),
                Tbq::new(PrecisionAssignment::r4e4t2()),
                None,
                Classifier::new(ClassifierConfig {
                    layers: vec![0, 1],
                    thresholds: vec![0.42, 0.7],
                    refresh: 10_000,
                }),
                None,
            )
        };
        let mut rng = Rng::new(g.usize(0, 1 << 30) as u64);
        let mut bd = Breakdown::default();
        let pool = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(Arc::clone(&pool), cfg.block_size);

        // the publisher prefills fully, then publishes its prefix
        let pf = fake_prefill(&mut rng, &m);
        let mut publisher = mk();
        publisher.write_prefill(&pf, m.prefill_len);
        let n = 8; // one shared block
        let payload = publisher.export_prefix(n).ok_or("export failed")?;
        let geom = publisher.prefix_geom();
        let tokens: Vec<i32> = (0..n as i32).collect();
        let pub_att = idx.publish(&tokens, geom, payload).ok_or("publish failed")?;
        publisher.reattach_prefix(pub_att);
        let publisher_before = publisher.snapshot().map_err(|e| e.to_string())?;

        // the sharer attaches the resident blocks + its private tail;
        // an unshared twin prefills the same K/V the plain way
        let att = idx
            .attach(&tokens, geom, m.prefill_len)
            .ok_or("attach failed")?;
        let att_bytes = att.bytes();
        let mut sharer = mk();
        sharer
            .write_prefill_shared(&pf, m.prefill_len, Arc::clone(&att))
            .map_err(|e| format!("shared prefill: {e}"))?;
        let mut twin = mk();
        twin.write_prefill(&pf, m.prefill_len);
        if sharer.shared_prefix_tokens() != n {
            return Err("shared region not marked".into());
        }

        // identical decode histories for sharer and twin
        let mut pos = m.prefill_len;
        for _ in 0..g.usize(5, 40) {
            let out = fake_decode(&mut rng, &m, span);
            for b in [&mut sharer, &mut twin] {
                b.make_room(pos, &mut bd).map_err(|e| format!("make_room: {e}"))?;
                b.absorb(&out, pos, &m, &mut bd).map_err(|e| format!("absorb: {e}"))?;
            }
            pos += 1;
        }
        // delta-only billing, exact: twin pays the prefix, sharer doesn't
        if sharer.bytes_used() + att_bytes != twin.bytes_used() {
            return Err(format!(
                "delta accounting drifted: {} + {} != {}",
                sharer.bytes_used(),
                att_bytes,
                twin.bytes_used()
            ));
        }

        // suspend/restore round trip with the attachment re-linked
        let snap = sharer.snapshot().map_err(|e| e.to_string())?;
        if snap.device_bytes != sharer.bytes_used() {
            return Err("device_bytes must record delta-accounted bytes_used".into());
        }
        let mut resumed = mk();
        resumed
            .restore(sharer.snapshot().map_err(|e| e.to_string())?)
            .map_err(|e| format!("restore: {e}"))?;
        resumed.reattach_prefix(Arc::clone(&att));
        if resumed.bytes_used() != sharer.bytes_used() {
            return Err("restored footprint drifted".into());
        }
        if resumed.shared_prefix_tokens() != n {
            return Err("shared region lost across the round trip".into());
        }
        let snap_b = resumed.snapshot().map_err(|e| e.to_string())?;
        let (SnapshotPayload::Quant(qa), SnapshotPayload::Quant(qb)) =
            (&snap.payload, &snap_b.payload)
        else {
            return Err("wrong payload kind".into());
        };
        if qa != qb {
            return Err("shared-prefix snapshot not bit-exact after restore".into());
        }

        // the publisher's cache never moved while the sharer attached,
        // decoded, suspended, and resumed
        let publisher_after = publisher.snapshot().map_err(|e| e.to_string())?;
        let (SnapshotPayload::Quant(pa), SnapshotPayload::Quant(pb)) =
            (&publisher_before.payload, &publisher_after.payload)
        else {
            return Err("wrong payload kind".into());
        };
        if pa != pb {
            return Err("sharer activity perturbed the publisher's cache".into());
        }
        Ok(())
    });
}

/// Same fidelity property for the f32 backend, parameterized over
/// **every** registered arena policy: the live rows, buffer residue,
/// and each policy's accumulated statistics (`box_clone` state) must
/// all survive the round trip — identical eviction/skip decisions
/// afterwards, for H2O and RaaS and SnapKV and Crystal-KV alike.
#[test]
fn fp32_backend_snapshot_roundtrip_bit_exact_for_every_policy() {
    prop::check(4, |g| {
        let m = tiny_model();
        let kvd = m.n_kv_heads * m.d_head;
        let capacity = 64;
        let span = capacity + m.buf_slots;
        let budget = *g.pick(&[24usize, 32, 48]);
        let seed = g.usize(0, 1 << 30) as u64;
        // FullKV never evicts: prefill + steps + the 16-step
        // continuation must fit the slab + ring (64 + 8) with slack
        let steps = g.usize(5, 32);
        for (ki, kind) in PolicyKind::ALL.into_iter().enumerate() {
            let name = kind.name();
            let mk = || {
                Fp32Backend::new(
                    Fp32Cache::new(m.n_layers, capacity, kvd, m.buf_slots),
                    kind.build(budget),
                    kind.budget_for(budget),
                    kind.gather(),
                    capacity,
                )
            };
            let mut rng = Rng::new(seed.wrapping_add(ki as u64));
            let mut bd = Breakdown::default();
            let mut backend = mk();
            backend.write_prefill(&fake_prefill(&mut rng, &m), m.prefill_len);
            let mut pos = m.prefill_len;
            for _ in 0..steps {
                let out = fake_decode(&mut rng, &m, span);
                backend.make_room(pos, &mut bd).map_err(|e| format!("{name} make_room: {e}"))?;
                backend.absorb(&out, pos, &m, &mut bd).map_err(|e| format!("{name} absorb: {e}"))?;
                pos += 1;
            }

            let snap_a = backend.snapshot().map_err(|e| e.to_string())?;
            if snap_a.bytes != backend.snapshot_bytes() {
                return Err(format!("{name}: snapshot_bytes must price the snapshot exactly"));
            }
            let mut resumed = mk();
            resumed
                .restore(backend.snapshot().map_err(|e| e.to_string())?)
                .map_err(|e| format!("{name} restore: {e}"))?;
            if resumed.bytes_used() != backend.bytes_used() {
                return Err(format!("{name}: restored footprint drifted"));
            }
            let snap_b = resumed.snapshot().map_err(|e| e.to_string())?;
            let (SnapshotPayload::Fp32(fa), SnapshotPayload::Fp32(fb)) =
                (&snap_a.payload, &snap_b.payload)
            else {
                return Err(format!("{name}: wrong payload kind"));
            };
            if fa.cache != fb.cache {
                return Err(format!("{name}: fp32 cache image not bit-exact after restore"));
            }

            // behavioral: the cloned policy must make identical eviction
            // and skip decisions (gather timing excluded: wall-clock)
            for _ in 0..16 {
                let out = fake_decode(&mut rng, &m, span);
                for b in [&mut backend, &mut resumed] {
                    b.make_room(pos, &mut bd).map_err(|e| format!("{name} cont make_room: {e}"))?;
                    b.absorb(&out, pos, &m, &mut bd)
                        .map_err(|e| format!("{name} cont absorb: {e}"))?;
                }
                pos += 1;
            }
            // (retention counters restart at zero on the resumed
            // backend — decisions, not tallies, are what must agree)
            if backend.live_positions() != resumed.live_positions() {
                return Err(format!("{name}: original and resumed made different evictions"));
            }
            let fin_a = backend.snapshot().map_err(|e| e.to_string())?;
            let fin_b = resumed.snapshot().map_err(|e| e.to_string())?;
            let (SnapshotPayload::Fp32(fa), SnapshotPayload::Fp32(fb)) =
                (&fin_a.payload, &fin_b.payload)
            else {
                return Err(format!("{name}: wrong payload kind"));
            };
            let mut ca = fa.cache.clone();
            let mut cb = fb.cache.clone();
            ca.gather_nanos = 0;
            cb.gather_nanos = 0;
            if ca != cb {
                return Err(format!("{name}: original and resumed fp32 backends diverged"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// SLO goodput accounting (ISSUE 7)
// ---------------------------------------------------------------------------

/// Goodput accounting must balance for any randomized mix of classed /
/// unclassed sessions, termination orders, verdicts, and clock skews
/// driven through the production scheduler on its logical clock:
/// `goodput + slo_violations` counts exactly the classed terminations
/// (best-effort sessions never score), every verdict matches the same
/// `met()` the scheduler applies, the per-class books fold to the
/// global pair, and the snapshot's SLO surface survives a JSON round
/// trip bit-exactly.
#[test]
fn slo_goodput_accounting_balances_and_roundtrips() {
    use std::sync::mpsc;
    use thinkv::coordinator::{SchedPolicy, Scheduler, ServeConfig, Session, SloTarget};
    use thinkv::testkit::tiny_manifest;

    prop::check(15, |g| {
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        let goodput_mode = g.bool();
        if goodput_mode {
            sched.set_policy(SchedPolicy::Goodput);
        }
        let mut now = 1u64;
        sched.drive_clock(now);
        let (tx, _rx) = mpsc::channel();

        // submit a random tenant mix: some sessions carry a class label,
        // some a live target, some neither — only label AND target score
        let n = g.usize(1, 20);
        for id in 1..=n as u64 {
            let label =
                if g.chance(0.8) { Some(*g.pick(&["chat", "math", "bulk"])) } else { None };
            let target = if g.chance(0.75) {
                SloTarget::new(
                    g.usize(1, 60) as u64,
                    if g.bool() { g.usize(200, 4000) as u64 } else { 0 },
                )
            } else {
                SloTarget::default()
            };
            let cfg = ServeConfig {
                max_new_tokens: 8,
                slo_class: label.map(str::to_string),
                slo: target,
                ..ServeConfig::default()
            };
            now += g.usize(0, 10) as u64;
            sched.drive_clock(now);
            let s = Session::with_pool(id, vec![1, 2, 3], &cfg, &man, Some(Arc::clone(&pool)))
                .map_err(|e| format!("session: {e}"))?;
            sched.submit(s, tx.clone());
        }

        // terminate every session with a randomized history — maybe a
        // first token, a few generated tokens, maybe a hard failure —
        // predicting each verdict with the scheduler's own met()
        let mut want: Vec<(String, u64, u64)> = Vec::new();
        let mut ok = 0u64;
        for _ in 0..n {
            let mut e = sched.next().ok_or("scheduler stopped early")?;
            if g.chance(0.8) {
                now += g.usize(0, 90) as u64;
                sched.drive_clock(now);
                e.session.slo.first_token_tick = Some(now);
            }
            for t in 0..g.usize(0, 5) {
                e.session.tokens.push(t as i32);
            }
            now += g.usize(0, 60) as u64;
            sched.drive_clock(now);
            let failed = g.chance(0.2);
            if e.session.slo.classed() {
                let mut probe = e.session.slo.clone();
                probe.finished_tick = Some(now);
                let met = !failed && probe.met(e.session.tokens.len()).unwrap_or(false);
                match want.iter().position(|(c, _, _)| *c == probe.class) {
                    Some(i) => {
                        if met {
                            want[i].1 += 1;
                        } else {
                            want[i].2 += 1;
                        }
                    }
                    None => want.push((probe.class.clone(), met as u64, !met as u64)),
                }
            }
            if failed {
                sched.complete_failed(&mut e.session);
            } else {
                sched.complete(&mut e.session);
                ok += 1;
            }
        }

        let snap = sched.snapshot();
        if snap.sched_policy_goodput != goodput_mode {
            return Err("policy flag drifted".into());
        }
        if snap.completions != ok {
            return Err(format!("completions {} != {ok}", snap.completions));
        }
        let (wg, wv) = want.iter().fold((0u64, 0u64), |(a, b), r| (a + r.1, b + r.2));
        if (snap.goodput, snap.slo_violations) != (wg, wv) {
            return Err(format!(
                "global pair ({}, {}) != predicted ({wg}, {wv})",
                snap.goodput, snap.slo_violations
            ));
        }
        if snap.goodput + snap.slo_violations > n as u64 {
            return Err("scored more sessions than terminated".into());
        }
        // class books appear in first-termination order and fold to the
        // global pair
        if snap.slo_classes.len() != want.len() {
            return Err(format!(
                "class book count {} != {}",
                snap.slo_classes.len(),
                want.len()
            ));
        }
        for (c, (name, cg, cv)) in snap.slo_classes.iter().zip(&want) {
            if (&c.name, c.goodput, c.violations) != (name, *cg, *cv) {
                return Err(format!(
                    "class {} book ({}, {}) != predicted {name} ({cg}, {cv})",
                    c.name, c.goodput, c.violations
                ));
            }
            if c.ttft_p99 < c.ttft_p50 || c.tpot_p99_milli < c.tpot_p50_milli {
                return Err(format!("class {} percentiles out of order", c.name));
            }
        }

        // the SLO surface must survive serialization exactly
        let j = snap.to_json();
        let back = json::parse(&j.to_string()).map_err(|e| format!("parse: {e}"))?;
        if back != j {
            return Err("snapshot JSON does not round-trip".into());
        }
        let policy = back.get("sched_policy").and_then(|v| v.as_str()).ok_or("sched_policy")?;
        if policy != if goodput_mode { "goodput" } else { "throughput" } {
            return Err(format!("sched_policy serialized as {policy}"));
        }
        for (key, val) in [("goodput", wg), ("slo_violations", wv)] {
            let got = back.get(key).and_then(|v| v.as_f64()).ok_or(key)?;
            if got != val as f64 {
                return Err(format!("{key} serialized as {got}, want {val}"));
            }
        }
        let classes = back.get("slo_classes").and_then(|v| v.as_arr()).ok_or("slo_classes")?;
        if classes.len() != want.len() {
            return Err("serialized class count drifted".into());
        }
        for (c, (name, cg, cv)) in classes.iter().zip(&want) {
            let cname = c.get("name").and_then(|v| v.as_str()).ok_or("class name")?;
            let cgood = c.get("goodput").and_then(|v| v.as_f64()).ok_or("class goodput")?;
            let cviol = c.get("violations").and_then(|v| v.as_f64()).ok_or("class violations")?;
            if cname != name || cgood != *cg as f64 || cviol != *cv as f64 {
                return Err(format!("class {cname} serialized as ({cgood}, {cviol})"));
            }
        }
        Ok(())
    });
}
