//! Cross-session prefix sharing: the ISSUE 4 acceptance properties,
//! artifact-free (a deterministic **causal** engine fake stands in for
//! PJRT — prefill K/V at position `i` depends only on tokens `0..=i`,
//! the invariant real causal prefill provides and sharing relies on).
//!
//! Properties pinned here:
//! * **Stream invariance** — N sessions with a common system prompt
//!   produce token streams bit-identical to the unshared path.
//! * **Admission multiplication** — a pool sized for ~1 full prefix +
//!   N deltas admits all N concurrently, while the unshared path
//!   admits only ~1.
//! * **CoW isolation** — the first divergent write privatizes the
//!   writer without perturbing the other sharers' caches or streams.

use std::sync::{mpsc, Arc};

use thinkv::coordinator::{
    advance_batch, CompressionMode, RequestResult, Scheduler, ServeConfig, Session, StepOutcome,
};
use thinkv::kvcache::{BlockPool, PrefixIndex};
use thinkv::model::Manifest;
use thinkv::testkit::{share_manifest, CausalEngine};

/// A common-system-prompt workload: one publisher prompt plus
/// `sharers` prompts that share the 88-token system prefix and then
/// diverge.
fn workload(sharers: usize) -> Vec<Vec<i32>> {
    let system: Vec<i32> = (0..88).map(|i| ((i * 3) % 60) as i32).collect();
    let mut prompts = Vec::new();
    for s in 0..=sharers {
        let mut p = system.clone();
        p.extend((0..8).map(|i| (s * 8 + i) as i32)); // divergent tail
        prompts.push(p);
    }
    prompts
}

/// Unshared reference: each session advanced alone, no pool bound.
fn run_reference(
    engine: &CausalEngine,
    man: &Manifest,
    cfg: &ServeConfig,
    prompts: &[Vec<i32>],
) -> Vec<Vec<i32>> {
    let mut streams = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let mut s = Session::new(i as u64 + 1, p.clone(), cfg, man).expect("session");
        loop {
            match s.step(engine).expect("reference step") {
                StepOutcome::Running => {}
                StepOutcome::Finished => break,
                StepOutcome::NeedMemory => panic!("reference run is unbounded"),
            }
        }
        streams.push(s.tokens.clone());
    }
    streams
}

/// Drive a scheduler until every submitted request completed.
fn drain(sched: &Scheduler, engine: &CausalEngine) {
    while sched.inflight() > 0 {
        let batch = sched.next_batch(4).expect("runnable batch while inflight");
        advance_batch(sched, engine, 3, batch);
    }
}

/// Acceptance: bit-identical streams + admission multiplication.
#[test]
fn shared_prefix_multiplies_admission_with_identical_streams() {
    let man = share_manifest();
    let engine = CausalEngine::new(man.model.clone());
    // quantization-only ThinKV: no TBE, so the shared region stays
    // read-only for the whole run (CoW is exercised separately below)
    let cfg = ServeConfig {
        mode: CompressionMode::parse("thinkv-notbe").expect("mode"),
        budget: 256,
        max_new_tokens: 6,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let sharers = 5;
    let prompts = workload(sharers);
    let reference = run_reference(&engine, &man, &cfg, &prompts);

    // ---- phase A: measure the byte economics on an unbounded pool ----
    let (est, resident, delta) = {
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
        let (tx, rx) = mpsc::channel();
        let publisher = Session::with_parts(
            1,
            prompts[0].clone(),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        let est = publisher.admission_bytes();
        sched.submit(publisher, tx.clone());
        drain(&sched, &engine);
        drop(tx);
        let _ = rx.iter().count();
        let resident = idx.stats().resident_bytes;
        let probe = Session::with_parts(
            2,
            prompts[1].clone(),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        let delta = probe.admission_bytes();
        (est, resident, delta)
    };
    assert!(resident > 0 && delta < est, "sharing must shrink admission: {delta} vs {est}");

    // ---- phase B: a pool sized for ~1 full prefix + N deltas (plus a
    // decode-growth margin: tokens past the ring quantize into the
    // cache beyond the admission estimate) ----
    let pool_bytes = (est + resident).max(resident + sharers as u64 * delta) + 4096;
    let pool = Arc::new(BlockPool::new(pool_bytes));
    let idx = PrefixIndex::new(Arc::clone(&pool), 8);
    let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
    let (tx, rx) = mpsc::channel();
    // the publisher runs first and leaves the prefix resident
    let publisher = Session::with_parts(
        1,
        prompts[0].clone(),
        &cfg,
        &man,
        Some(Arc::clone(&pool)),
        Some(Arc::clone(&idx)),
    )
    .expect("session");
    sched.submit(publisher, tx.clone());
    drain(&sched, &engine);
    assert_eq!(idx.stats().inserts, 1, "publisher left a resident prefix");
    // every sharer is admitted concurrently — the tentpole claim
    for (i, p) in prompts.iter().enumerate().skip(1) {
        let s = Session::with_parts(
            i as u64 + 1,
            p.clone(),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        assert!(s.has_prefix_attachment(), "sharer {i} must hit the trie");
        sched.submit(s, tx.clone());
    }
    let snap = sched.snapshot();
    assert_eq!(
        snap.running, sharers,
        "a pool of 1 prefix + {sharers} deltas must admit every sharer concurrently"
    );
    assert_eq!(snap.queue_depth, 0);
    assert!(snap.pool_peak <= snap.pool_capacity);
    drain(&sched, &engine);
    drop(tx);
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), prompts.len());
    for (r, want) in results.iter().zip(&reference) {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(&r.tokens, want, "request {} stream diverged from unshared", r.id);
    }
    let snap = sched.snapshot();
    assert_eq!(snap.prefix_hits as usize, sharers, "every sharer attached");
    assert_eq!(snap.prefix_cow_faults, 0, "nothing wrote past the boundary");
    assert_eq!(
        snap.pool_used, snap.prefix_resident_bytes,
        "at quiescence only the resident prefix holds bytes"
    );

    // ---- seed behavior: the same pool without sharing admits ~1 ----
    let pool2 = Arc::new(BlockPool::new(pool_bytes));
    let sched2 = Scheduler::new(Arc::clone(&pool2));
    let (tx2, _rx2) = mpsc::channel();
    for (i, p) in prompts.iter().enumerate().skip(1) {
        let s = Session::with_pool(i as u64 + 1, p.clone(), &cfg, &man, Some(Arc::clone(&pool2)))
            .expect("session");
        sched2.submit(s, tx2.clone());
    }
    let unshared_running = sched2.snapshot().running;
    assert_eq!(
        unshared_running,
        (pool_bytes / est) as usize,
        "unshared admission is full-prefix bound"
    );
    assert!(
        unshared_running < sharers,
        "seed path must admit fewer than the shared path ({unshared_running} vs {sharers})"
    );
    sched2.shutdown();
}

/// CoW isolation: with TBE on, budget pressure writes past the shared
/// boundary; the writer privatizes (pool has room) and every stream
/// still matches the unshared reference — other sharers unperturbed.
#[test]
fn cow_on_divergent_write_never_perturbs_sharers() {
    let man = share_manifest();
    let engine = CausalEngine::new(man.model.clone());
    let cfg = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 64, // < prefill_len: TBE must evict into the prefix
        max_new_tokens: 6,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let prompts = workload(3);
    let reference = run_reference(&engine, &man, &cfg, &prompts);

    let pool = Arc::new(BlockPool::new(u64::MAX / 2));
    let idx = PrefixIndex::new(Arc::clone(&pool), 8);
    let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
    let (tx, rx) = mpsc::channel();
    for (i, p) in prompts.iter().enumerate() {
        let s = Session::with_parts(
            i as u64 + 1,
            p.clone(),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        sched.submit(s, tx.clone());
    }
    drain(&sched, &engine);
    drop(tx);
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(
            &r.tokens, want,
            "request {} diverged: CoW must reproduce the unshared eviction history",
            r.id
        );
    }
    let stats = idx.stats();
    assert!(stats.cow_faults >= 1, "budget pressure must trigger copy-on-write");
    assert_eq!(stats.cow_denied, 0, "an unbounded pool never denies CoW");
    sched.shutdown();
}

/// The fp32 family shares too: FullKV sessions with a common system
/// prompt attach the resident rows and stream-match the unshared path.
#[test]
fn fp32_fullkv_sessions_share_prefix() {
    let man = share_manifest();
    let engine = CausalEngine::new(man.model.clone());
    let cfg = ServeConfig {
        mode: CompressionMode::FullKv,
        budget: 256,
        max_new_tokens: 5,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let prompts = workload(2);
    let reference = run_reference(&engine, &man, &cfg, &prompts);

    let pool = Arc::new(BlockPool::new(u64::MAX / 2));
    let idx = PrefixIndex::new(Arc::clone(&pool), 8);
    let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
    let (tx, rx) = mpsc::channel();
    for (i, p) in prompts.iter().enumerate() {
        let s = Session::with_parts(
            i as u64 + 1,
            p.clone(),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        sched.submit(s, tx.clone());
    }
    drain(&sched, &engine);
    drop(tx);
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&reference) {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(&r.tokens, want, "fp32 request {} stream diverged", r.id);
    }
    let stats = idx.stats();
    assert_eq!(stats.inserts, 1);
    assert!(stats.hits >= 2, "both later sessions attach");
    sched.shutdown();
}
