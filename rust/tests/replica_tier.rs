//! Multi-replica serving tier (ISSUE 9), artifact-free.
//!
//! Three bars:
//!
//! * **Differential** — a 1-replica `Router` must be byte-identical to
//!   the legacy single `Scheduler`: same per-request token streams,
//!   bit-equal `SchedSnapshot` (counters, gauges, histograms).
//! * **Live migration (deterministic acceptance)** — suspend a session
//!   mid-decode on a hot replica, resume it on a cold one: the token
//!   stream is bit-identical to a standalone reference, zero recompute
//!   steps are paid, the SLO submission stamp survives the move, and
//!   `migrations` / `migration_bytes` surface in the fleet-merged
//!   snapshot and its JSON.
//! * **Migration-point property** — the same holds at every mid-decode
//!   migration point across a sweep of pinned seeds.

use std::sync::{mpsc, Arc};

use thinkv::coordinator::{
    advance_batch, CompressionMode, RequestResult, Router, Scheduler, ServeConfig, Session,
    SloTarget, StepOutcome,
};
use thinkv::kvcache::BlockPool;
use thinkv::testkit::{share_manifest, CausalEngine};
use thinkv::util::json::Json;

fn base_cfg() -> ServeConfig {
    ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 64,
        max_new_tokens: 8,
        workers: 1,
        temperature: 0.8,
        ..ServeConfig::default()
    }
}

fn prompt_for(s: usize, vocab: usize) -> Vec<i32> {
    (0..8).map(|i| ((i * 5 + s * 17) % vocab) as i32).collect()
}

/// Standalone reference stream: the same session decoded to completion
/// with no scheduler involved.
fn reference_tokens(id: u64, prompt: Vec<i32>, cfg: &ServeConfig) -> Vec<i32> {
    let man = share_manifest();
    let engine = CausalEngine::new(man.model.clone());
    let mut s = Session::new(id, prompt, cfg, &man).expect("reference session");
    while !matches!(s.step(&engine).expect("step"), StepOutcome::Finished) {}
    s.tokens
}

fn drive(sched: &Scheduler, engine: &CausalEngine) {
    while sched.inflight() > 0 {
        let batch = sched.next_batch(4).expect("runnable while inflight");
        advance_batch(sched, engine, 2, batch);
    }
}

/// Differential bar: the 1-replica router IS the legacy scheduler.
/// Both runs share a tight pool (2 admissions for 6 arrivals, so the
/// queueing and recompute-preemption machinery is exercised), a pinned
/// logical clock, and identical sessions; streams and the full snapshot
/// must match bit-for-bit.
#[test]
fn single_replica_router_matches_legacy_scheduler() {
    let man = share_manifest();
    let cfg = base_cfg();
    let per_adm = Session::new(0, prompt_for(0, man.model.vocab), &cfg, &man)
        .expect("probe")
        .admission_bytes();
    let pool_bytes = per_adm * 2 + 4096;

    // legacy: one Scheduler in front of its own pool
    let legacy_pool = Arc::new(BlockPool::new(pool_bytes));
    let legacy = Scheduler::new(Arc::clone(&legacy_pool));
    legacy.drive_clock(1);
    let engine = CausalEngine::new(man.model.clone());
    let (tx, rx) = mpsc::channel();
    for s in 0..6usize {
        let sess = Session::with_pool(
            s as u64 + 1,
            prompt_for(s, man.model.vocab),
            &cfg,
            &man,
            Some(Arc::clone(&legacy_pool)),
        )
        .expect("session");
        legacy.submit(sess, tx.clone());
    }
    drive(&legacy, &engine);
    drop(tx);
    let mut legacy_results: Vec<RequestResult> = rx.iter().collect();
    legacy_results.sort_by_key(|r| r.id);
    let legacy_snap = legacy.snapshot();
    legacy.shutdown();

    // fleet of one: same pool bytes, same arrivals, driven identically
    let router = Router::new(1, pool_bytes, None, false, 16);
    let fleet = router.replicas()[0].scheduler();
    fleet.drive_clock(1);
    let engine2 = CausalEngine::new(man.model.clone());
    let (tx2, rx2) = mpsc::channel();
    for s in 0..6usize {
        let sess = Session::with_pool(
            s as u64 + 1,
            prompt_for(s, man.model.vocab),
            &cfg,
            &man,
            Some(Arc::clone(fleet.pool())),
        )
        .expect("session");
        router.submit_to(0, sess, tx2.clone());
    }
    drive(fleet, &engine2);
    drop(tx2);
    let mut fleet_results: Vec<RequestResult> = rx2.iter().collect();
    fleet_results.sort_by_key(|r| r.id);
    let fleet_snap = router.snapshot();

    assert_eq!(legacy_results.len(), 6);
    assert_eq!(fleet_results.len(), 6);
    for (a, b) in legacy_results.iter().zip(&fleet_results) {
        assert!(a.error.is_none() && b.error.is_none());
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} stream diverged", a.id);
        assert_eq!(a.preemptions, b.preemptions);
    }
    assert_eq!(legacy_snap, fleet_snap, "1-replica fleet snapshot must be bit-identical");
    // quiescent point: every session terminated, so the byte ledger on
    // both pools must balance (no leaked admission/bond/CoW leases)
    legacy_pool.assert_conserved();
    fleet.pool().assert_conserved();
    assert_eq!(fleet_snap.replicas, 1);
    assert_eq!(fleet_snap.migrations, 0);
    assert_eq!(router.rebalance(), 0, "a fleet of one never migrates");
    router.shutdown();
}

/// Deterministic acceptance bar: three classed sessions land on replica
/// 0, decode a couple of steps, then `rebalance` live-migrates one to
/// the idle replica 1. Streams stay bit-identical to standalone
/// references, zero recompute is paid (`preemptions == 0`, exactly one
/// swap round trip), the pre-migration SLO stamps decide the verdicts,
/// and the fleet snapshot + JSON surface the migration counters.
#[test]
fn live_migration_is_bit_exact_and_counted() {
    let man = share_manifest();
    let cfg = ServeConfig {
        max_new_tokens: 16,
        slo_class: Some("chat".into()),
        slo: SloTarget::new(50, 0),
        ..base_cfg()
    };
    let prompts: Vec<Vec<i32>> = (0..3).map(|s| prompt_for(s, man.model.vocab)).collect();
    let refs: Vec<Vec<i32>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| reference_tokens(i as u64 + 1, p.clone(), &cfg))
        .collect();

    let router = Router::new(2, u64::MAX / 4, Some(64 << 20), false, 16);
    let s0 = router.replicas()[0].scheduler();
    let s1 = router.replicas()[1].scheduler();
    s0.drive_clock(1);
    s1.drive_clock(1);
    let e0 = CausalEngine::new(man.model.clone());
    let e1 = CausalEngine::new(man.model.clone());
    let (tx, rx) = mpsc::channel();
    for (i, p) in prompts.iter().enumerate() {
        let sess =
            Session::with_pool(i as u64 + 1, p.clone(), &cfg, &man, Some(Arc::clone(s0.pool())))
                .expect("session");
        router.submit_to(0, sess, tx.clone());
    }
    // every TTFT deadline (50 ticks) is already lost when decode starts:
    // the verdicts below can only come out (0 met, 3 violated) if the
    // migrated session keeps its tick-1 submission stamp
    s0.drive_clock(200);
    s1.drive_clock(200);
    // all three prefill and decode two steps on the hot replica
    for _ in 0..3 {
        let batch = s0.next_batch(1).expect("runnable");
        advance_batch(s0, &e0, 2, batch);
    }
    assert_eq!(s0.load(), 3);
    assert_eq!(s1.load(), 0);
    let moved = router.rebalance();
    assert_eq!(moved, 1, "3-vs-0 skew is one migration over the gap");
    assert_eq!(router.migrations(), 1);

    // drain both replicas, each on its own engine
    loop {
        let (i0, i1) = (s0.inflight(), s1.inflight());
        if i0 + i1 == 0 {
            break;
        }
        if i0 > 0 {
            let batch = s0.next_batch(2).expect("runnable");
            advance_batch(s0, &e0, 4, batch);
        }
        if i1 > 0 {
            let batch = s1.next_batch(2).expect("runnable");
            advance_batch(s1, &e1, 4, batch);
        }
    }
    drop(tx);
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 3);
    for (r, want) in results.iter().zip(&refs) {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(&r.tokens, want, "request {} must decode bit-identically", r.id);
        assert_eq!(r.preemptions, 0, "migration must cost zero recompute resets");
    }
    let swap_ins: u64 = results.iter().map(|r| r.swap_ins).sum();
    assert_eq!(swap_ins, 1, "exactly the migrated session restores from a snapshot");

    // quiescent point: fleet drained — device and swap ledgers on both
    // replicas must balance (the migration rebound its leases cleanly)
    s0.pool().assert_conserved();
    s1.pool().assert_conserved();
    s0.swap_pool().expect("swap enabled").assert_conserved();
    s1.swap_pool().expect("swap enabled").assert_conserved();
    let merged = router.snapshot();
    assert_eq!(merged.replicas, 2);
    assert_eq!(merged.migrations, 1);
    assert!(merged.migration_bytes > 0, "a snapshot's bytes moved");
    assert_eq!(merged.preemptions, 0);
    assert_eq!((merged.swap_outs, merged.swap_ins), (1, 1));
    assert_eq!(merged.swap_used, 0, "swap bytes returned after the resume");
    assert_eq!(
        (merged.goodput, merged.slo_violations),
        (0, 3),
        "pre-migration SLO stamps must decide every verdict"
    );
    // the counters must be visible in the JSON stats surface and the
    // human summary (server `stats` reply / `thinkv generate` output)
    let j = merged.to_json();
    assert_eq!(j.get("migrations").and_then(Json::as_usize), Some(1));
    assert!(j.get("migration_bytes").and_then(Json::as_usize).unwrap_or(0) > 0);
    assert_eq!(j.get("replicas").and_then(Json::as_usize), Some(2));
    assert!(merged.summary().contains("1 migrations"), "summary: {}", merged.summary());
    router.shutdown();
}

/// Property bar: migration is stream-preserving at *every* mid-decode
/// point. Sweep pinned seeds and migration points (1..=4 single-step
/// pulls before the rebalance); whichever sessions move, all streams
/// must equal their standalone references with zero recompute.
#[test]
fn migration_at_any_mid_decode_point_preserves_streams() {
    let man = share_manifest();
    for pre in 1usize..=4 {
        let cfg = ServeConfig { seed: 40 + pre as u64, ..base_cfg() };
        let prompts: Vec<Vec<i32>> = (0..4).map(|s| prompt_for(s, man.model.vocab)).collect();
        let refs: Vec<Vec<i32>> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| reference_tokens(i as u64 + 1, p.clone(), &cfg))
            .collect();
        let router = Router::new(2, u64::MAX / 4, Some(64 << 20), false, 16);
        let s0 = router.replicas()[0].scheduler();
        let s1 = router.replicas()[1].scheduler();
        s0.drive_clock(1);
        s1.drive_clock(1);
        let e0 = CausalEngine::new(man.model.clone());
        let e1 = CausalEngine::new(man.model.clone());
        let (tx, rx) = mpsc::channel();
        for (i, p) in prompts.iter().enumerate() {
            let sess = Session::with_pool(
                i as u64 + 1,
                p.clone(),
                &cfg,
                &man,
                Some(Arc::clone(s0.pool())),
            )
            .expect("session");
            router.submit_to(0, sess, tx.clone());
        }
        // vary the migration point: `pre` single-step pulls leave the
        // front `pre` sessions at different decode depths
        for _ in 0..pre {
            let batch = s0.next_batch(1).expect("runnable");
            advance_batch(s0, &e0, 1, batch);
        }
        let moved = router.rebalance();
        assert!(moved >= 1, "pre={pre}: the 4-vs-0 skew must migrate");
        assert_eq!(moved as u64, router.migrations());
        loop {
            let (i0, i1) = (s0.inflight(), s1.inflight());
            if i0 + i1 == 0 {
                break;
            }
            if i0 > 0 {
                let batch = s0.next_batch(2).expect("runnable");
                advance_batch(s0, &e0, 4, batch);
            }
            if i1 > 0 {
                let batch = s1.next_batch(2).expect("runnable");
                advance_batch(s1, &e1, 4, batch);
            }
        }
        drop(tx);
        let mut results: Vec<RequestResult> = rx.iter().collect();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 4);
        for (r, want) in results.iter().zip(&refs) {
            assert!(r.error.is_none(), "pre={pre}: request {} failed", r.id);
            assert_eq!(&r.tokens, want, "pre={pre}: request {} stream diverged", r.id);
            assert_eq!(r.preemptions, 0, "pre={pre}: recompute paid for a migration");
        }
        let swap_ins: u64 = results.iter().map(|r| r.swap_ins).sum();
        assert_eq!(swap_ins, moved as u64, "pre={pre}: one snapshot restore per migration");
        let merged = router.snapshot();
        assert_eq!(merged.migrations, moved as u64);
        assert_eq!(merged.preemptions, 0, "pre={pre}: no preemption storm");
        s0.pool().assert_conserved();
        s1.pool().assert_conserved();
        router.shutdown();
    }
}
