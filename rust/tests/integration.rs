//! Integration tests over the full stack: AOT artifacts -> PJRT engine ->
//! coordinator. Skipped (with a notice) when `make artifacts` has not run.

use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig};
use thinkv::model::default_artifacts_dir;
use thinkv::runtime::{Engine, QuantCache};

fn artifacts_ready() -> bool {
    let dir = default_artifacts_dir();
    std::path::Path::new(&format!("{dir}/model_config.json")).exists()
}

struct Golden {
    h: usize,
    hkv: usize,
    d: usize,
    g: usize,
    c: usize,
    bu: usize,
    q: Vec<f32>,
    kc: Vec<u8>,
    ks: Vec<f32>,
    vc: Vec<u8>,
    vs: Vec<f32>,
    tags: Vec<u8>,
    mask: Vec<f32>,
    bk: Vec<f32>,
    bv: Vec<f32>,
    bm: Vec<f32>,
    want_out: Vec<f32>,
    want_probs: Vec<f32>,
}

fn load_attn_golden() -> Golden {
    let dir = default_artifacts_dir();
    let bytes = std::fs::read(format!("{dir}/attn_golden.bin")).expect("attn_golden.bin");
    let mut off = 4usize;
    let mut rd = |o: &mut usize| {
        let v = u32::from_le_bytes(bytes[*o..*o + 4].try_into().unwrap());
        *o += 4;
        v as usize
    };
    let _ver = rd(&mut off);
    let (h, hkv, d, g, c, bu) = (rd(&mut off), rd(&mut off), rd(&mut off), rd(&mut off), rd(&mut off), rd(&mut off));
    let f32s = |o: &mut usize, n: usize| -> Vec<f32> {
        let v = bytes[*o..*o + 4 * n]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        *o += 4 * n;
        v
    };
    let u8s = |o: &mut usize, n: usize| -> Vec<u8> {
        let v = bytes[*o..*o + n].to_vec();
        *o += n;
        v
    };
    let q = f32s(&mut off, h * d);
    let kc = u8s(&mut off, c * hkv * d);
    let ks = f32s(&mut off, c * hkv * g);
    let vc = u8s(&mut off, c * hkv * d);
    let vs = f32s(&mut off, c * hkv * g);
    let tags = u8s(&mut off, c);
    let mask = f32s(&mut off, c);
    let bk = f32s(&mut off, bu * hkv * d);
    let bv = f32s(&mut off, bu * hkv * d);
    let bm = f32s(&mut off, bu);
    let want_out = f32s(&mut off, h * d);
    let want_probs = f32s(&mut off, h * (c + bu));
    Golden { h, hkv, d, g, c, bu, q, kc, ks, vc, vs, tags, mask, bk, bv, bm, want_out, want_probs }
}

#[test]
fn fused_attention_hlo_matches_python_reference() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let eng = Engine::new().unwrap();
    let gl = load_attn_golden();
    let mc = eng.manifest.micro_c;
    // pad the golden case into the micro capacity with masked slots
    let mut kc = vec![0u8; mc * gl.hkv * gl.d];
    kc[..gl.kc.len()].copy_from_slice(&gl.kc);
    let mut ks = vec![0f32; mc * gl.hkv * gl.g];
    ks[..gl.ks.len()].copy_from_slice(&gl.ks);
    let mut vc = vec![0u8; mc * gl.hkv * gl.d];
    vc[..gl.vc.len()].copy_from_slice(&gl.vc);
    let mut vs = vec![0f32; mc * gl.hkv * gl.g];
    vs[..gl.vs.len()].copy_from_slice(&gl.vs);
    let mut tags = vec![0u8; mc];
    tags[..gl.c].copy_from_slice(&gl.tags);
    let mut mask = vec![0f32; mc];
    mask[..gl.c].copy_from_slice(&gl.mask);
    let (out, probs) = eng
        .attn_micro(&gl.q, &kc, &ks, &vc, &vs, &tags, &mask, &gl.bk, &gl.bv, &gl.bm)
        .unwrap();
    let out_err = out
        .iter()
        .zip(&gl.want_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(out_err < 1e-4, "attention out err {out_err}");
    let mut perr = 0f32;
    for h in 0..gl.h {
        for j in 0..gl.c {
            perr = perr.max((probs[h * (mc + gl.bu) + j] - gl.want_probs[h * (gl.c + gl.bu) + j]).abs());
        }
        for j in 0..gl.bu {
            perr = perr
                .max((probs[h * (mc + gl.bu) + mc + j] - gl.want_probs[h * (gl.c + gl.bu) + gl.c + j]).abs());
        }
    }
    assert!(perr < 1e-4, "probs err {perr}");
}

#[test]
fn decode_step_deterministic_and_probs_normalized() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let eng = Engine::new().unwrap();
    let m = eng.model().clone();
    let cap = eng.manifest.quant_caps[0];
    let (l, hkv, dh, g, b) = (m.n_layers, m.n_kv_heads, m.d_head, m.groups(), m.buf_slots);
    let k_codes = vec![0u8; l * cap * hkv * dh];
    let k_scales = vec![0f32; l * cap * hkv * g];
    let v_codes = vec![0u8; l * cap * hkv * dh];
    let v_scales = vec![0f32; l * cap * hkv * g];
    let tags = vec![0u8; l * cap];
    let mask = vec![0f32; l * cap];
    let buf_k = vec![0f32; l * b * hkv * dh];
    let buf_v = vec![0f32; l * b * hkv * dh];
    let buf_mask = vec![0f32; l * b];
    let cache = QuantCache {
        capacity: cap,
        k_codes: &k_codes,
        k_scales: &k_scales,
        v_codes: &v_codes,
        v_scales: &v_scales,
        tags: &tags,
        mask: &mask,
        buf_k: &buf_k,
        buf_v: &buf_v,
        buf_mask: &buf_mask,
        shared: None,
    };
    let a = eng.decode_quant(5, 0, 0, &cache).unwrap();
    let bb = eng.decode_quant(5, 0, 0, &cache).unwrap();
    assert_eq!(a.logits, bb.logits, "decode must be deterministic");
    assert_eq!(a.logits.len(), m.vocab);
    assert_eq!(a.new_k.len(), l * hkv * dh);
    // with an empty cache, attention sees only the current token: each
    // row's probability mass must be exactly 1 on the buffer slot
    let span = cap + b;
    for lh in 0..l * m.n_heads {
        let row = &a.probs[lh * span..(lh + 1) * span];
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row mass {sum}");
        assert!((row[cap] - 1.0).abs() < 1e-4, "self slot {}", row[cap]);
    }
}

#[test]
fn prefill_then_decode_consistency_quant_vs_fp32() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // The same prefill cache fed through (a) the fp32 decode path and
    // (b) the FP8-quantized path must agree on the next-token argmax.
    let eng = Engine::new().unwrap();
    let m = eng.model().clone();
    let p = m.prefill_len;
    let prompt: Vec<i32> = (0..p as i32).map(|i| (i * 11) % m.vocab as i32).collect();
    let pf = eng.prefill(&prompt).unwrap();

    let (l, hkv, dh, g, b) = (m.n_layers, m.n_kv_heads, m.d_head, m.groups(), m.buf_slots);
    let kvd = hkv * dh;
    // fp32 path
    let capf = eng.manifest.fp32_caps[0];
    let mut kf = vec![0f32; l * capf * kvd];
    let mut vf = vec![0f32; l * capf * kvd];
    let mut maskf = vec![0f32; l * capf];
    for li in 0..l {
        for pos in 0..p {
            let src = (li * p + pos) * kvd;
            let dst = (li * capf + pos) * kvd;
            kf[dst..dst + kvd].copy_from_slice(&pf.k[src..src + kvd]);
            vf[dst..dst + kvd].copy_from_slice(&pf.v[src..src + kvd]);
            maskf[li * capf + pos] = 1.0;
        }
    }
    let zbk = vec![0f32; l * b * kvd];
    let zbm = vec![0f32; l * b];
    let fp = eng
        .decode_fp32(capf, 17, p as i32, 0, &kf, &vf, &maskf, &zbk, &zbk, &zbm, None)
        .unwrap();

    // FP8 quantized path
    let capq = eng.manifest.quant_caps[0];
    let mut cache = thinkv::kvcache::CtCache::new(thinkv::kvcache::CacheConfig {
        layers: l,
        capacity: capq,
        block_size: 8,
        hkv,
        dh,
        buf_slots: b,
    });
    cache.write_prefill(&pf.k, &pf.v, p, thinkv::quant::Precision::Fp8);
    let q = eng.decode_quant(17, p as i32, 0, &cache.view()).unwrap();

    let am_f = thinkv::util::stats::argmax(&fp.logits);
    let am_q = thinkv::util::stats::argmax(&q.logits);
    assert_eq!(am_f, am_q, "fp8-quantized decode must track fp32 argmax");
    let max_diff = fp
        .logits
        .iter()
        .zip(&q.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 0.2, "logit drift {max_diff}");
}

#[test]
fn coordinator_end_to_end_thinkv_vs_fullkv() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for (mode, budget) in [
        (CompressionMode::thinkv_default(), 192usize),
        (CompressionMode::FullKv, usize::MAX),
    ] {
        let label = mode.label();
        let cfg = ServeConfig {
            mode,
            budget: budget.min(192),
            max_new_tokens: 40,
            workers: 1,
            temperature: 0.0,
            ..ServeConfig::default()
        };
        let coordinator = Coordinator::start(cfg).unwrap();
        let prompt: Vec<i32> = (0..64).map(|i| (i * 3 % 512) as i32).collect();
        let results = coordinator
            .run_batch(vec![prompt.clone(), prompt])
            .unwrap();
        assert_eq!(results.len(), 2, "{label}");
        for r in &results {
            assert_eq!(r.tokens.len(), 40, "{label}");
            assert!(r.breakdown.steps > 0, "{label}");
        }
        // greedy + same prompt => identical outputs across requests
        assert_eq!(results[0].tokens, results[1].tokens, "{label} determinism");
    }
}

/// The acceptance scenario for the memory-aware scheduler: aggregate KV
/// demand far exceeds the pool, yet every request completes via
/// admission queueing (and preemption when a running request must grow),
/// and the pool never goes over capacity.
#[test]
fn scheduler_completes_oversubscribed_batch_within_pool() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let manifest = thinkv::model::Manifest::load(&default_artifacts_dir()).unwrap();
    let base = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 96,
        max_new_tokens: 24,
        workers: 2,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    // size the pool to ~2.5 admission reserves so 6 requests oversubscribe
    let probe = thinkv::coordinator::Session::new(0, vec![1, 2, 3], &base, &manifest).unwrap();
    let per = probe.admission_bytes();
    assert!(per > 0);
    let cfg = ServeConfig { pool_bytes: Some(per * 5 / 2), ..base };
    let coordinator = Coordinator::start(cfg).unwrap();
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|u| (0..64).map(|i| ((i * 7 + u) % 512) as i32).collect())
        .collect();
    let results = coordinator.run_batch(prompts).unwrap();
    assert_eq!(results.len(), 6);
    for r in &results {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(r.tokens.len(), 24, "request {} truncated", r.id);
    }
    // results are delivered just before the scheduler's completion
    // bookkeeping runs; give the workers a moment to settle
    let mut stats = coordinator.sched_stats();
    for _ in 0..200 {
        if stats.completions == 6 && stats.pool_used == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        stats = coordinator.sched_stats();
    }
    assert!(
        stats.pool_peak <= stats.pool_capacity,
        "pool overflow: peak {} > capacity {}",
        stats.pool_peak,
        stats.pool_capacity
    );
    assert!(stats.pool_peak > 0, "pool accounting inactive");
    assert_eq!(stats.completions, 6);
    assert!(stats.admissions >= 6, "each request admitted at least once");
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(stats.running, 0);
    assert_eq!(stats.pool_used, 0, "all bytes returned at quiescence");
    // ledger conservation at quiescence: used == Σ live-lease bytes
    // (and both are zero here — no admission/bond/CoW lease leaked)
    coordinator.pool().assert_conserved();
    assert_eq!((stats.pool_leases, stats.pool_leased_bytes), (0, 0));
}

/// The ISSUE 2 acceptance scenario: with suspend-to-host swap enabled,
/// every preempted session resumes from its snapshot instead of
/// recomputing — the token streams are identical to an unpreempted run,
/// no session ever replays a decode step, and the swap pool drains back
/// to zero at quiescence.
#[test]
fn swapped_preemption_preserves_streams_with_zero_recompute() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let manifest = thinkv::model::Manifest::load(&default_artifacts_dir()).unwrap();
    let base = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 96,
        max_new_tokens: 32,
        workers: 2,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|u| (0..64).map(|i| ((i * 7 + u) % 512) as i32).collect())
        .collect();

    // reference run: unbounded pool, no preemption possible
    let reference = Coordinator::start(base.clone()).unwrap();
    let ref_results = reference.run_batch(prompts.clone()).unwrap();
    assert_eq!(reference.sched_stats().preemptions, 0, "reference must not preempt");
    reference.shutdown();

    // oversubscribed run with swap: tight pool forces preemptions, the
    // generous host pool absorbs every snapshot
    let probe = thinkv::coordinator::Session::new(0, vec![1, 2, 3], &base, &manifest).unwrap();
    let per = probe.admission_bytes();
    let cfg = ServeConfig {
        pool_bytes: Some(per * 2 + per / 4),
        swap_bytes: Some(256 << 20),
        ..base.clone()
    };
    let coordinator = Coordinator::start(cfg).unwrap();
    let results = coordinator.run_batch(prompts).unwrap();
    assert_eq!(results.len(), 6);
    for (r, rr) in results.iter().zip(&ref_results) {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(
            r.tokens, rr.tokens,
            "request {}: swapped run must produce the identical stream",
            r.id
        );
        assert_eq!(r.preemptions, 0, "request {}: no recompute resets", r.id);
        // zero replay: one decode step per generated token (prefill
        // bootstraps the first), never more
        assert!(
            r.breakdown.steps < r.tokens.len() as u64 + 1,
            "request {}: {} steps for {} tokens (replayed work)",
            r.id,
            r.breakdown.steps,
            r.tokens.len()
        );
    }
    // settle, then check the swap books balance
    let mut stats = coordinator.sched_stats();
    for _ in 0..200 {
        if stats.completions == 6 && stats.pool_used == 0 && stats.swap_used == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        stats = coordinator.sched_stats();
    }
    assert!(stats.pool_peak <= stats.pool_capacity);
    assert_eq!(stats.completions, 6);
    assert_eq!(stats.swap_fallbacks, 0, "every snapshot must fit the host pool");
    assert_eq!(stats.swap_ins, stats.swap_outs, "every swap-out resumed");
    assert_eq!(stats.swap_bytes_in, stats.swap_bytes_out);
    assert_eq!(stats.swap_used, 0, "swap pool drained at quiescence");
    assert_eq!(stats.pool_used, 0);
    // both ledgers must balance at quiescence: every admission, growth
    // bond, and swap-stage lease was settled exactly once
    coordinator.pool().assert_conserved();
    if let Some(swap) = coordinator.router().replicas()[0].scheduler().swap_pool() {
        swap.assert_conserved();
    }
}

#[test]
fn coordinator_respects_budget() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cfg = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 96,
        max_new_tokens: 80,
        workers: 1,
        ..ServeConfig::default()
    };
    let coordinator = Coordinator::start(cfg).unwrap();
    let prompt: Vec<i32> = (0..64).map(|i| (i % 512) as i32).collect();
    let r = coordinator.submit(prompt).unwrap().wait().unwrap();
    assert_eq!(r.tokens.len(), 80);
    assert!(r.live_tokens <= 96 + 16, "budget violated: {}", r.live_tokens);
    assert!(r.avg_bits < 8.0, "TBQ not applied: {}", r.avg_bits);
}
