//! Stall-free chunked prefill: correctness and scheduling properties,
//! artifact-free (ISSUE 5).
//!
//! Two bars:
//!
//! * **Bit-invariance** — running prompts through the chunked-prefill
//!   scheduler path (`Scheduler::set_prefill_chunking` + the
//!   `advance_batch` prefill lane) must produce token streams
//!   bit-identical to the whole-prompt path, across randomized chunk
//!   sizes, both cache families, and prefix sharing on/off.
//! * **Head-of-line regression** — a long-prompt arrival must delay a
//!   running session's next decode step by at most one chunk (plus its
//!   decode batch-mates), not a full prefill. Measured on the metered
//!   causal fake's deterministic engine-time clock, so the bound is
//!   exact rather than a wall-clock heuristic.

use std::sync::{mpsc, Arc};

use thinkv::coordinator::{
    advance_batch, CompressionMode, RequestResult, Scheduler, ServeConfig, Session, StepOutcome,
};
use thinkv::kvcache::{BlockPool, PrefixIndex};
use thinkv::metrics::SchedSnapshot;
use thinkv::testkit::{share_manifest, tiny_manifest, CausalEngine, MeteredEngine};
use thinkv::util::prop;
use thinkv::util::rng::Rng;

/// Prefix-trie granularity used by the serving coordinator.
const PREFIX_BLOCK_TOKENS: usize = 8;

fn mode_for(tag: usize) -> CompressionMode {
    match tag {
        0 => CompressionMode::thinkv_default(),
        1 => CompressionMode::parse("kivi2").expect("kivi2 parses"),
        _ => CompressionMode::FullKv,
    }
}

fn cfg_for(tag: usize, max_new: usize, temperature: f64) -> ServeConfig {
    ServeConfig {
        mode: mode_for(tag),
        budget: 64,
        max_new_tokens: max_new,
        workers: 1,
        temperature,
        ..ServeConfig::default()
    }
}

/// Reference: each session advanced alone through `Session::step`,
/// whole-prompt prefill inside the first step, no scheduler.
fn run_whole(
    engine: &CausalEngine,
    man: &thinkv::model::Manifest,
    cfgs: &[ServeConfig],
    prompts: &[Vec<i32>],
) -> Vec<Vec<i32>> {
    let mut streams = Vec::new();
    for (i, (cfg, prompt)) in cfgs.iter().zip(prompts).enumerate() {
        let mut s = Session::new(i as u64 + 1, prompt.clone(), cfg, man).expect("session");
        loop {
            match s.step(engine).expect("whole-prompt step") {
                StepOutcome::Running => {}
                StepOutcome::Finished => break,
                StepOutcome::NeedMemory => panic!("unbounded pool cannot starve"),
            }
        }
        streams.push(s.tokens.clone());
    }
    streams
}

/// Chunked: the production path — scheduler batch formation with the
/// prefill lane + token budget, the `advance_batch` worker body — with
/// randomized batch caps and worker chunk lengths.
fn run_chunked(
    engine: &CausalEngine,
    man: &thinkv::model::Manifest,
    cfgs: &[ServeConfig],
    prompts: &[Vec<i32>],
    chunk_tokens: usize,
    share: bool,
    g: &mut prop::Gen,
) -> (Vec<Vec<i32>>, SchedSnapshot) {
    let pool = Arc::new(BlockPool::new(u64::MAX / 2));
    let prefix = share.then(|| PrefixIndex::new(Arc::clone(&pool), PREFIX_BLOCK_TOKENS));
    let sched = Scheduler::with_prefix(Arc::clone(&pool), None, prefix);
    sched.set_prefill_chunking(chunk_tokens, 0);
    let (tx, rx) = mpsc::channel();
    for (i, (cfg, prompt)) in cfgs.iter().zip(prompts).enumerate() {
        let s = Session::with_parts(
            i as u64 + 1,
            prompt.clone(),
            cfg,
            man,
            Some(Arc::clone(&pool)),
            sched.prefix_index().cloned(),
        )
        .expect("session");
        sched.submit(s, tx.clone());
    }
    drop(tx);
    while sched.inflight() > 0 {
        let max = g.usize(1, 6);
        let steps = g.usize(1, 7);
        let batch = sched.next_batch(max).expect("runnable batch while inflight");
        advance_batch(&sched, engine, steps, batch);
    }
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    for r in &results {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
    }
    let snap = sched.snapshot();
    (results.into_iter().map(|r| r.tokens).collect(), snap)
}

/// Chunked prefill must be stream-bit-invariant vs whole-prompt
/// prefill, for randomized chunk sizes (sub-block through
/// larger-than-prompt), mixed cache families, and sharing on/off — and
/// the chunk counters must account for the work.
#[test]
fn chunked_streams_bit_identical_to_whole_prompt() {
    prop::check(10, |g| {
        let man = tiny_manifest();
        let engine = CausalEngine::new(man.model.clone());
        let n = g.usize(2, 6);
        // 1..40 spans single-token chunks through one-chunk-per-prompt
        // (prefill_len is 32)
        let chunk_tokens = g.usize(1, 40);
        let share = g.bool();
        let max_new = g.usize(4, 12);
        let temperature = if g.bool() { 0.8 } else { 0.0 };
        let mut rng = Rng::new(g.usize(0, 1 << 30) as u64);
        let cfgs: Vec<ServeConfig> = (0..n)
            .map(|_| cfg_for(rng.below(3), max_new, temperature))
            .collect();
        // with sharing on, prompts carry a common block-aligned system
        // prefix so the attach/publish fork is exercised under chunking
        let system: Vec<i32> = (0..16).map(|i| (i * 3 % 60) as i32).collect();
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|u| {
                let mut p = if share { system.clone() } else { Vec::new() };
                let tail = rng.below(8) + 3;
                p.extend((0..tail).map(|i| (40 + u * 8 + i) as i32));
                p
            })
            .collect();

        let reference = run_whole(&engine, &man, &cfgs, &prompts);
        let (chunked, snap) =
            run_chunked(&engine, &man, &cfgs, &prompts, chunk_tokens, share, g);

        for (i, (whole, ck)) in reference.iter().zip(&chunked).enumerate() {
            if whole != ck {
                return Err(format!(
                    "session {} diverged under chunk={chunk_tokens} share={share}: \
                     whole {:?} vs chunked {:?}",
                    i + 1,
                    whole,
                    ck
                ));
            }
            if whole.len() != max_new {
                return Err(format!("session {} truncated: {} tokens", i + 1, whole.len()));
            }
        }
        if snap.prefill_chunks == 0 {
            return Err("chunked run recorded no prefill chunks".into());
        }
        if snap.completions != n as u64 {
            return Err(format!("completions {} != {n}", snap.completions));
        }
        // books at quiescence: only resident shared prefixes may remain
        if snap.pool_used != snap.prefix_resident_bytes {
            return Err(format!(
                "pool bytes stranded: used {} vs resident prefixes {}",
                snap.pool_used, snap.prefix_resident_bytes
            ));
        }
        if share && snap.prefix_hits + snap.prefix_inserts == 0 {
            return Err("sharing enabled but the trie never engaged".into());
        }
        Ok(())
    });
}

/// The prefill cursor is a real state machine: chunks advance it,
/// `prefill_remaining` counts down, a recompute reset rewinds it, and
/// the restarted session still produces the reference stream.
#[test]
fn prefill_cursor_advances_and_survives_reset() {
    let man = tiny_manifest();
    let engine = CausalEngine::new(man.model.clone());
    let cfg = cfg_for(0, 6, 0.0);
    let prompt: Vec<i32> = (0..20).collect();
    let p_len = man.model.prefill_len; // 32, prompt padded up to it

    // reference stream
    let mut reference = Session::new(7, prompt.clone(), &cfg, &man).unwrap();
    while !matches!(reference.step(&engine).unwrap(), StepOutcome::Finished) {}

    let mut s = Session::new(7, prompt.clone(), &cfg, &man).unwrap();
    assert!(!s.prefill_done());
    assert_eq!(s.prefill_remaining(), p_len);
    assert!(!s.advance_prefill(&engine, 10).unwrap());
    assert_eq!(s.prefill_remaining(), p_len - 10);
    assert!(!s.advance_prefill(&engine, 10).unwrap());
    // a mid-prefill reset rewinds the cursor without counting a
    // recompute preemption (no generated work was lost)
    s.reset_for_preemption();
    assert_eq!(s.preemptions, 0);
    assert_eq!(s.prefill_remaining(), p_len);
    // finish in uneven chunks; the final chunk bootstraps the token
    assert!(!s.advance_prefill(&engine, 30).unwrap());
    assert_eq!(s.prefill_remaining(), 2);
    assert!(s.advance_prefill(&engine, 99).unwrap());
    assert!(s.prefill_done());
    assert_eq!(s.tokens.len(), 1, "final chunk samples the first token");
    assert_eq!(s.breakdown.prefill_chunks, 4, "2 pre-reset + 2 post-reset");
    assert!(s.breakdown.prefill_exec_ns > 0, "prefill wall time recorded");
    while !matches!(s.step(&engine).unwrap(), StepOutcome::Finished) {}
    assert_eq!(s.tokens, reference.tokens, "reset + chunked replay is bit-identical");
}

/// Drive one running session plus one long-prompt arrival and measure
/// the runner's inter-step gaps on the deterministic engine-time clock.
fn runner_gaps(chunk: Option<usize>) -> (u64, SchedSnapshot) {
    let man = share_manifest(); // prefill_len 96: a genuinely long prompt
    let engine = MeteredEngine::new(man.model.clone());
    let pool = Arc::new(BlockPool::new(u64::MAX / 2));
    let sched = Scheduler::new(Arc::clone(&pool));
    if let Some(c) = chunk {
        sched.set_prefill_chunking(c, 0);
    }
    let base = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 64,
        max_new_tokens: 200,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let (tx, rx) = mpsc::channel();
    let prompt: Vec<i32> = (0..96).map(|i| (i % 50) as i32).collect();
    let runner =
        Session::with_pool(1, prompt.clone(), &base, &man, Some(Arc::clone(&pool))).unwrap();
    sched.submit(runner, tx.clone());
    // warm the runner into steady decode
    for _ in 0..4 {
        let batch = sched.next_batch(3).expect("runner runnable");
        advance_batch(&sched, &engine, 4, batch);
    }
    // the long-prompt arrival lands
    let arr_cfg = ServeConfig { max_new_tokens: 4, ..base.clone() };
    let mut p2 = prompt.clone();
    p2[0] = 49;
    sched.submit(Session::with_pool(2, p2, &arr_cfg, &man, Some(Arc::clone(&pool))).unwrap(), tx);
    let start = engine.step_marks().len().saturating_sub(1);
    let mut results: Vec<RequestResult> = Vec::new();
    while results.is_empty() {
        let batch = sched.next_batch(3).expect("runnable while inflight");
        advance_batch(&sched, &engine, 4, batch);
        results.extend(rx.try_iter());
    }
    let marks = engine.step_marks();
    let max_gap = marks[start..]
        .windows(2)
        .map(|w| w[1] - w[0])
        .max()
        .expect("runner decoded through the arrival");
    // drain the runner so the books balance
    while sched.inflight() > 0 {
        let batch = sched.next_batch(3).expect("runnable while inflight");
        advance_batch(&sched, &engine, 8, batch);
    }
    results.extend(rx.iter());
    assert_eq!(results.iter().filter(|r| r.error.is_none()).count(), 2);
    let snap = sched.snapshot();
    assert!(snap.pool_peak <= snap.pool_capacity);
    sched.shutdown();
    (max_gap, snap)
}

/// Head-of-line regression: whole-prompt prefill stalls the runner for
/// a full prefill (>= 96 engine-time units); chunked prefill bounds the
/// stall at one chunk plus the fused batch width — and the interleave
/// counters prove the chunk rode along live decode steps.
#[test]
fn arrival_delays_runner_by_one_chunk_not_a_full_prefill() {
    const CHUNK: usize = 16;
    let (whole_max, whole_snap) = runner_gaps(None);
    assert!(
        whole_max >= 96,
        "whole-prompt arrival must stall the runner for a full prefill (gap {whole_max})"
    );
    assert_eq!(whole_snap.prefill_chunks, 0, "no chunk lane when disabled");

    let (chunked_max, chunked_snap) = runner_gaps(Some(CHUNK));
    assert!(
        chunked_max <= (CHUNK + 2) as u64,
        "runner delayed by more than one chunk + batch width: {chunked_max}"
    );
    assert!(chunked_max < whole_max);
    assert!(
        chunked_snap.prefill_chunks >= (96 / CHUNK) as u64,
        "arrival must prefill chunk by chunk ({} chunks)",
        chunked_snap.prefill_chunks
    );
    assert!(
        chunked_snap.prefill_interleaved_steps > 0,
        "chunks must interleave with live decode steps"
    );
}
