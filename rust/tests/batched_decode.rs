//! Cross-session batched decode: correctness properties that must hold
//! for the fused worker path.
//!
//! The core bar (ISSUE 3): batching is **stream-invariant** — advancing
//! sessions through `Scheduler::next_batch` + `advance_batch` (the real
//! production worker body, one fused `decode_batch` call per step) must
//! produce token streams bit-identical to advancing each session alone
//! through `Session::step`, for randomized batch compositions, chunk
//! sizes, compression-mode mixes, and sampling temperatures. A
//! deterministic [`DecodeEngine`] fake stands in for the PJRT engine so
//! the property runs everywhere (CI has no artifacts).
//!
//! The `artifact_*` lanes (ISSUE 6) raise the same bar against the real
//! PJRT engine and its compiled batched-decode artifacts: one execute
//! per fused step when a compiled width covers the batch (asserted via
//! the scheduler's PJRT ledger), counted greedy splits beyond the
//! widest width, both cache families, and shared-prefix aliasing that
//! is bit-invisible in the output. They self-skip (loudly) when `make
//! artifacts` has not run.

use std::sync::{mpsc, Arc};

use anyhow::Result;
use thinkv::coordinator::{
    advance_batch, CompressionMode, RequestResult, Scheduler, ServeConfig, Session, StepOutcome,
};
use thinkv::kvcache::{BlockPool, PrefixIndex};
use thinkv::model::{default_artifacts_dir, Manifest, ModelConfig};
use thinkv::runtime::{CacheView, DecodeEngine, DecodeOut, Engine, PrefillOut};
use thinkv::util::prop;
use thinkv::util::rng::Rng;

/// Hand-built manifest: tiny dims, no artifact files needed (the fake
/// engine never loads HLO).
fn tiny_manifest() -> Manifest {
    Manifest {
        model: ModelConfig {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            d_head: 16,
            d_ffn: 64,
            rope_base: 10000.0,
            buf_slots: 16,
            prefill_len: 32,
            obs_window: 8,
            group_size: 16,
        },
        quant_caps: vec![128],
        fp32_caps: vec![256],
        batch_widths: vec![],
        prefill_chunk_lens: vec![],
        micro_c: 128,
        golden_attn_c: 128,
        artifacts_dir: ".".into(),
        weights: vec![],
        seed: 0,
    }
}

/// Deterministic engine stand-in: outputs are a pure function of the
/// decode-step inputs (token, position) and of the prompt for prefill,
/// so any two runs that feed it the same per-session inputs — batched
/// or not — see identical outputs.
struct FakeEngine {
    m: ModelConfig,
}

impl FakeEngine {
    fn new(m: ModelConfig) -> FakeEngine {
        FakeEngine { m }
    }
}

impl DecodeEngine for FakeEngine {
    fn model(&self) -> &ModelConfig {
        &self.m
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let seed = tokens
            .iter()
            .fold(0xABCDu64, |h, &t| h.wrapping_mul(31).wrapping_add(t as u64));
        let mut rng = Rng::new(seed);
        let m = &self.m;
        let kvd = m.n_kv_heads * m.d_head;
        let mut logits = vec![0f32; m.vocab];
        let mut k = vec![0f32; m.n_layers * m.prefill_len * kvd];
        let mut v = vec![0f32; m.n_layers * m.prefill_len * kvd];
        rng.fill_normal_f32(&mut logits, 0.0, 1.0);
        rng.fill_normal_f32(&mut k, 0.0, 1.0);
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        Ok(PrefillOut { logits, k, v, obs: vec![0.0; m.n_layers * m.prefill_len] })
    }

    fn decode(&self, token: i32, pos: i32, _buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
        let capacity = match view {
            CacheView::Quant(q) => q.capacity,
            CacheView::Fp32 { capacity, .. } => *capacity,
        };
        let m = &self.m;
        let span = capacity + m.buf_slots;
        let kvd = m.n_kv_heads * m.d_head;
        let seed = ((token as u32 as u64) << 32) | pos as u32 as u64;
        let mut rng = Rng::new(seed ^ 0x5EED_CAFE);
        let mut logits = vec![0f32; m.vocab];
        let mut new_k = vec![0f32; m.n_layers * kvd];
        let mut new_v = vec![0f32; m.n_layers * kvd];
        let mut probs = vec![0f32; m.n_layers * m.n_heads * span];
        rng.fill_normal_f32(&mut logits, 0.0, 1.0);
        rng.fill_normal_f32(&mut new_k, 0.0, 1.0);
        rng.fill_normal_f32(&mut new_v, 0.0, 1.0);
        rng.fill_normal_f32(&mut probs, 0.5, 0.2);
        for p in probs.iter_mut() {
            *p = p.abs();
        }
        Ok(DecodeOut { logits, new_k, new_v, probs })
    }
}

fn mode_for(tag: usize) -> CompressionMode {
    match tag {
        0 => CompressionMode::thinkv_default(),
        1 => CompressionMode::parse("kivi2").expect("kivi2 parses"),
        _ => CompressionMode::FullKv,
    }
}

fn cfg_for(tag: usize, max_new: usize, temperature: f64) -> ServeConfig {
    ServeConfig {
        mode: mode_for(tag),
        budget: 64,
        max_new_tokens: max_new,
        workers: 1,
        temperature,
        ..ServeConfig::default()
    }
}

/// Reference: each session advanced alone, one `Session::step` at a
/// time (no scheduler, no batching).
fn run_sequential(
    engine: &dyn DecodeEngine,
    man: &Manifest,
    cfgs: &[ServeConfig],
    prompts: &[Vec<i32>],
) -> Vec<Vec<i32>> {
    let mut streams = Vec::new();
    for (i, (cfg, prompt)) in cfgs.iter().zip(prompts).enumerate() {
        let mut s = Session::new(i as u64 + 1, prompt.clone(), cfg, man).expect("session");
        loop {
            match s.step(engine).expect("sequential step") {
                StepOutcome::Running => {}
                StepOutcome::Finished => break,
                StepOutcome::NeedMemory => panic!("unbounded pool cannot starve"),
            }
        }
        streams.push(s.tokens.clone());
    }
    streams
}

/// Batched: the production path — scheduler batch formation plus the
/// worker chunk body (`advance_batch`, one fused call per step). `pick`
/// supplies each round's (batch cap, chunk length).
fn run_batched_with(
    engine: &dyn DecodeEngine,
    man: &Manifest,
    cfgs: &[ServeConfig],
    prompts: &[Vec<i32>],
    mut pick: impl FnMut() -> (usize, usize),
) -> (Vec<Vec<i32>>, thinkv::metrics::SchedSnapshot) {
    let pool = Arc::new(BlockPool::new(u64::MAX / 2));
    let sched = Scheduler::new(Arc::clone(&pool));
    let (tx, rx) = mpsc::channel();
    for (i, (cfg, prompt)) in cfgs.iter().zip(prompts).enumerate() {
        let s = Session::with_pool(
            i as u64 + 1,
            prompt.clone(),
            cfg,
            man,
            Some(Arc::clone(&pool)),
        )
        .expect("session");
        sched.submit(s, tx.clone());
    }
    drop(tx);
    while sched.inflight() > 0 {
        let (max, chunk) = pick();
        let batch = sched.next_batch(max).expect("runnable batch while inflight");
        advance_batch(&sched, engine, chunk, batch);
    }
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    let snap = sched.snapshot();
    for r in &results {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
    }
    (results.into_iter().map(|r| r.tokens).collect(), snap)
}

/// [`run_batched_with`] driven by randomized batch caps / chunk lengths
/// from the property generator (the artifact-free lanes).
fn run_batched(
    engine: &dyn DecodeEngine,
    man: &Manifest,
    cfgs: &[ServeConfig],
    prompts: &[Vec<i32>],
    g: &mut prop::Gen,
) -> (Vec<Vec<i32>>, thinkv::metrics::SchedSnapshot) {
    run_batched_with(engine, man, cfgs, prompts, || (g.usize(1, 6), g.usize(1, 7)))
}

/// Batched decode must be stream-invariant: identical token streams to
/// sequential execution across randomized batch compositions, and the
/// fused-step books must balance.
#[test]
fn batched_decode_streams_match_sequential() {
    prop::check(8, |g| {
        let man = tiny_manifest();
        let engine = FakeEngine::new(man.model.clone());
        let n = g.usize(2, 7);
        let max_new = g.usize(4, 20);
        let temperature = if g.bool() { 0.8 } else { 0.0 };
        let mut rng = Rng::new(g.usize(0, 1 << 30) as u64);
        let cfgs: Vec<ServeConfig> = (0..n)
            .map(|_| cfg_for(rng.below(3), max_new, temperature))
            .collect();
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|_| {
                (0..rng.below(24) + 3)
                    .map(|_| rng.below(man.model.vocab) as i32)
                    .collect()
            })
            .collect();

        let sequential = run_sequential(&engine, &man, &cfgs, &prompts);
        let (batched, snap) = run_batched(&engine, &man, &cfgs, &prompts, g);

        for (i, (seq, bat)) in sequential.iter().zip(&batched).enumerate() {
            if seq != bat {
                return Err(format!(
                    "session {} diverged: sequential {:?} vs batched {:?}",
                    i + 1,
                    seq,
                    bat
                ));
            }
            if seq.len() != max_new {
                return Err(format!("session {} truncated: {} tokens", i + 1, seq.len()));
            }
        }
        // every decode step went through the fused entry point, the
        // histogram accounts for every fused step, and the pool drained
        if snap.fused_steps == 0 {
            return Err("no fused steps recorded".into());
        }
        if snap.fused_sessions < snap.fused_steps {
            return Err("fused_sessions must count at least one session per step".into());
        }
        if snap.batch_hist.iter().sum::<u64>() != snap.fused_steps {
            return Err("batch histogram does not account for every fused step".into());
        }
        if snap.completions != n as u64 || snap.pool_used != 0 {
            return Err(format!(
                "books unbalanced at quiescence: completions {}, pool_used {}",
                snap.completions, snap.pool_used
            ));
        }
        Ok(())
    });
}

/// Sessions of different cache families never share a fused call, yet
/// a mixed workload still completes with identical streams — the
/// compatibility key only affects grouping, never results.
#[test]
fn mixed_family_batches_complete_and_match() {
    prop::check_seeded(7, 1, |g| {
        let man = tiny_manifest();
        let engine = FakeEngine::new(man.model.clone());
        // two sessions of each family, interleaved
        let cfgs: Vec<ServeConfig> = (0..6).map(|i| cfg_for(i % 3, 8, 0.0)).collect();
        let prompts: Vec<Vec<i32>> = (0..6)
            .map(|u| (0..16).map(|i| ((i * 5 + u) % 64) as i32).collect())
            .collect();
        let sequential = run_sequential(&engine, &man, &cfgs, &prompts);
        let (batched, snap) = run_batched(&engine, &man, &cfgs, &prompts, g);
        if sequential != batched {
            return Err("mixed-family streams must match".into());
        }
        if snap.fused_steps == 0 || snap.completions != 6 {
            return Err(format!(
                "fused bookkeeping off: steps {}, completions {}",
                snap.fused_steps, snap.completions
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Artifact-gated lanes: identical bar, real PJRT engine (ISSUE 6).
// ---------------------------------------------------------------------------

fn artifacts_ready() -> bool {
    let dir = default_artifacts_dir();
    std::path::Path::new(&format!("{dir}/model_config.json")).exists()
}

fn real_cfg(mode: CompressionMode, max_new: usize) -> ServeConfig {
    ServeConfig {
        mode,
        budget: 256,
        max_new_tokens: max_new,
        workers: 1,
        temperature: 0.8,
        ..ServeConfig::default()
    }
}

/// Distinct prompts of ragged lengths (all under the compiled prefill
/// length), so per-session positions and memo keys never collide.
fn real_prompts(n: usize, vocab: usize, salt: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(0xA11A5 ^ salt);
    (0..n)
        .map(|i| (0..9 + 5 * i).map(|_| rng.below(vocab) as i32).collect())
        .collect()
}

/// The tentpole acceptance bar, quant family: with the compiled batch
/// widths covering every batch the scheduler forms, a fused step issues
/// **exactly one** PJRT execute (the ragged batch pads into the next
/// compiled width instead of falling back per member), and the token
/// streams stay bit-identical to per-session sequential decode.
#[test]
fn artifact_quant_fused_step_is_one_execute_and_stream_invariant() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::new().expect("engine");
    let man = engine.manifest.clone();
    // 5 is deliberately not a compiled width: the fused call must pad
    // into width 8, not split or fall back
    let n = 5;
    let cfgs: Vec<ServeConfig> = (0..n)
        .map(|_| real_cfg(CompressionMode::thinkv_default(), 6))
        .collect();
    let prompts = real_prompts(n, man.model.vocab, 1);

    let sequential = run_sequential(&engine, &man, &cfgs, &prompts);
    let (batched, snap) = run_batched_with(&engine, &man, &cfgs, &prompts, || (6, 3));

    assert_eq!(sequential, batched, "fused PJRT decode must be stream-invariant");
    assert!(snap.fused_steps > 0, "batched run must fuse");
    assert_eq!(
        snap.pjrt_decode_executes, snap.fused_steps,
        "compiled widths cover every batch: exactly 1 execute per fused step"
    );
    assert_eq!(snap.pjrt_fallback_executes, 0, "no per-member fallback");
    // every whole-prompt prefill either executed or hit the engine memo
    assert_eq!(snap.pjrt_prefill_executes + snap.prefill_memo_hits, n as u64);
}

/// Same bar for the fp32 cache family (FullKV sessions batch through
/// the fp32 batched artifacts, not the quant ones).
#[test]
fn artifact_fp32_fused_step_is_one_execute_and_stream_invariant() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::new().expect("engine");
    let man = engine.manifest.clone();
    let n = 3;
    let cfgs: Vec<ServeConfig> =
        (0..n).map(|_| real_cfg(CompressionMode::FullKv, 5)).collect();
    let prompts = real_prompts(n, man.model.vocab, 2);

    let sequential = run_sequential(&engine, &man, &cfgs, &prompts);
    let (batched, snap) = run_batched_with(&engine, &man, &cfgs, &prompts, || (4, 2));

    assert_eq!(sequential, batched, "fp32 fused decode must be stream-invariant");
    assert!(snap.fused_steps > 0);
    assert_eq!(snap.pjrt_decode_executes, snap.fused_steps);
    assert_eq!(snap.pjrt_fallback_executes, 0);
    assert_eq!(snap.pjrt_prefill_executes + snap.prefill_memo_hits, n as u64);
}

/// A batch wider than the widest compiled width cannot be one execute:
/// the engine must split it greedily into compiled sub-batches (8 + 2
/// for 10 members), every sub-execute landing in the ledger — never
/// silently degrading to per-member fallback, never changing streams.
#[test]
fn artifact_batch_beyond_widest_width_splits_counted() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::new().expect("engine");
    let man = engine.manifest.clone();
    let widest = *man.batch_widths.iter().max().expect("compiled batch widths");
    let n = widest + 2;
    let cfgs: Vec<ServeConfig> = (0..n)
        .map(|_| real_cfg(CompressionMode::thinkv_default(), 5))
        .collect();
    let prompts = real_prompts(n, man.model.vocab, 3);

    let sequential = run_sequential(&engine, &man, &cfgs, &prompts);
    let (batched, snap) = run_batched_with(&engine, &man, &cfgs, &prompts, || (n + 2, 2));

    assert_eq!(sequential, batched, "split fused decode must be stream-invariant");
    assert!(
        snap.pjrt_decode_executes > snap.fused_steps,
        "width {} batches exceed the widest compiled width {widest}: \
         {} executes over {} steps must show the split",
        n,
        snap.pjrt_decode_executes,
        snap.fused_steps
    );
    assert_eq!(snap.pjrt_fallback_executes, 0, "greedy split, not fallback");
}

/// Acceptance (ISSUE 6): shared-prefix members reference **one physical
/// copy** of the prefix — and the aliasing is invisible in the output.
/// A session attached to a resident prefix (block tables pointing into
/// the shared rows, zero payload copies) must produce a token stream
/// bit-identical to the same request decoded with sharing disabled.
#[test]
fn artifact_shared_prefix_alias_is_bit_invariant() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::new().expect("engine");
    let man = engine.manifest.clone();
    let cfg = real_cfg(CompressionMode::thinkv_default(), 6);
    // common-system-prompt workload: a 32-token block-aligned system
    // prefix (4 trie blocks of 8) plus distinct 24-token tails
    let vocab = man.model.vocab;
    let system: Vec<i32> = (0..32).map(|i| ((i * 7) % vocab) as i32).collect();
    let mut rng = Rng::new(0xBEEF);
    let mut tail = || (0..24).map(|_| rng.below(vocab) as i32).collect::<Vec<i32>>();
    let mut pub_prompt = system.clone();
    pub_prompt.extend(tail());
    let mut shr_prompt = system.clone();
    shr_prompt.extend(tail());

    // shared lane: the publisher completes first (publishing its
    // prefill), then the sharer attaches the resident blocks at
    // construction and prefills only its delta
    let pool = Arc::new(BlockPool::new(u64::MAX / 2));
    let idx = PrefixIndex::new(Arc::clone(&pool), 8);
    let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
    let (tx, rx) = mpsc::channel();
    let drain = |sched: &Scheduler| {
        while sched.inflight() > 0 {
            let batch = sched.next_batch(4).expect("runnable batch while inflight");
            advance_batch(sched, &engine, 4, batch);
        }
    };
    let publisher = Session::with_parts(
        1,
        pub_prompt,
        &cfg,
        &man,
        Some(Arc::clone(&pool)),
        Some(Arc::clone(&idx)),
    )
    .expect("publisher session");
    sched.submit(publisher, tx.clone());
    drain(&sched);
    let sharer = Session::with_parts(
        2,
        shr_prompt.clone(),
        &cfg,
        &man,
        Some(Arc::clone(&pool)),
        Some(Arc::clone(&idx)),
    )
    .expect("sharer session");
    sched.submit(sharer, tx.clone());
    drain(&sched);
    drop(tx);
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2);
    for r in &results {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
    }
    let snap = sched.snapshot();
    assert!(snap.prefix_hits >= 1, "sharer must hit the resident prefix");
    assert!(
        snap.prefix_alias_hits >= 1,
        "attachment must alias the shared rows, not memcpy them"
    );
    assert!(snap.prefix_alias_bytes > 0, "aliased bytes must be accounted");

    // unshared control: same request id, prompt, and config — no index,
    // so the whole prompt is prefilled into private rows
    let pool2 = Arc::new(BlockPool::new(u64::MAX / 2));
    let sched2 = Scheduler::new(Arc::clone(&pool2));
    let (tx2, rx2) = mpsc::channel();
    let solo = Session::with_pool(2, shr_prompt, &cfg, &man, Some(Arc::clone(&pool2)))
        .expect("solo session");
    sched2.submit(solo, tx2);
    drain(&sched2);
    let solo_res = rx2.iter().next().expect("solo result");
    assert!(solo_res.error.is_none(), "solo failed: {:?}", solo_res.error);
    assert_eq!(
        results[1].tokens, solo_res.tokens,
        "aliased shared-prefix decode must be bit-identical to unshared decode"
    );
}
