//! `make loom` — exhaustive interleaving checks for the scheduler's
//! three hairiest lock dances, driven by the in-crate explorer
//! (`thinkv::syncx::model`, the container carries no external loom
//! crate).
//!
//! Each model abstracts one real dance into cooperative state-machine
//! threads whose atomic actions are the real code's critical sections
//! (one action = one region executed under the scheduler lock, or one
//! lock-free step between regions — exactly the granularity at which
//! the real threads interleave). The invariants are the ones the
//! production comments promise:
//!
//! * **Model A — `preempt_unlocked`**: the snapshot copy runs outside
//!   the scheduler lock with the victim detached; `pending_preempts`
//!   is the only thing standing between a starving session and a
//!   spurious "KV demand exceeds the pool" failure. Checked: no
//!   spurious failure, the victim requeues exactly once, pool bytes
//!   conserve across every interleaving.
//! * **Model B — `rebind_charge` vs `reclaim_unreferenced`**: a
//!   migrating session re-attaches to the fleet prefix by bumping the
//!   new handle's ref **before** releasing the old one, so a
//!   concurrent reclaim pass never observes a transient zero refcount
//!   on a still-referenced prefix. The seeded release-before-bump
//!   variant must be caught.
//! * **Model C — `take_for_migration` / `migration_release`**: the
//!   migrated session lands on exactly one replica, `pending_preempts`
//!   returns to zero once the source is released, and bytes conserve
//!   across both device pools and the staging swap pool.

use thinkv::syncx::model::{explore, Step, Thread};

/// Device bytes the modeled victim / migrant holds.
const BYTES: u64 = 4;
/// Bytes the modeled starving / admitting session needs.
const NEED: u64 = 3;
/// Device pool capacity for models A and C.
const POOL: u64 = 4;

// ---------------------------------------------------------------------
// Model A: preempt_unlocked vs a starving session
// ---------------------------------------------------------------------

/// Shared variables of the preemption dance. `pool_free + victim_held +
/// starver_held` is the conservation sum ([`POOL`]).
#[derive(Debug, Clone, PartialEq)]
struct Preempt {
    pool_free: u64,
    victim_held: u64,
    starver_held: u64,
    /// `Inner::pending_preempts`: detached victims whose copy still
    /// runs outside the lock.
    pending: usize,
    /// Starver parked in `stalled` (waiting for the victim's bytes).
    stalled: bool,
    /// `unstall()` ran and woke the starver.
    woken: bool,
    /// Starver took the spurious-failure branch.
    failed: bool,
    /// Times the victim was requeued to the waiting line.
    requeued: u32,
    /// Starver's growth reservation succeeded.
    grew: bool,
    /// Model the guard (`true` = production code, `false` = seeded bug
    /// that ignores `pending_preempts` in the alone-check).
    guarded: bool,
}

impl Preempt {
    fn new(guarded: bool) -> Preempt {
        Preempt {
            pool_free: POOL - BYTES,
            victim_held: BYTES,
            starver_held: 0,
            pending: 0,
            stalled: false,
            woken: false,
            failed: false,
            requeued: 0,
            grew: false,
            guarded,
        }
    }
}

/// Preemptor critical section 1 (`yield_back` honoring a mark /
/// `cannot_grow` youngest-victim branch): detach the victim under the
/// lock and raise `pending_preempts`.
fn p_detach(s: &mut Preempt) -> Step {
    s.pending += 1;
    Step::Ran
}

/// Preemptor step 2 (**outside** the lock): the snapshot copy finishes
/// and the victim's device bytes return to the pool.
fn p_copy_release(s: &mut Preempt) -> Step {
    s.pool_free += s.victim_held;
    s.victim_held = 0;
    Step::Ran
}

/// Preemptor critical section 3 (`preempt_unlocked` tail): drop
/// `pending_preempts`, requeue the victim, unstall parked sessions.
fn p_requeue(s: &mut Preempt) -> Step {
    s.pending -= 1;
    s.requeued += 1;
    if s.stalled {
        s.stalled = false;
        s.woken = true;
    }
    Step::Ran
}

/// Starver critical section (`cannot_grow` finding no admitted peers):
/// grow if the bytes are back; otherwise it *looks* alone — fail
/// outright unless the `pending_preempts` guard says a detached
/// victim's bytes are still in flight, in which case park in `stalled`.
/// While the victim is still admitted (neither detached nor requeued)
/// the real code would preempt it instead — that branch is outside this
/// model, so the action blocks until the detach happened.
fn s_grow_or_park(s: &mut Preempt) -> Step {
    if s.pool_free >= NEED {
        s.pool_free -= NEED;
        s.starver_held += NEED;
        s.grew = true;
        return Step::Ran;
    }
    if s.pending == 0 && s.requeued == 0 {
        return Step::Blocked; // victim still admitted: not the alone path
    }
    if s.guarded && s.pending > 0 {
        s.stalled = true;
    } else {
        s.failed = true;
    }
    Step::Ran
}

/// Starver retry after an unstall wake-up (the re-pulled step).
fn s_retry(s: &mut Preempt) -> Step {
    if s.grew || s.failed {
        return Step::Ran; // already resolved, nothing to retry
    }
    if !s.woken {
        return Step::Blocked; // parked: only `unstall` can wake us
    }
    if s.pool_free < NEED {
        return Step::Blocked;
    }
    s.pool_free -= NEED;
    s.starver_held += NEED;
    s.grew = true;
    Step::Ran
}

fn preempt_threads() -> Vec<Thread<Preempt>> {
    vec![
        Thread::new("preemptor", vec![p_detach, p_copy_release, p_requeue]),
        Thread::new("starver", vec![s_grow_or_park, s_retry]),
    ]
}

fn preempt_invariant(s: &Preempt) {
    assert_eq!(
        s.pool_free + s.victim_held + s.starver_held,
        POOL,
        "pool bytes not conserved: {s:?}"
    );
    assert!(s.requeued <= 1, "victim requeued more than once: {s:?}");
    assert!(
        !s.failed,
        "spurious failure: starver failed while a preemption was in flight: {s:?}"
    );
}

/// Every interleaving of the guarded (production) dance keeps the
/// invariants: the starver either grows immediately or parks and is
/// woken, never failing while the victim's bytes are in flight.
#[test]
fn preempt_dance_never_spuriously_fails() {
    let n = explore(&Preempt::new(true), &preempt_threads(), &preempt_invariant);
    assert!(n >= 2, "expected multiple schedules, got {n}");
    // terminal sanity via a second pass: once both threads finish, the
    // starver holds its bytes and nothing is pending
    explore(&Preempt::new(true), &preempt_threads(), &|s| {
        if s.requeued == 1 && s.grew {
            assert_eq!(s.pending, 0, "pending_preempts leaked: {s:?}");
        }
    });
}

/// Seeded violation: with the `pending_preempts` guard removed, some
/// schedule runs the starver's alone-check while the victim's copy is
/// mid-flight — the explorer must reach the spurious failure.
#[test]
fn preempt_dance_without_pending_guard_is_caught() {
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        explore(&Preempt::new(false), &preempt_threads(), &preempt_invariant)
    }));
    let msg = format!("{:?}", err.expect_err("unguarded dance must spuriously fail"));
    assert!(msg.contains("spurious failure"), "got: {msg}");
}

// ---------------------------------------------------------------------
// Model B: rebind_charge vs reclaim_unreferenced
// ---------------------------------------------------------------------

/// Shared variables of the rebind/reclaim dance on one shared prefix.
#[derive(Debug, Clone, PartialEq)]
struct Rebind {
    /// `SharedPrefix` refcount (the migrating session holds one ref
    /// through its old attachment handle at the start).
    refs: u32,
    /// Residency payload still resident (its lease is live).
    resident: bool,
    /// Pool bytes the residency lease holds.
    pool_used: u64,
    /// The reclaim pass freed the entry.
    reclaimed: bool,
    /// The rebind completed (new handle live, old released).
    rebound: bool,
}

impl Rebind {
    fn new() -> Rebind {
        Rebind { refs: 1, resident: true, pool_used: BYTES, reclaimed: false, rebound: false }
    }
}

/// Rebind step 1 — production order (`AttachedPrefix::rebind_charge`):
/// the **new** handle's reference is taken first.
fn rb_bump_new(s: &mut Rebind) -> Step {
    s.refs += 1;
    Step::Ran
}

/// Rebind step 2: the old handle drops its reference.
fn rb_release_old(s: &mut Rebind) -> Step {
    s.refs -= 1;
    s.rebound = true;
    Step::Ran
}

/// One `reclaim_unreferenced` pass under the trie root lock: frees the
/// entry iff nobody references it (a no-op pass otherwise — the real
/// scan just moves on).
fn rc_scan(s: &mut Rebind) -> Step {
    if s.refs == 0 && s.resident {
        s.resident = false;
        s.pool_used -= BYTES;
        s.reclaimed = true;
    }
    Step::Ran
}

fn rebind_invariant(s: &Rebind) {
    assert!(
        !(s.reclaimed && s.refs > 0),
        "reclaim freed a prefix a live attachment still references: {s:?}"
    );
    assert!(
        !s.rebound || s.resident,
        "rebound attachment points at a reclaimed payload: {s:?}"
    );
    let expect = if s.resident { BYTES } else { 0 };
    assert_eq!(s.pool_used, expect, "residency bytes drifted: {s:?}");
}

/// Production order (bump-before-release): no interleaving lets the
/// reclaim pass observe a transient zero refcount.
#[test]
fn rebind_bump_before_release_survives_concurrent_reclaim() {
    let threads = vec![
        Thread::new("rebind", vec![rb_bump_new, rb_release_old]),
        Thread::new("reclaimer", vec![rc_scan]),
    ];
    let n = explore(&Rebind::new(), &threads, &rebind_invariant);
    assert!(n >= 3, "expected one schedule per scan position, got {n}");
}

/// Seeded violation: releasing the old ref before bumping the new one
/// opens a zero-ref window; a reclaim pass landing inside it frees the
/// still-referenced prefix, and the invariant must catch it.
#[test]
fn rebind_release_before_bump_is_caught() {
    let threads = vec![
        // buggy order: old ref dropped first
        Thread::new("rebind-buggy", vec![rb_release_old, rb_bump_new]),
        Thread::new("reclaimer", vec![rc_scan]),
    ];
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        explore(&Rebind::new(), &threads, &rebind_invariant)
    }));
    let msg = format!("{:?}", err.expect_err("zero-ref window must be reachable"));
    assert!(
        msg.contains("reclaimed payload") || msg.contains("still references"),
        "got: {msg}"
    );
}

// ---------------------------------------------------------------------
// Model C: take_for_migration / migration_release vs a source admitter
// ---------------------------------------------------------------------

/// Shared variables of the migration dance: one session moving from the
/// source replica to the destination while the source keeps admitting.
#[derive(Debug, Clone, PartialEq)]
struct Migrate {
    src_free: u64,
    /// Bytes the migrant holds in the source pool.
    migrant_held: u64,
    /// Bytes the source's own waiting session holds after admission.
    admitted_held: u64,
    /// Snapshot bytes staged in the swap pool.
    swap_used: u64,
    /// Source `pending_preempts` (raised by `take_for_migration`).
    pending: usize,
    /// Where the migrant currently is: 1 = source runnable queue,
    /// 2 = detached (in flight), 3 = destination waiting line.
    migrant_at: u8,
    /// `migration_release` ran on the source.
    released: bool,
    /// The source admitter got its session in.
    admitted: bool,
}

impl Migrate {
    fn new() -> Migrate {
        Migrate {
            src_free: POOL - BYTES,
            migrant_held: BYTES,
            admitted_held: 0,
            swap_used: 0,
            pending: 0,
            migrant_at: 1,
            released: false,
            admitted: false,
        }
    }
}

/// Migrator critical section 1 (`take_for_migration`): detach the
/// migrant from the source's queues; it keeps its pool bytes.
fn m_take(s: &mut Migrate) -> Step {
    s.migrant_at = 2;
    s.pending += 1;
    Step::Ran
}

/// Migrator step 2 (outside the source lock): suspend to the staging
/// swap pool — device bytes come home, snapshot bytes go host-side.
fn m_suspend(s: &mut Migrate) -> Step {
    s.swap_used += BYTES;
    s.src_free += s.migrant_held;
    s.migrant_held = 0;
    Step::Ran
}

/// Migrator step 3 (`rebind_for_migration` + destination `resubmit`):
/// the migrant joins the destination's waiting line; the snapshot
/// drains from swap when it restores there (modeled at resubmit — the
/// restore path settles the stage lease).
fn m_resubmit(s: &mut Migrate) -> Step {
    s.migrant_at = 3;
    s.swap_used -= BYTES;
    Step::Ran
}

/// Migrator critical section 4 (`migration_release` on the source):
/// drop `pending_preempts` so freed bytes reach waiters.
fn m_release(s: &mut Migrate) -> Step {
    s.pending -= 1;
    s.released = true;
    Step::Ran
}

/// One source `try_admit` pass: admit the waiting session iff its
/// reserve fits right now (no-op otherwise, like a real failed pass).
fn m_admit(s: &mut Migrate) -> Step {
    if !s.admitted && s.src_free >= NEED {
        s.src_free -= NEED;
        s.admitted_held += NEED;
        s.admitted = true;
    }
    Step::Ran
}

fn migrate_invariant(s: &Migrate) {
    assert_eq!(
        s.src_free + s.migrant_held + s.admitted_held,
        POOL,
        "source pool bytes not conserved: {s:?}"
    );
    assert!(s.swap_used <= BYTES, "swap pool over-staged: {s:?}");
    // the migrant exists in exactly one place at all times
    assert!(matches!(s.migrant_at, 1..=3), "migrant lost: {s:?}");
    assert!(
        !(s.migrant_held > 0 && s.migrant_at == 3),
        "migrant resubmitted while still holding source bytes: {s:?}"
    );
    if s.released {
        assert_eq!(s.pending, 0, "pending_preempts leaked past release: {s:?}");
        assert_eq!(s.migrant_at, 3, "released before the migrant landed: {s:?}");
    }
}

/// Every interleaving of the migration dance with a concurrent source
/// admitter conserves bytes in both pools, lands the migrant exactly
/// once, and returns `pending_preempts` to zero.
#[test]
fn migration_dance_is_exactly_once_and_conserving() {
    let threads = vec![
        Thread::new("migrator", vec![m_take, m_suspend, m_resubmit, m_release]),
        Thread::new("src-admitter", vec![m_admit]),
    ];
    let n = explore(&Migrate::new(), &threads, &migrate_invariant);
    assert!(n >= 5, "expected one schedule per admit position, got {n}");
    // terminal check: whatever the admit position, the final state has
    // the migrant at the destination and zero staged swap bytes
    explore(&Migrate::new(), &threads, &|s| {
        if s.released {
            assert_eq!((s.migrant_at, s.swap_used), (3, 0), "bad terminal: {s:?}");
        }
    });
}

/// The admitter can only squeeze in once the migrant's bytes are home:
/// schedules where the admit pass runs before `m_suspend` are no-ops
/// (NEED > src_free), proving migration never double-frees bytes early.
#[test]
fn admission_cannot_use_bytes_before_the_snapshot_copy_returns_them() {
    // thread order variant: admitter runs its single pass first in some
    // schedules; it must only succeed when src_free >= NEED, which is
    // impossible while the migrant still holds BYTES of POOL
    let threads = vec![
        Thread::new("src-admitter", vec![m_admit]),
        Thread::new("migrator", vec![m_take, m_suspend, m_resubmit, m_release]),
    ];
    explore(&Migrate::new(), &threads, &|s| {
        if s.admitted && s.migrant_held > 0 {
            panic!("admitter used bytes the migrant still holds: {s:?}");
        }
        migrate_invariant(s);
    });
}
