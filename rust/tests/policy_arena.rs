//! Policy-arena conformance suite: the one battery every registered
//! eviction policy must pass ([`PolicyKind::ALL`] is the single source
//! of truth — adding a policy to the registry enrolls it here with no
//! further wiring).
//!
//! The battery covers the ISSUE 8 tentpole end to end:
//! * **live-vs-sim differential** — randomized decode/evict histories
//!   driven through the live `Fp32Backend` with the retention audit log
//!   enabled must replay through `sim::oracle::replay_divergence`'s
//!   freshly built twin with divergence exactly 0;
//! * **clone fidelity** — `box_clone` mid-history must capture all
//!   policy state (the suspend-to-host snapshot path), keeping clone
//!   and original in decision lockstep forever after;
//! * **shared-prefix guard** — a policy proposing positions inside a
//!   read-only shared region under a *denied* copy-on-write must be
//!   filtered, never corrupt the region, and still make eviction
//!   progress on unguarded positions;
//! * **budget + sink invariants** — final live set within budget,
//!   proposals drawn from the live set without duplicates, never
//!   over-evicting past the survivor target, sink positions immortal
//!   for sink-carrying policies.

use std::sync::Arc;

use thinkv::baselines::{PolicyKind, RetentionEvent};
use thinkv::coordinator::{CompressionMode, ServeConfig, Session, StepOutcome};
use thinkv::kvcache::{BlockPool, Fp32Backend, Fp32Cache, KvBackend, PrefixIndex};
use thinkv::metrics::Breakdown;
use thinkv::model::ModelConfig;
use thinkv::runtime::{DecodeOut, PrefillOut};
use thinkv::sim::replay_divergence;
use thinkv::testkit::{drive_arena, tiny_manifest, CausalEngine};
use thinkv::util::prop;
use thinkv::util::rng::Rng;

/// Sink depth shared by the sink-carrying registry entries
/// (StreamingLLM / Crystal-KV / SkipKV all protect the first 4).
const SINKS: usize = 4;

fn sink_carrying(kind: PolicyKind) -> bool {
    matches!(kind, PolicyKind::StreamingLlm | PolicyKind::CrystalKv | PolicyKind::SkipKv)
}

/// Tentpole battery, part 1: the differential conformance property.
/// Every policy's recorded history — observations, keep/skip verdicts,
/// eviction selections — must replay bit-exactly through the sim twin,
/// and the audit log must reconcile with the backend's counters.
#[test]
fn every_policy_replays_exactly_through_the_sim_twin() {
    prop::check(5, |g| {
        let budget = *g.pick(&[20usize, 28, 40]);
        let steps = g.usize(12, 48);
        let seed = g.usize(0, 1 << 30) as u64;
        for kind in PolicyKind::ALL {
            let name = kind.name();
            let run = drive_arena(kind, budget, steps, seed);
            if run.trace.events.is_empty() {
                return Err(format!("{name}: empty audit log"));
            }
            let d = replay_divergence(&run.trace);
            if d.divergence != 0.0 || d.mismatches != 0 {
                return Err(format!(
                    "{name}: live/sim divergence {} ({} mismatches, first at {:?})",
                    d.divergence, d.mismatches, d.first_mismatch
                ));
            }

            // the audit log reconciles with the retention counters
            let observes = run
                .trace
                .events
                .iter()
                .filter(|e| matches!(e, RetentionEvent::Observe { .. }))
                .count();
            let keeps = run
                .trace
                .events
                .iter()
                .filter(|e| matches!(e, RetentionEvent::Keep { .. }))
                .count();
            let skips = run
                .trace
                .events
                .iter()
                .filter(|e| matches!(e, RetentionEvent::Skip { .. }))
                .count();
            if observes != steps || keeps + skips != steps {
                return Err(format!(
                    "{name}: {observes} observes, {keeps}+{skips} verdicts, want {steps}"
                ));
            }
            if run.counters.skipped != skips as u64 {
                return Err(format!(
                    "{name}: skipped counter {} != {} skip events",
                    run.counters.skipped, skips
                ));
            }
            let proposed: u64 = run
                .trace
                .events
                .iter()
                .filter_map(|e| match e {
                    RetentionEvent::Evict { evicted, .. } => Some(evicted.len() as u64),
                    _ => None,
                })
                .sum();
            if run.counters.evicted != proposed {
                return Err(format!(
                    "{name}: evicted counter {} != {} proposed (unshared run: no filtering)",
                    run.counters.evicted, proposed
                ));
            }

            // eviction-contract invariants on every recorded selection
            for ev in &run.trace.events {
                let RetentionEvent::Evict { live, target, evicted } = ev else {
                    continue;
                };
                let set: std::collections::BTreeSet<_> = evicted.iter().collect();
                if set.len() != evicted.len() {
                    return Err(format!("{name}: duplicate eviction proposals"));
                }
                if evicted.iter().any(|p| !live.contains(p)) {
                    return Err(format!("{name}: proposed a position outside the live set"));
                }
                if live.len() - evicted.len() < *target {
                    return Err(format!(
                        "{name}: over-evicted below target {target}: {} of {}",
                        evicted.len(),
                        live.len()
                    ));
                }
                if sink_carrying(kind) && evicted.iter().any(|&p| p < SINKS) {
                    return Err(format!("{name}: evicted a sink position"));
                }
            }

            // budget invariant on the final state (the ring buffer may
            // transiently carry tokens past the budget mid-flush, but
            // the settled slab never exceeds it)
            if kind == PolicyKind::FullKv {
                if run.counters.evicted != 0 || run.counters.skipped != 0 {
                    return Err("FullKV: must never evict or skip".into());
                }
            } else if run.live.len() > budget {
                return Err(format!(
                    "{name}: final live set {} exceeds budget {budget}",
                    run.live.len()
                ));
            }
            if sink_carrying(kind) && (0..SINKS).any(|p| !run.live.contains(&p)) {
                return Err(format!("{name}: a sink position left the live set"));
            }
            if run.counters.retained_bytes == 0 {
                return Err(format!("{name}: retained_bytes must reflect the live cache"));
            }
        }
        Ok(())
    });
}

/// Tentpole battery, part 2: `box_clone` must capture every piece of
/// policy state mid-history — clone and original make identical
/// skip/evict decisions immediately and stay in lockstep as further
/// identical observations arrive (this is what suspend-to-host leans
/// on when it snapshots the policy).
#[test]
fn every_policy_clone_stays_in_decision_lockstep() {
    prop::check(6, |g| {
        let live: Vec<usize> = (0..g.usize(24, 60)).collect();
        let target = g.usize(SINKS + 1, live.len());
        let seed = g.usize(0, 1 << 30) as u64;
        for kind in PolicyKind::ALL {
            let name = kind.name();
            let mut a = kind.build(24);
            let mut rng = Rng::new(seed ^ 0xC10E);
            let mut row = |step: usize| {
                let attn: Vec<(usize, f32)> =
                    live.iter().map(|&p| (p, rng.f32().abs())).collect();
                thinkv::baselines::PosAttn { step, attn }
            };
            for step in 0..8 {
                a.observe(&row(step));
            }
            let mut b = a.box_clone();
            for step in 8..14 {
                let r = row(step);
                a.observe(&r);
                b.observe(&r);
                let pos = live.len() + step;
                if a.skip_kv(pos) != b.skip_kv(pos) {
                    return Err(format!("{name}: clone diverged on skip_kv({pos})"));
                }
                let ea = a.select_evictions(&live, target);
                let eb = b.select_evictions(&live, target);
                if ea != eb {
                    return Err(format!(
                        "{name}: clone diverged on select_evictions: {ea:?} vs {eb:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

fn fake_prefill(rng: &mut Rng, m: &ModelConfig) -> PrefillOut {
    let n = m.n_layers * m.prefill_len * m.n_kv_heads * m.d_head;
    let mut k = vec![0f32; n];
    let mut v = vec![0f32; n];
    rng.fill_normal_f32(&mut k, 0.0, 1.0);
    rng.fill_normal_f32(&mut v, 0.0, 1.0);
    PrefillOut { logits: vec![0.0; m.vocab], k, v, obs: vec![0.0; m.n_layers * m.prefill_len] }
}

fn fake_decode(rng: &mut Rng, m: &ModelConfig, span: usize) -> DecodeOut {
    let kvd = m.n_kv_heads * m.d_head;
    let mut new_k = vec![0f32; m.n_layers * kvd];
    let mut new_v = vec![0f32; m.n_layers * kvd];
    rng.fill_normal_f32(&mut new_k, 0.0, 1.0);
    rng.fill_normal_f32(&mut new_v, 0.0, 1.0);
    let mut probs = vec![0f32; m.n_layers * m.n_heads * span];
    rng.fill_normal_f32(&mut probs, 0.5, 0.2);
    for p in probs.iter_mut() {
        *p = p.abs();
    }
    DecodeOut { logits: vec![0.0; m.vocab], new_k, new_v, probs }
}

/// Tentpole battery, part 3 + satellite regression: a policy proposing
/// positions inside a **read-only shared prefix** whose copy-on-write
/// is denied (pool exhausted) must have those proposals filtered by the
/// shared guarded-region helper — the region survives untouched (the
/// `evict_slots` debug sentinel would abort this debug-build test on
/// any corruption), eviction still progresses on private positions,
/// and the recorded history still replays with zero divergence.
#[test]
fn denied_cow_keeps_shared_prefix_read_only_without_starving_eviction() {
    let man = tiny_manifest();
    let m = &man.model;
    let kvd = m.n_kv_heads * m.d_head;
    let capacity = man.fp32_caps[0];
    let mk = |kind: PolicyKind, budget: usize| {
        Fp32Backend::new(
            Fp32Cache::new(m.n_layers, capacity, kvd, m.buf_slots),
            kind.build(budget),
            kind.budget_for(budget),
            kind.gather(),
            capacity,
        )
    };
    let mut rng = Rng::new(0x6A2D);
    let pf = fake_prefill(&mut rng, m);

    // publisher: prefill, export the first 16 positions, publish them
    let mut publisher = mk(PolicyKind::FullKv, 1 << 20);
    publisher.write_prefill(&pf, m.prefill_len);
    let n = 16usize;
    let payload = publisher.export_prefix(n).expect("pristine prefix exports");
    let geom = publisher.prefix_geom();
    let tokens: Vec<i32> = (0..n as i32).collect();
    let pool = Arc::new(BlockPool::new(1 << 20));
    let idx = PrefixIndex::new(Arc::clone(&pool), 8);
    let att_pub = idx.publish(&tokens, geom, payload).expect("publish fits the pool");
    drop(att_pub);

    // the sharer attaches the read-only region, then the pool is
    // drained so its copy-on-write can never be granted
    // quiescent point: the only pool charge is the published prefix's
    // residency lease, so the byte ledger must balance exactly
    pool.assert_conserved();
    let att = idx.attach(&tokens, geom, m.prefill_len).expect("prefix attaches");
    let budget = 20usize;
    let mut sharer = mk(PolicyKind::StreamingLlm, budget);
    sharer.enable_trace(PolicyKind::StreamingLlm, budget);
    sharer
        .write_prefill_shared(&pf, m.prefill_len, Arc::clone(&att))
        .expect("shared prefill");
    assert_eq!(sharer.shared_prefix_tokens(), n);
    let free = pool.free();
    assert!(free > 0 && pool.reserve(free), "drain the pool to deny CoW");

    // StreamingLLM proposes the oldest non-sink positions — squarely
    // inside the shared region — on every budget enforcement
    let span = capacity + m.buf_slots;
    let mut bd = Breakdown::default();
    for i in 0..24 {
        let pos = m.prefill_len + i;
        sharer.make_room(pos, &mut bd).expect("make_room under denied CoW");
        let out = fake_decode(&mut rng, m, span);
        sharer.absorb(&out, pos, m, &mut bd).expect("absorb under denied CoW");
    }

    // the guarded region is intact and still marked read-only
    assert_eq!(sharer.shared_prefix_tokens(), n, "shared region survived");
    let live = sharer.live_positions();
    for p in 0..n {
        assert!(live.contains(&p), "shared position {p} was evicted past a denied CoW");
    }
    // eviction made progress on private (>= n) positions regardless
    let r = sharer.retention();
    assert!(r.evicted > 0, "denied CoW must not starve eviction");
    assert!(
        !live.iter().any(|&p| p >= n && p < m.prefill_len),
        "private prefill tail should have been evicted first: {live:?}"
    );
    // the denial path was actually exercised, and no privatization slipped through
    let stats = idx.stats();
    assert!(stats.cow_denied > 0, "CoW denial was never exercised");
    assert_eq!(stats.cow_faults, 0, "no privatization can succeed on a drained pool");
    // the audit log still replays exactly — guard filtering happens
    // outside the recorded policy calls
    let trace = sharer.take_trace().expect("trace enabled");
    let d = replay_divergence(&trace);
    assert_eq!(d.mismatches, 0, "guarded run must replay (first at {:?})", d.first_mismatch);
    // returning the raw drain charge restores conservation: what's left
    // in the pool is exactly the residency lease again
    pool.release(free);
    pool.assert_conserved();
}

/// End-to-end: every registry entry is selectable through
/// `ServeConfig::policy` and serves a full session on the fake engine —
/// deterministically, within budget, with the policy's display name
/// visible on the session.
#[test]
fn every_policy_serves_a_session_end_to_end() {
    let man = tiny_manifest();
    let engine = CausalEngine::new(man.model.clone());
    let budget = 48usize;
    for kind in PolicyKind::ALL {
        let name = kind.name();
        let cfg = ServeConfig {
            mode: CompressionMode::FullKv,
            policy: Some(kind),
            budget,
            max_new_tokens: 24,
            workers: 1,
            temperature: 0.0,
            ..ServeConfig::default()
        };
        let run = |id: u64| {
            let mut s = Session::new(id, vec![3, 1, 4, 1, 5, 9, 2, 6], &cfg, &man)
                .unwrap_or_else(|e| panic!("{name}: session: {e}"));
            assert_eq!(s.policy_label, name, "probe label mismatch");
            loop {
                match s.step(&engine).unwrap_or_else(|e| panic!("{name}: step: {e}")) {
                    StepOutcome::Running => {}
                    StepOutcome::Finished => break,
                    StepOutcome::NeedMemory => panic!("{name}: unbounded pool starved"),
                }
            }
            s
        };
        let a = run(1);
        let b = run(2);
        assert_eq!(a.tokens, b.tokens, "{name}: arena path must be deterministic");
        assert_eq!(a.tokens.len(), 24, "{name}: truncated stream");
        let r = a.retention();
        if kind == PolicyKind::FullKv {
            assert_eq!(r.evicted, 0, "FullKV evicted");
            assert_eq!(r.skipped, 0, "FullKV skipped");
        } else {
            assert!(a.live_tokens() <= budget, "{name}: live {} > budget", a.live_tokens());
        }
        assert!(r.retained_bytes > 0, "{name}: no retained bytes reported");
        if kind == PolicyKind::SkipKv {
            assert!(r.skipped > 0, "SkipKV never exercised its never-materialize axis");
        }
    }
}
