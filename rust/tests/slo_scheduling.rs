//! SLO-aware goodput scheduling under the deterministic trace-driven
//! multi-tenant load harness (ISSUE 7), artifact-free.
//!
//! Three bars:
//!
//! * **Trace determinism (golden)** — the same `(classes, seed,
//!   horizon, vocab)` must generate byte-identical arrival streams and
//!   digests; different seeds must diverge.
//! * **Replay determinism** — replaying one arrival trace through the
//!   scheduler + `advance_batch` on the metered causal fake (whose
//!   logical clock drives the scheduler via `drive_clock`) must produce
//!   bit-identical `SchedSnapshot`s — counters, SLO verdicts, and
//!   latency percentiles included — across independent runs.
//! * **Mid-prefill SLO eviction** — under the goodput policy, a
//!   deadline-hopeless session caught mid-prefill is the preferred
//!   preemption victim, skips the suspend-to-host copy, and its rewound
//!   `PrefillCursor` replays to a token stream bit-identical to the
//!   whole-prompt reference.

use std::sync::{mpsc, Arc};

use thinkv::coordinator::{
    advance_batch, CompressionMode, RequestResult, SchedPolicy, Scheduler, ServeConfig, Session,
    SloTarget, StepOutcome,
};
use thinkv::kvcache::{BlockPool, SwapPool};
use thinkv::metrics::SchedSnapshot;
use thinkv::sim::{ArrivalTrace, TenantClass};
use thinkv::testkit::{share_manifest, CausalEngine, MeteredEngine};

/// The tenant mix every test here replays: an oversubscribing stream of
/// long math sessions plus periodic bursts of tight-TTFT chat sessions.
fn mix() -> Vec<TenantClass> {
    vec![
        TenantClass {
            system_prompt_len: 48,
            tail_len: 16,
            max_new_tokens: 12,
            rate: 0.0,
            burst_every: 30,
            burst_size: 1,
            slo: SloTarget::new(100_000, 0),
            ..TenantClass::math()
        },
        TenantClass {
            system_prompt_len: 16,
            tail_len: 8,
            max_new_tokens: 4,
            rate: 0.0,
            burst_every: 100,
            burst_size: 2,
            slo: SloTarget::new(1_500, 0),
            ..TenantClass::chat()
        },
    ]
}

/// Satellite: golden determinism of the arrival-trace generator, from
/// the public API (the in-crate unit tests cover the internals).
#[test]
fn arrival_trace_same_seed_same_stream() {
    let man = share_manifest();
    let a = ArrivalTrace::generate(&mix(), 77, 900, man.model.vocab);
    let b = ArrivalTrace::generate(&mix(), 77, 900, man.model.vocab);
    assert_eq!(a, b, "same seed must reproduce the stream byte-for-byte");
    assert_eq!(a.digest(), b.digest());
    let c = ArrivalTrace::generate(&mix(), 78, 900, man.model.vocab);
    assert_ne!(a.digest(), c.digest(), "different seeds must diverge");
    // the stream is time-sorted, fully counted, and every event carries
    // its class's SLO target
    assert!(!a.events.is_empty());
    for w in a.events.windows(2) {
        assert!(w[0].at <= w[1].at);
    }
    assert_eq!(a.per_class.iter().sum::<usize>(), a.events.len());
    for e in &a.events {
        assert_eq!(e.slo, mix()[e.class_id].slo);
    }
}

/// Replay `trace` through the production scheduler path on a fresh
/// metered engine: the engine's logical clock is the arrival timeline
/// (idle gaps fast-forwarded with `tick`) and the scheduler clock
/// (`drive_clock`), so TTFT/TPOT verdicts are engine-time exact.
fn replay(trace: &ArrivalTrace, man: &thinkv::model::Manifest, goodput: bool) -> SchedSnapshot {
    let base = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 64,
        max_new_tokens: 12,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let per_adm = Session::new(0, trace.events[0].prompt.clone(), &base, man)
        .expect("probe")
        .admission_bytes();
    let engine = MeteredEngine::new(man.model.clone());
    let pool = Arc::new(BlockPool::new(per_adm * 2 + 4096));
    let sched = Scheduler::new(Arc::clone(&pool));
    sched.set_prefill_chunking(16, 0);
    if goodput {
        sched.set_policy(SchedPolicy::Goodput);
    }
    let (tx, rx) = mpsc::channel();
    let mut next = 0usize;
    let mut results: Vec<RequestResult> = Vec::new();
    loop {
        sched.drive_clock(engine.clock());
        while next < trace.events.len() && trace.events[next].at <= engine.clock() {
            let e = &trace.events[next];
            let cfg = ServeConfig {
                max_new_tokens: e.max_new_tokens,
                slo_class: Some(e.class_name.to_string()),
                slo: e.slo,
                ..base.clone()
            };
            let s = Session::with_pool(e.id, e.prompt.clone(), &cfg, man, Some(Arc::clone(&pool)))
                .expect("arrival session");
            sched.submit(s, tx.clone());
            next += 1;
        }
        results.extend(rx.try_iter());
        if results.len() >= trace.events.len() {
            break;
        }
        if sched.inflight() == 0 {
            if next < trace.events.len() {
                let gap = trace.events[next].at.saturating_sub(engine.clock()).max(1);
                engine.tick(gap);
            }
            continue;
        }
        let batch = sched.next_batch(4).expect("runnable while inflight");
        advance_batch(&sched, &engine, 2, batch);
    }
    assert!(results.iter().all(|r| r.error.is_none()), "every arrival must complete");
    let snap = sched.snapshot();
    sched.shutdown();
    snap
}

/// Two independent same-seed replays must agree bit-for-bit — the whole
/// `SchedSnapshot`, SLO class books and percentiles included — and the
/// goodput accounting must balance.
#[test]
fn same_seed_replay_is_bit_identical() {
    let man = share_manifest();
    let trace = ArrivalTrace::generate(&mix(), 41, 300, man.model.vocab);
    assert!(!trace.events.is_empty());
    for goodput in [false, true] {
        let a = replay(&trace, &man, goodput);
        let b = replay(&trace, &man, goodput);
        assert_eq!(a, b, "replay (goodput={goodput}) must be deterministic");
        assert_eq!(a.sched_policy_goodput, goodput);
        // every arrival here is classed, so each completion is scored
        // exactly once, and the class books fold into the global pair
        assert_eq!(a.completions, trace.events.len() as u64);
        assert_eq!(a.goodput + a.slo_violations, a.completions);
        let folded = a
            .slo_classes
            .iter()
            .fold((0u64, 0u64), |(g, v), c| (g + c.goodput, v + c.violations));
        assert_eq!(folded, (a.goodput, a.slo_violations));
        for c in &a.slo_classes {
            assert!(c.goodput + c.violations > 0, "class {} never scored", c.name);
            assert!(c.ttft_p50 > 0 && c.ttft_p99 >= c.ttft_p50, "percentiles in order");
        }
        assert!(a.pool_peak <= a.pool_capacity, "pool overflow");
    }
}

/// Satellite: mid-prefill SLO eviction. A deadline-hopeless session
/// caught mid-prefill is the goodput victim of choice, skips the
/// swap-out copy even though a swap pool is configured, and — after its
/// cursor rewinds — replays to the exact whole-prompt token stream.
#[test]
fn hopeless_midprefill_eviction_preserves_stream() {
    let man = share_manifest();
    let p_len = man.model.prefill_len; // 96
    let engine = MeteredEngine::new(man.model.clone());
    let pool = Arc::new(BlockPool::new(u64::MAX / 2));
    let swap = Arc::new(SwapPool::new(64 << 20));
    let sched = Scheduler::with_prefix(Arc::clone(&pool), Some(Arc::clone(&swap)), None);
    sched.set_policy(SchedPolicy::Goodput);
    sched.set_prefill_chunking(16, 0);
    sched.drive_clock(1);

    let base = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 64,
        max_new_tokens: 8,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let b_cfg = ServeConfig {
        slo_class: Some("chat".into()),
        slo: SloTarget::new(40, 0),
        ..base.clone()
    };
    let prompt_a: Vec<i32> = (0..p_len).map(|i| (i % 50) as i32).collect();
    let mut prompt_b = prompt_a.clone();
    prompt_b[0] = 49;

    // whole-prompt reference stream for B, no scheduler involved
    let ref_engine = CausalEngine::new(man.model.clone());
    let mut reference = Session::new(9, prompt_b.clone(), &b_cfg, &man).expect("reference");
    while !matches!(reference.step(&ref_engine).expect("step"), StepOutcome::Finished) {}

    let (tx, rx) = mpsc::channel();
    let a = Session::with_pool(1, prompt_a, &base, &man, Some(Arc::clone(&pool))).expect("A");
    sched.submit(a, tx.clone());
    let b = Session::with_pool(2, prompt_b, &b_cfg, &man, Some(Arc::clone(&pool))).expect("B");
    sched.submit(b, tx.clone());
    drop(tx);

    // hold both sessions like two workers would
    let e1 = sched.next().expect("entry");
    let e2 = sched.next().expect("entry");
    let (ea, mut eb) = if e1.session.id == 1 { (e1, e2) } else { (e2, e1) };
    // B advances two chunks, then stalls mid-prefill
    assert!(!eb.session.advance_prefill(&engine, 16).expect("chunk"));
    assert!(!eb.session.advance_prefill(&engine, 16).expect("chunk"));
    let rem = eb.session.prefill_remaining();
    assert!(rem > 0 && rem < p_len, "B must be mid-prefill (remaining {rem})");
    // B's TTFT deadline expires while it is still prefilling
    sched.drive_clock(100);
    assert!(eb.session.slo.hopeless(sched.now_ticks()), "B's deadline must be lost");
    sched.yield_back(eb);

    // A hits a memory wall: the goodput policy must evict hopeless B —
    // younger, deadline lost — and must not waste a swap-out on it
    sched.cannot_grow(ea);
    let snap = sched.snapshot();
    assert!(snap.preemptions >= 1, "hopeless B must be preempted");
    assert_eq!(snap.swap_outs, 0, "hopeless victim must skip the swap copy");

    // drain: B restarts prefill from a rewound cursor and still produces
    // the whole-prompt reference stream
    while sched.inflight() > 0 {
        let batch = sched.next_batch(2).expect("runnable while inflight");
        advance_batch(&sched, &engine, 4, batch);
    }
    let mut results: Vec<RequestResult> = rx.iter().collect();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2);
    for r in &results {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
    }
    assert_eq!(
        results[1].tokens, reference.tokens,
        "evicted mid-prefill session must replay bit-identically"
    );
    let end = sched.snapshot();
    // B was classed and blew its deadline: exactly one violation, no
    // goodput; untargeted A is never scored
    assert_eq!((end.goodput, end.slo_violations), (0, 1));
    assert_eq!(end.slo_classes.len(), 1);
    assert_eq!(end.slo_classes[0].name, "chat");
    assert_eq!(end.slo_classes[0].violations, 1);
    assert_eq!(end.pool_used, 0, "all bytes returned");
    sched.shutdown();
}
