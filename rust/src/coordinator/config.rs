//! Serving configuration: which compression mode a session runs, budgets,
//! sampling, worker counts.

use crate::baselines::eviction::PolicyKind;
use crate::compress::tbq::PrecisionAssignment;
use crate::quant::Precision;

/// Which KV compression runs on the request path.
#[derive(Debug, Clone, PartialEq)]
pub enum CompressionMode {
    /// Uncompressed f32 cache (the FullKV baseline).
    FullKv,
    /// ThinKV: thought-adaptive TBQ + TBE over the CT cache.
    ThinKv {
        assignment: PrecisionAssignment,
        /// Disable TBQ: f32... not representable on the quant path, so the
        /// iso-compression ablation runs FP8 uniform instead (documented).
        no_tbq: bool,
        /// Disable TBE (quantization-only).
        no_tbe: bool,
    },
    /// Eviction baseline over the f32 cache.
    Evict(crate::sim::harness::EvictKind),
    /// Uniform quantization baseline (KIVI) over the CT cache machinery.
    Kivi(Precision),
    /// Progressive quantization baseline (PM-KVQ).
    PmKvq,
}

impl CompressionMode {
    pub fn thinkv_default() -> CompressionMode {
        CompressionMode::ThinKv {
            assignment: PrecisionAssignment::r4e4t2(),
            no_tbq: false,
            no_tbe: false,
        }
    }

    pub fn label(&self) -> String {
        match self {
            CompressionMode::FullKv => "FullKV".into(),
            CompressionMode::ThinKv { no_tbq: true, .. } => "ThinKV w/o TBQ".into(),
            CompressionMode::ThinKv { no_tbe: true, .. } => "ThinKV w/o TBE".into(),
            CompressionMode::ThinKv { assignment, .. } => format!("ThinKV {}", assignment.name()),
            CompressionMode::Evict(k) => k.label().into(),
            CompressionMode::Kivi(p) => format!("KIVI-{}", p.bits() as usize),
            CompressionMode::PmKvq => "PM-KVQ".into(),
        }
    }

    pub fn parse(s: &str) -> Option<CompressionMode> {
        use crate::sim::harness::EvictKind as E;
        Some(match s.to_ascii_lowercase().as_str() {
            "fullkv" | "full" => CompressionMode::FullKv,
            "thinkv" => CompressionMode::thinkv_default(),
            "thinkv-notbq" => CompressionMode::ThinKv {
                assignment: PrecisionAssignment::r4e4t2(),
                no_tbq: true,
                no_tbe: false,
            },
            "thinkv-notbe" => CompressionMode::ThinKv {
                assignment: PrecisionAssignment::r4e4t2(),
                no_tbq: false,
                no_tbe: true,
            },
            "h2o" => CompressionMode::Evict(E::H2O),
            "rkv" | "r-kv" => CompressionMode::Evict(E::Rkv),
            "lazyeviction" | "lazy" => CompressionMode::Evict(E::LazyEviction),
            "raas" => CompressionMode::Evict(E::RaaS),
            "snapkv" => CompressionMode::Evict(E::SnapKv),
            "streaming" | "streamingllm" => CompressionMode::Evict(E::StreamingLlm),
            "kivi2" | "kivi-2" => CompressionMode::Kivi(Precision::Ternary),
            "kivi4" | "kivi-4" => CompressionMode::Kivi(Precision::Nvfp4),
            "pmkvq" | "pm-kvq" => CompressionMode::PmKvq,
            _ => return None,
        })
    }

    /// Registered arena policy this mode maps to, when the mode runs on
    /// the fp32 cache path (`None` for the quantized-cache modes, which
    /// have no pluggable eviction policy).
    pub fn policy_kind(&self) -> Option<PolicyKind> {
        use crate::sim::harness::EvictKind as E;
        Some(match self {
            CompressionMode::FullKv => PolicyKind::FullKv,
            CompressionMode::Evict(k) => match k {
                E::H2O => PolicyKind::H2O,
                E::Rkv | E::RkvOverlapped => PolicyKind::Rkv,
                E::LazyEviction => PolicyKind::LazyEviction,
                E::RaaS => PolicyKind::RaaS,
                E::SnapKv => PolicyKind::SnapKv,
                E::StreamingLlm => PolicyKind::StreamingLlm,
            },
            _ => return None,
        })
    }
}

/// Per-class SLO target a session is scheduled against.
///
/// Both fields are in **scheduler ticks**: wall-clock milliseconds on
/// the live path, deterministic engine-time units when a logical clock
/// drives the scheduler (`Scheduler::drive_clock`, the trace-replay
/// harness). `0` disables that half of the target. TPOT is fixed-point
/// milli-ticks per token so [`crate::metrics::SchedSnapshot`] stays
/// `Eq`-comparable (bit-reproducible runs compare snapshots directly).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SloTarget {
    /// Time-to-first-token ceiling in ticks (0 = no TTFT target).
    pub ttft_ticks: u64,
    /// Time-per-output-token ceiling in milli-ticks (0 = no TPOT target).
    pub tpot_milli_ticks: u64,
}

impl SloTarget {
    /// A target with both halves set.
    pub fn new(ttft_ticks: u64, tpot_milli_ticks: u64) -> SloTarget {
        SloTarget { ttft_ticks, tpot_milli_ticks }
    }

    /// True when neither half is set (the session is unclassed /
    /// best-effort and never counts toward goodput or violations).
    pub fn is_none(&self) -> bool {
        self.ttft_ticks == 0 && self.tpot_milli_ticks == 0
    }
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub mode: CompressionMode,
    /// Explicit arena-policy override (`--policy`): run this registered
    /// [`PolicyKind`] on the fp32 cache path regardless of what `mode`
    /// would map to. `None` = derive the policy from `mode`
    /// ([`CompressionMode::policy_kind`]); quantized-cache modes ignore
    /// the derived value but an explicit override still forces the
    /// session onto the fp32 arena.
    pub policy: Option<PolicyKind>,
    /// KV cache token budget k.
    pub budget: usize,
    /// Compiled cache capacity to use (>= budget; picked from manifest).
    pub capacity: Option<usize>,
    pub max_new_tokens: usize,
    /// Thought refresh interval τ.
    pub refresh: usize,
    /// Retention schedule R.
    pub retention: Vec<usize>,
    /// Decode workers (PJRT engines).
    pub workers: usize,
    /// Steps each worker advances a session before re-queueing
    /// (continuous-batching chunk).
    pub chunk: usize,
    /// Max sessions per cross-session decode batch: a worker pulls up
    /// to this many compatible runnable sessions (same cache family +
    /// compiled capacity) and advances them with one fused engine call
    /// per step. 1 = per-session decode (pre-batching behavior).
    pub max_decode_batch: usize,
    /// Stall-free chunked prefill: split prompt prefill into chunks of
    /// this many tokens, co-scheduled with fused decode steps — each
    /// decode batch carries at most one prefilling session, which
    /// advances one chunk per step (Sarathi-style), so a long-prompt
    /// arrival no longer head-of-line-blocks its batch-mates for a
    /// whole inline prefill. `None` = whole-prompt prefill inside the
    /// first decode step (pre-chunking behavior). Token streams are
    /// bit-identical either way.
    pub prefill_chunk_tokens: Option<usize>,
    /// Sampling temperature (0 = greedy).
    pub temperature: f64,
    pub seed: u64,
    /// Global KV block-pool capacity in bytes (packed accounting) the
    /// memory-aware scheduler admits against. `None` = effectively
    /// unbounded (accounting on, admission never refused).
    pub pool_bytes: Option<u64>,
    /// Host-side swap pool capacity in bytes for suspend-to-host
    /// preemption: preempted sessions whose cache snapshot fits this
    /// pool are swapped out and resume with zero recompute steps;
    /// oversized snapshots (and `None`) fall back to the PR 1
    /// recompute-from-prompt path.
    pub swap_bytes: Option<u64>,
    /// Cross-session prefix sharing: identical block-aligned prompt
    /// prefixes (system prompts, few-shot templates) are stored and
    /// charged to the block pool **once**; later sessions attach the
    /// resident read-only blocks, pay only their delta, and privatize
    /// via copy-on-write on the first divergent write. Off by default —
    /// correctness relies on causal prefill (K/V of a prefix token
    /// depends only on the tokens before it), which holds for the real
    /// engine.
    pub prefix_share: bool,
    /// Tenant-class label sessions built from this config carry (e.g.
    /// `"chat"`, `"math"`, `"coding"`). Classed sessions are scored
    /// against `slo` at completion; `None` = best-effort (never counted
    /// in goodput or violations).
    pub slo_class: Option<String>,
    /// Per-class TTFT/TPOT target (ticks; see [`SloTarget`]). Ignored
    /// unless `slo_class` is set.
    pub slo: SloTarget,
    /// Schedule to goodput (requests meeting their SLO) instead of raw
    /// throughput: deadline-slack ordering replaces FIFO in admission
    /// and batch formation, preemption prefers deadline-hopeless
    /// victims, and hopeless victims skip the swap-out copy. Off =
    /// PR 1–6 throughput-greedy behavior, bit-for-bit.
    pub slo_aware: bool,
    /// Replica-fleet width: the coordinator runs this many independent
    /// replicas (each with its own `BlockPool`, `SwapPool`, scheduler
    /// and worker pool) behind a `Router` that places new sessions by
    /// least-loaded-lane scoring and live-migrates suspended sessions
    /// from hot to cold replicas via the `KvSnapshot` path. `1` (the
    /// default) is byte-identical to the legacy single-scheduler path.
    /// `pool_bytes`/`swap_bytes`/`workers` are **per replica**.
    pub replicas: usize,
    /// Proactive idle swap-out: a prefilled session that has sat
    /// runnable without being pulled by a worker for at least this many
    /// scheduler ticks is suspended to the swap pool *before* pool
    /// pressure forces a preemption, so admission and migration find
    /// free device bytes instead of triggering preemption storms.
    /// `None` = off. Requires `swap_bytes`.
    pub idle_swap_ticks: Option<u64>,
}

impl ServeConfig {
    /// Arena policy sessions built from this config run on the fp32
    /// path: the explicit `--policy` override when present, else the
    /// policy `mode` maps to, else `None` (quantized-cache session).
    pub fn policy_kind(&self) -> Option<PolicyKind> {
        self.policy.or_else(|| self.mode.policy_kind())
    }

    /// Display label for stats surfaces: the arena policy's registered
    /// name, or the quant backend's policy label placeholder.
    pub fn policy_label(&self) -> String {
        match self.policy_kind() {
            Some(k) => k.name().to_string(),
            None => String::new(),
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            mode: CompressionMode::thinkv_default(),
            policy: None,
            budget: 1024,
            capacity: None,
            max_new_tokens: 192,
            refresh: 128,
            retention: vec![64, 32, 16, 8, 4],
            workers: 2,
            chunk: 16,
            max_decode_batch: 8,
            prefill_chunk_tokens: None,
            temperature: 0.8,
            seed: 42,
            pool_bytes: None,
            swap_bytes: None,
            prefix_share: false,
            slo_class: None,
            slo: SloTarget::default(),
            slo_aware: false,
            replicas: 1,
            idle_swap_ticks: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for s in ["fullkv", "thinkv", "h2o", "rkv", "kivi2", "kivi4", "pmkvq", "raas"] {
            assert!(CompressionMode::parse(s).is_some(), "{s}");
        }
        assert!(CompressionMode::parse("nope").is_none());
    }

    #[test]
    fn labels_distinct() {
        let labels: Vec<String> = [
            "fullkv", "thinkv", "thinkv-notbq", "thinkv-notbe", "h2o", "kivi2",
        ]
        .iter()
        .map(|s| CompressionMode::parse(s).unwrap().label())
        .collect();
        let set: std::collections::BTreeSet<_> = labels.iter().collect();
        assert_eq!(set.len(), labels.len());
    }

    #[test]
    fn policy_kind_derivation_and_override() {
        use crate::sim::harness::EvictKind;
        // mode-derived: fp32-path modes map onto the arena registry
        let mut cfg = ServeConfig { mode: CompressionMode::FullKv, ..Default::default() };
        assert_eq!(cfg.policy_kind(), Some(PolicyKind::FullKv));
        cfg.mode = CompressionMode::Evict(EvictKind::SnapKv);
        assert_eq!(cfg.policy_kind(), Some(PolicyKind::SnapKv));
        assert_eq!(cfg.policy_label(), "SnapKV");
        // quantized-cache modes have no arena policy...
        cfg.mode = CompressionMode::thinkv_default();
        assert_eq!(cfg.policy_kind(), None);
        assert_eq!(cfg.policy_label(), "");
        // ...unless --policy forces one (which wins over any mode)
        cfg.policy = Some(PolicyKind::CrystalKv);
        assert_eq!(cfg.policy_kind(), Some(PolicyKind::CrystalKv));
        assert_eq!(cfg.policy_label(), "Crystal-KV");
        cfg.mode = CompressionMode::Evict(EvictKind::H2O);
        assert_eq!(cfg.policy_kind(), Some(PolicyKind::CrystalKv));
    }

    #[test]
    fn slo_target_none_detection() {
        assert!(SloTarget::default().is_none());
        assert!(!SloTarget::new(100, 0).is_none());
        assert!(!SloTarget::new(0, 500).is_none());
        assert!(ServeConfig::default().slo.is_none());
        assert!(ServeConfig::default().slo_class.is_none());
    }
}
