//! The L3 serving coordinator: request router, memory-aware scheduler,
//! and the per-request decode sessions that drive the PJRT engine.
//!
//! Architecture (vLLM-router-like): submitted requests flow through the
//! [`scheduler::Scheduler`] — a waiting queue plus an admitted running
//! set with **byte-accurate admission** against the global
//! [`crate::kvcache::BlockPool`] and preempt-youngest reclamation when a
//! running request cannot grow. N worker threads each own a PJRT
//! [`crate::runtime::Engine`] (the handles are not Sync) and repeatedly
//! pull a **decode batch** of compatible admitted sessions
//! ([`scheduler::Scheduler::next_batch`], grouped by
//! [`crate::kvcache::BatchKey`]), advance the whole batch by a chunk of
//! decode steps — one fused
//! [`crate::runtime::DecodeEngine::decode_batch`] call per step — over
//! the unified [`crate::kvcache::KvBackend`] path, and hand every
//! member back — continuous batching at chunk granularity. Completed
//! sessions are delivered to the submitter through a channel. Python is
//! never involved: the engines execute the AOT HLO artifacts only.
//!
//! Preemption is two-tier: with a host-side
//! [`crate::kvcache::SwapPool`] configured
//! ([`config::ServeConfig::swap_bytes`]), a preempted session suspends
//! its compressed cache snapshot to host memory and later resumes with
//! zero recompute steps; without one (or when the snapshot does not
//! fit) it falls back to recompute-from-prompt.
//!
//! # Example: scheduler lifecycle (no artifacts needed)
//!
//! Submit under memory pressure, watch admission queueing, drain:
//!
//! ```
//! use std::sync::{mpsc, Arc};
//! use thinkv::coordinator::{CompressionMode, Scheduler, ServeConfig, Session};
//! use thinkv::kvcache::BlockPool;
//! use thinkv::model::{Manifest, ModelConfig};
//!
//! // hand-built manifest: the scheduler never touches the engine
//! let manifest = Manifest {
//!     model: ModelConfig {
//!         vocab: 64, d_model: 32, n_layers: 2, n_heads: 2, n_kv_heads: 1,
//!         d_head: 16, d_ffn: 64, rope_base: 10000.0, buf_slots: 16,
//!         prefill_len: 32, obs_window: 8, group_size: 16,
//!     },
//!     quant_caps: vec![128],
//!     fp32_caps: vec![256],
//!     batch_widths: vec![],
//!     prefill_chunk_lens: vec![],
//!     micro_c: 128,
//!     golden_attn_c: 128,
//!     artifacts_dir: ".".into(),
//!     weights: vec![],
//!     seed: 0,
//! };
//! let cfg = ServeConfig {
//!     mode: CompressionMode::thinkv_default(),
//!     budget: 64,
//!     max_new_tokens: 8,
//!     workers: 1,
//!     temperature: 0.0,
//!     ..ServeConfig::default()
//! };
//! // pool sized for one admission reserve: the second request queues
//! let probe = Session::new(0, vec![1, 2, 3], &cfg, &manifest).unwrap();
//! let pool = Arc::new(BlockPool::new(probe.admission_bytes() * 3 / 2));
//! let sched = Scheduler::new(Arc::clone(&pool));
//! let (tx, _rx) = mpsc::channel();
//! for id in 1..=2 {
//!     let s = Session::with_pool(
//!         id, vec![1, 2, 3], &cfg, &manifest, Some(Arc::clone(&pool)),
//!     ).unwrap();
//!     sched.submit(s, tx.clone());
//! }
//! let snap = sched.snapshot();
//! assert_eq!((snap.running, snap.queue_depth), (1, 1));
//! // a decode worker would loop `next()` -> `Session::step` chunks ->
//! // `yield_back`/`cannot_grow`/`complete`; here we fake-finish both
//! for _ in 0..2 {
//!     let mut entry = sched.next().expect("runnable session");
//!     entry.session.finished_at = Some(std::time::Instant::now());
//!     sched.complete(&mut entry.session); // frees bytes, admits next
//! }
//! let snap = sched.snapshot();
//! assert_eq!(snap.completions, 2);
//! assert_eq!(snap.pool_used, 0, "all bytes returned");
//! ```

pub mod config;
pub mod engine_loop;
pub mod replica;
pub mod sampler;
pub mod scheduler;
pub mod session;
#[cfg(test)]
pub(crate) mod test_support;

pub use config::{CompressionMode, ServeConfig, SloTarget};
pub use engine_loop::{advance_batch, Coordinator, RequestHandle, RequestResult};
pub use replica::{Replica, Router};
pub use sampler::Sampler;
pub use scheduler::{Entry, SchedPolicy, Scheduler};
pub use session::{Session, SloState, StepOutcome, StepPrep};
