//! The L3 serving coordinator: request router, continuous batcher, and the
//! per-request decode sessions that drive the PJRT engine.
//!
//! Architecture (vLLM-router-like): a shared FIFO of [`session::Session`]s;
//! N worker threads each own a PJRT [`crate::runtime::Engine`] (the handles
//! are not Sync) and repeatedly pull a session, advance it by a chunk of
//! decode steps, and push it back — continuous batching at chunk
//! granularity. Completed sessions are delivered to the submitter through
//! a channel. Python is never involved: the engines execute the AOT HLO
//! artifacts only.

pub mod config;
pub mod engine_loop;
pub mod sampler;
pub mod session;

pub use config::{CompressionMode, ServeConfig};
pub use engine_loop::{Coordinator, RequestHandle, RequestResult};
pub use sampler::Sampler;
pub use session::Session;
