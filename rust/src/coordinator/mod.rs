//! The L3 serving coordinator: request router, memory-aware scheduler,
//! and the per-request decode sessions that drive the PJRT engine.
//!
//! Architecture (vLLM-router-like): submitted requests flow through the
//! [`scheduler::Scheduler`] — a waiting queue plus an admitted running
//! set with **byte-accurate admission** against the global
//! [`crate::kvcache::BlockPool`] and preempt-youngest reclamation when a
//! running request cannot grow. N worker threads each own a PJRT
//! [`crate::runtime::Engine`] (the handles are not Sync) and repeatedly
//! pull an admitted [`session::Session`], advance it by a chunk of
//! decode steps over the unified [`crate::kvcache::KvBackend`] path, and
//! hand it back — continuous batching at chunk granularity. Completed
//! sessions are delivered to the submitter through a channel. Python is
//! never involved: the engines execute the AOT HLO artifacts only.

pub mod config;
pub mod engine_loop;
pub mod sampler;
pub mod scheduler;
pub mod session;

pub use config::{CompressionMode, ServeConfig};
pub use engine_loop::{Coordinator, RequestHandle, RequestResult};
pub use sampler::Sampler;
pub use scheduler::Scheduler;
pub use session::{Session, StepOutcome};
