//! The continuous-batching engine loop: a shared run queue of sessions, N
//! worker threads each owning a PJRT engine, chunked round-robin decode.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

use anyhow::Result;

use crate::metrics::Breakdown;
use crate::runtime::Engine;

use super::config::ServeConfig;
use super::session::Session;

/// Final outcome of a request.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub tpot_ms: f64,
    pub breakdown: Breakdown,
    pub avg_bits: f64,
    pub live_tokens: usize,
    pub ct_reuses: u64,
    pub tbe_call_rate: f64,
    pub gather_calls: u64,
    pub gather_bytes: u64,
}

/// Handle for awaiting one submitted request.
pub struct RequestHandle {
    pub id: u64,
    rx: mpsc::Receiver<RequestResult>,
}

impl RequestHandle {
    pub fn wait(self) -> Result<RequestResult> {
        Ok(self.rx.recv()?)
    }
}

struct Queued {
    session: Session,
    done_tx: mpsc::Sender<RequestResult>,
}

struct Shared {
    queue: Mutex<VecDeque<Queued>>,
    cv: Condvar,
    stop: AtomicBool,
    inflight: AtomicU64,
}

/// The serving coordinator (leader): owns the run queue and the workers.
pub struct Coordinator {
    cfg: ServeConfig,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    manifest: crate::model::Manifest,
}

impl Coordinator {
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        Coordinator::start_with_dir(cfg, &crate::model::default_artifacts_dir())
    }

    pub fn start_with_dir(cfg: ServeConfig, artifacts_dir: &str) -> Result<Coordinator> {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
        });
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers.max(1) {
            let shared = Arc::clone(&shared);
            let chunk = cfg.chunk.max(1);
            let dir = artifacts_dir.to_string();
            let ready = ready_tx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("thinkv-decode-{w}"))
                    .spawn(move || {
                        let engine = match Engine::with_dir(&dir) {
                            Ok(e) => {
                                let _ = ready.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        worker_loop(&shared, &engine, chunk);
                    })
                    .expect("spawn decode worker"),
            );
        }
        drop(ready_tx);
        for _ in 0..cfg.workers.max(1) {
            ready_rx.recv()??;
        }
        Ok(Coordinator {
            cfg,
            shared,
            workers,
            next_id: AtomicU64::new(1),
            manifest: crate::model::Manifest::load(artifacts_dir)?,
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Submit a prompt; returns a handle to await the result.
    pub fn submit(&self, prompt: Vec<i32>) -> Result<RequestHandle> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        let queued = Queued {
            session: Session::new(id, prompt, &self.cfg, &self.manifest)?,
            done_tx: tx,
        };
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(queued);
            self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        }
        self.shared.cv.notify_one();
        Ok(RequestHandle { id, rx })
    }

    /// Submit many prompts and wait for all (batch experiments).
    pub fn run_batch(&self, prompts: Vec<Vec<i32>>) -> Result<Vec<RequestResult>> {
        let handles: Vec<RequestHandle> = prompts
            .into_iter()
            .map(|p| self.submit(p))
            .collect::<Result<Vec<_>>>()?;
        handles.into_iter().map(|h| h.wait()).collect()
    }

    pub fn inflight(&self) -> u64 {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared, engine: &Engine, chunk: usize) {
    loop {
        let mut item = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(item) = q.pop_front() {
                    break item;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // advance by up to `chunk` steps (continuous-batching quantum)
        let mut running = true;
        for _ in 0..chunk {
            match item.session.step(engine) {
                Ok(true) => {}
                Ok(false) => {
                    running = false;
                    break;
                }
                Err(e) => {
                    eprintln!("session {} failed: {e:#}", item.session.id);
                    item.session.finished_at = Some(std::time::Instant::now());
                    running = false;
                    break;
                }
            }
        }
        if running {
            let mut q = shared.queue.lock().unwrap();
            q.push_back(item);
            shared.cv.notify_one();
        } else {
            let s = &item.session;
            let total_ms = s
                .finished_at
                .unwrap_or_else(std::time::Instant::now)
                .duration_since(s.created)
                .as_secs_f64()
                * 1e3;
            let ttft_ms = s
                .first_token_at
                .map(|t| t.duration_since(s.created).as_secs_f64() * 1e3)
                .unwrap_or(total_ms);
            let n = s.tokens.len().max(1) as f64;
            let (gather_calls, gather_bytes, _) = s.gather_stats();
            let result = RequestResult {
                id: s.id,
                tokens: s.tokens.clone(),
                ttft_ms,
                total_ms,
                tpot_ms: (total_ms - ttft_ms).max(0.0) / n,
                breakdown: s.breakdown.clone(),
                avg_bits: s.avg_bits(),
                live_tokens: s.live_tokens(),
                ct_reuses: s.ct_reuse_count(),
                tbe_call_rate: s.tbe_stats().map(|t| t.call_rate()).unwrap_or(0.0),
                gather_calls,
                gather_bytes,
            };
            let _ = item.done_tx.send(result);
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
        }
    }
}
