//! The continuous-batching engine loop: N worker threads each owning a
//! PJRT engine pull **decode batches** of compatible sessions from the
//! memory-aware [`Scheduler`] ([`Scheduler::next_batch`]), advance the
//! whole batch by a chunk of steps — one fused
//! [`DecodeEngine::decode_batch`] call per step instead of one engine
//! call per session — and hand every member back (yield / preempt-retry
//! / complete). Batching is stream-invariant: a batched run produces
//! token streams identical to sequential execution (each member keeps
//! its own cache, sampler, and position; the fused call only amortizes
//! launches).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

use anyhow::Result;

use crate::kvcache::{BlockPool, PrefixIndex, SwapPool};
use crate::metrics::{Breakdown, SchedSnapshot};
use crate::runtime::{BatchDecodeReq, DecodeEngine, Engine};

use super::config::ServeConfig;
use super::scheduler::{Entry, Scheduler};
use super::session::{Session, StepOutcome, StepPrep};

/// Default pool capacity when `ServeConfig::pool_bytes` is unset —
/// effectively unbounded, so memory accounting stays on without ever
/// refusing admission.
const UNBOUNDED_POOL_BYTES: u64 = u64::MAX / 2;

/// Prefix-trie granularity: prompts share in whole blocks of this many
/// tokens, matching the CT block-table block size `build_backend`
/// compiles caches with.
const PREFIX_BLOCK_TOKENS: usize = 8;

/// Final outcome of a request.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub tpot_ms: f64,
    pub breakdown: Breakdown,
    pub avg_bits: f64,
    pub live_tokens: usize,
    pub ct_reuses: u64,
    pub tbe_call_rate: f64,
    pub gather_calls: u64,
    pub gather_bytes: u64,
    /// Times the scheduler preempted this request with *recompute*
    /// (reset + replay). Zero for requests whose preemptions all
    /// suspended to host.
    pub preemptions: u64,
    /// Times this request was suspended to the host swap pool.
    pub swap_outs: u64,
    /// Times this request was restored from the host swap pool.
    pub swap_ins: u64,
    /// Wall time spent restoring this request's snapshots (swap-in).
    pub restore_ns: u64,
    /// Set when the request terminated abnormally (e.g. its KV demand
    /// exceeded the block pool).
    pub error: Option<String>,
}

impl RequestResult {
    /// Snapshot a (finished) session into its result record.
    pub(crate) fn from_session(s: &Session) -> RequestResult {
        let total_ms = s
            .finished_at
            .unwrap_or_else(std::time::Instant::now)
            .duration_since(s.created)
            .as_secs_f64()
            * 1e3;
        let ttft_ms = s
            .first_token_at
            .map(|t| t.duration_since(s.created).as_secs_f64() * 1e3)
            .unwrap_or(total_ms);
        // the first token comes from prefill logits (its latency is
        // ttft), so `total - ttft` spans only the n-1 decode gaps
        let gaps = s.tokens.len().saturating_sub(1).max(1) as f64;
        let (gather_calls, gather_bytes, _) = s.gather_stats();
        RequestResult {
            id: s.id,
            tokens: s.tokens.clone(),
            ttft_ms,
            total_ms,
            tpot_ms: (total_ms - ttft_ms).max(0.0) / gaps,
            breakdown: s.breakdown.clone(),
            avg_bits: s.avg_bits(),
            live_tokens: s.live_tokens(),
            ct_reuses: s.ct_reuse_count(),
            tbe_call_rate: s.tbe_stats().map(|t| t.call_rate()).unwrap_or(0.0),
            gather_calls,
            gather_bytes,
            preemptions: s.preemptions,
            swap_outs: s.swap_outs,
            swap_ins: s.swap_ins,
            restore_ns: s.restore_ns,
            error: None,
        }
    }
}

/// Handle for awaiting one submitted request.
pub struct RequestHandle {
    pub id: u64,
    rx: mpsc::Receiver<RequestResult>,
}

impl RequestHandle {
    pub fn wait(self) -> Result<RequestResult> {
        Ok(self.rx.recv()?)
    }
}

/// The serving coordinator (leader): owns the scheduler and the workers.
pub struct Coordinator {
    cfg: ServeConfig,
    scheduler: Arc<Scheduler>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    manifest: crate::model::Manifest,
}

impl Coordinator {
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        Coordinator::start_with_dir(cfg, &crate::model::default_artifacts_dir())
    }

    pub fn start_with_dir(cfg: ServeConfig, artifacts_dir: &str) -> Result<Coordinator> {
        let manifest = crate::model::Manifest::load(artifacts_dir)?;
        let pool = Arc::new(BlockPool::new(
            cfg.pool_bytes.unwrap_or(UNBOUNDED_POOL_BYTES),
        ));
        // suspend-to-host preemption: swapped sessions resume instead of
        // recomputing whenever their snapshot fits this host pool
        let swap = cfg.swap_bytes.map(|b| Arc::new(SwapPool::new(b)));
        // cross-session prefix sharing: the index accounts its resident
        // payloads against the same block pool the scheduler admits
        // from, at the CT block granularity
        let prefix = cfg
            .prefix_share
            .then(|| PrefixIndex::new(Arc::clone(&pool), PREFIX_BLOCK_TOKENS));
        let scheduler = Arc::new(Scheduler::with_prefix(pool, swap, prefix));
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers.max(1) {
            let scheduler = Arc::clone(&scheduler);
            let chunk = cfg.chunk.max(1);
            let max_batch = cfg.max_decode_batch.max(1);
            let dir = artifacts_dir.to_string();
            let ready = ready_tx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("thinkv-decode-{w}"))
                    .spawn(move || {
                        let engine = match Engine::with_dir(&dir) {
                            Ok(e) => {
                                let _ = ready.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        worker_loop(&scheduler, &engine, chunk, max_batch);
                    })
                    .expect("spawn decode worker"),
            );
        }
        drop(ready_tx);
        for _ in 0..cfg.workers.max(1) {
            ready_rx.recv()??;
        }
        Ok(Coordinator {
            cfg,
            scheduler,
            workers,
            next_id: AtomicU64::new(1),
            manifest,
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Submit a prompt; returns a handle to await the result. Fails fast
    /// when the request's KV demand can never fit the pool.
    pub fn submit(&self, prompt: Vec<i32>) -> Result<RequestHandle> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let session = Session::with_parts(
            id,
            prompt,
            &self.cfg,
            &self.manifest,
            Some(Arc::clone(self.scheduler.pool())),
            self.scheduler.prefix_index().cloned(),
        )?;
        if session.admission_bytes() > self.scheduler.pool().capacity() {
            anyhow::bail!(
                "request {id}: admission demand {} B exceeds pool capacity {} B",
                session.admission_bytes(),
                self.scheduler.pool().capacity()
            );
        }
        let (tx, rx) = mpsc::channel();
        self.scheduler.submit(session, tx);
        Ok(RequestHandle { id, rx })
    }

    /// Submit many prompts and wait for all (batch experiments).
    pub fn run_batch(&self, prompts: Vec<Vec<i32>>) -> Result<Vec<RequestResult>> {
        let handles: Vec<RequestHandle> = prompts
            .into_iter()
            .map(|p| self.submit(p))
            .collect::<Result<Vec<_>>>()?;
        handles.into_iter().map(|h| h.wait()).collect()
    }

    pub fn inflight(&self) -> u64 {
        self.scheduler.inflight()
    }

    /// The global KV block pool (memory accounting).
    pub fn pool(&self) -> &BlockPool {
        self.scheduler.pool()
    }

    /// Scheduler + pool counters (admissions, preemptions, queue depth,
    /// pool used/peak/free).
    pub fn sched_stats(&self) -> SchedSnapshot {
        self.scheduler.snapshot()
    }

    pub fn shutdown(mut self) {
        self.scheduler.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.scheduler.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

enum ChunkEnd {
    Yield,
    NeedMemory,
    Finished,
    Failed(String),
}

/// Hand one session back to the scheduler / submitter according to how
/// its chunk ended.
fn dispatch(scheduler: &Scheduler, mut item: Entry, end: ChunkEnd) {
    match end {
        ChunkEnd::Yield => scheduler.yield_back(item),
        ChunkEnd::NeedMemory => scheduler.cannot_grow(item),
        ChunkEnd::Finished => {
            let result = RequestResult::from_session(&item.session);
            let _ = item.done_tx.send(result);
            scheduler.complete(&mut item.session);
        }
        ChunkEnd::Failed(why) => {
            // the submitter must be able to tell a failed decode from
            // a short answer, and stats must not count it as success
            let mut result = RequestResult::from_session(&item.session);
            result.error = Some(why);
            let _ = item.done_tx.send(result);
            scheduler.complete_failed(&mut item.session);
        }
    }
}

/// Advance a decode batch by up to `chunk` steps, one fused
/// [`DecodeEngine::decode_batch`] call per step, then hand every member
/// back to the scheduler (yield / preempt-retry / complete / fail).
///
/// Each step runs in three phases:
///
/// 1. **prepare** — every member runs [`Session::begin_step`]
///    (swap-in restore, prefill, growth reservation, ring-buffer
///    flush); members that finish, fail, or cannot grow leave the batch
///    immediately so their bytes / results are released mid-chunk.
/// 2. **fused decode** — one engine call covers every prepared member
///    (`note_fused_step` records the batch size for the stats
///    histogram).
/// 3. **absorb** — every member runs [`Session::finish_step`] on its
///    own output (classification, append, eviction, sampling).
///
/// This is the whole worker body behind [`Coordinator`]; it is public
/// so artifact-free harnesses (e.g. the batched-vs-sequential stream
/// invariance property test) can drive the exact production code path
/// with a deterministic [`DecodeEngine`].
pub fn advance_batch(
    scheduler: &Scheduler,
    engine: &dyn DecodeEngine,
    chunk: usize,
    batch: Vec<Entry>,
) {
    let mut members = batch;
    for _ in 0..chunk.max(1) {
        if members.is_empty() {
            return;
        }
        // phase 1: prepare every member for the fused call
        let mut preps: Vec<Option<(i32, i32, i32)>> = Vec::with_capacity(members.len());
        let mut exits: Vec<(usize, ChunkEnd)> = Vec::new();
        for (i, m) in members.iter_mut().enumerate() {
            match m.session.begin_step(engine) {
                Ok(StepPrep::Ready { token, pos, buf_idx }) => {
                    preps.push(Some((token, pos, buf_idx)));
                }
                Ok(StepPrep::Finished) => {
                    preps.push(None);
                    exits.push((i, ChunkEnd::Finished));
                }
                Ok(StepPrep::NeedMemory) => {
                    preps.push(None);
                    exits.push((i, ChunkEnd::NeedMemory));
                }
                Err(e) => {
                    eprintln!("session {} failed: {e:#}", m.session.id);
                    m.session.finished_at = Some(std::time::Instant::now());
                    preps.push(None);
                    exits.push((i, ChunkEnd::Failed(format!("{e:#}"))));
                }
            }
        }
        // phase 2: one fused engine call over every prepared member
        let fused = {
            let reqs: Vec<BatchDecodeReq> = members
                .iter()
                .zip(&preps)
                .filter_map(|(m, p)| {
                    p.map(|(token, pos, buf_idx)| BatchDecodeReq {
                        token,
                        pos,
                        buf_idx,
                        view: m.session.cache_view(),
                    })
                })
                .collect();
            if reqs.is_empty() {
                None
            } else {
                let n = reqs.len();
                let t0 = std::time::Instant::now();
                let outs = engine.decode_batch(&reqs);
                let ns = t0.elapsed().as_nanos() as u64;
                Some((outs, ns / n as u64, n))
            }
        };
        // phase 3: absorb per member
        match fused {
            None => {}
            Some((result, per_ns, n)) => {
                // an engine that returns the wrong number of outputs is
                // as unattributable as one that errors — same path
                let result = result.and_then(|outs| {
                    if outs.len() == n {
                        Ok(outs)
                    } else {
                        Err(anyhow::anyhow!(
                            "fused decode returned {} outputs for {} requests",
                            outs.len(),
                            n
                        ))
                    }
                });
                match result {
                    Ok(outs) => {
                        scheduler.note_fused_step(n);
                        let mut oi = 0;
                        for (i, (m, p)) in members.iter_mut().zip(&preps).enumerate() {
                            if p.is_none() {
                                continue;
                            }
                            let out = &outs[oi];
                            oi += 1;
                            m.session.breakdown.decode_exec_ns += per_ns;
                            match m.session.finish_step(out, engine) {
                                Ok(StepOutcome::Running) => {}
                                Ok(StepOutcome::Finished) => exits.push((i, ChunkEnd::Finished)),
                                Ok(StepOutcome::NeedMemory) => {
                                    exits.push((i, ChunkEnd::NeedMemory));
                                }
                                Err(e) => {
                                    eprintln!("session {} failed: {e:#}", m.session.id);
                                    m.session.finished_at = Some(std::time::Instant::now());
                                    exits.push((i, ChunkEnd::Failed(format!("{e:#}"))));
                                }
                            }
                        }
                    }
                    Err(e) => {
                        // a failed fused call fails every member that was
                        // in it: per-member attribution is impossible once
                        // the engine errors, and silent retry would hide
                        // real breakage
                        eprintln!("fused decode step failed: {e:#}");
                        let why = format!("{e:#}");
                        for (i, (m, p)) in members.iter_mut().zip(&preps).enumerate() {
                            if p.is_some() {
                                m.session.finished_at = Some(std::time::Instant::now());
                                exits.push((i, ChunkEnd::Failed(why.clone())));
                            }
                        }
                    }
                }
            }
        }
        // retire exited members (highest index first so removals are
        // position-stable), releasing bytes/results mid-chunk
        exits.sort_by(|a, b| b.0.cmp(&a.0));
        for (i, end) in exits {
            let item = members.remove(i);
            dispatch(scheduler, item, end);
        }
    }
    // chunk exhausted: everyone still running yields
    for item in members {
        dispatch(scheduler, item, ChunkEnd::Yield);
    }
}

fn worker_loop(scheduler: &Scheduler, engine: &Engine, chunk: usize, max_batch: usize) {
    while let Some(batch) = scheduler.next_batch(max_batch) {
        advance_batch(scheduler, engine, chunk, batch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::StepOutcome;
    use crate::coordinator::test_support::{tiny_cfg, tiny_manifest, FakeEngine};
    use std::time::{Duration, Instant};

    /// The first token comes from prefill logits (its latency is the
    /// ttft), so `tpot` must divide the post-ttft window by the n-1
    /// decode gaps — not by n (the pre-fix off-by-one, which understated
    /// tpot by (n-1)/n).
    #[test]
    fn tpot_divides_by_decode_gaps_not_token_count() {
        let man = tiny_manifest();
        let cfg = ServeConfig { max_new_tokens: 5, ..tiny_cfg() };
        let engine = FakeEngine::new(man.model.clone());
        let mut s = Session::new(1, vec![1, 2, 3], &cfg, &man).unwrap();
        loop {
            match s.step(&engine).unwrap() {
                StepOutcome::Finished => break,
                StepOutcome::Running => {}
                StepOutcome::NeedMemory => panic!("no pool bound"),
            }
        }
        assert_eq!(s.tokens.len(), 5);
        // pin the timeline: 100 ms total, 10 ms ttft -> 90 ms over 4 gaps
        let now = Instant::now();
        s.created = now - Duration::from_millis(100);
        s.first_token_at = Some(now - Duration::from_millis(90));
        s.finished_at = Some(now);
        let r = RequestResult::from_session(&s);
        let window = r.total_ms - r.ttft_ms;
        assert!(window > 80.0, "timeline pinned: {window}");
        assert!(
            (r.tpot_ms - window / 4.0).abs() < 1e-9,
            "5 tokens = 4 decode gaps: tpot {} vs window {}",
            r.tpot_ms,
            window
        );
        assert!(
            r.tpot_ms > window / 5.0 + 1.0,
            "must not divide by the token count"
        );

        // a single-token result degrades to the whole window, no panic
        let cfg1 = ServeConfig { max_new_tokens: 1, ..tiny_cfg() };
        let mut one = Session::new(2, vec![1], &cfg1, &man).unwrap();
        while !matches!(one.step(&engine).unwrap(), StepOutcome::Finished) {}
        assert_eq!(one.tokens.len(), 1);
        let r1 = RequestResult::from_session(&one);
        assert!(r1.tpot_ms >= 0.0);
    }
}
