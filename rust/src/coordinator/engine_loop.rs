//! The continuous-batching engine loop: N worker threads each owning a
//! PJRT engine pull admitted sessions from the memory-aware
//! [`Scheduler`], advance them by a chunk of decode steps, and hand them
//! back (yield / preempt-retry / complete).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

use anyhow::Result;

use crate::kvcache::{BlockPool, SwapPool};
use crate::metrics::{Breakdown, SchedSnapshot};
use crate::runtime::Engine;

use super::config::ServeConfig;
use super::scheduler::Scheduler;
use super::session::{Session, StepOutcome};

/// Default pool capacity when `ServeConfig::pool_bytes` is unset —
/// effectively unbounded, so memory accounting stays on without ever
/// refusing admission.
const UNBOUNDED_POOL_BYTES: u64 = u64::MAX / 2;

/// Final outcome of a request.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub tpot_ms: f64,
    pub breakdown: Breakdown,
    pub avg_bits: f64,
    pub live_tokens: usize,
    pub ct_reuses: u64,
    pub tbe_call_rate: f64,
    pub gather_calls: u64,
    pub gather_bytes: u64,
    /// Times the scheduler preempted this request with *recompute*
    /// (reset + replay). Zero for requests whose preemptions all
    /// suspended to host.
    pub preemptions: u64,
    /// Times this request was suspended to the host swap pool.
    pub swap_outs: u64,
    /// Times this request was restored from the host swap pool.
    pub swap_ins: u64,
    /// Wall time spent restoring this request's snapshots (swap-in).
    pub restore_ns: u64,
    /// Set when the request terminated abnormally (e.g. its KV demand
    /// exceeded the block pool).
    pub error: Option<String>,
}

impl RequestResult {
    /// Snapshot a (finished) session into its result record.
    pub(crate) fn from_session(s: &Session) -> RequestResult {
        let total_ms = s
            .finished_at
            .unwrap_or_else(std::time::Instant::now)
            .duration_since(s.created)
            .as_secs_f64()
            * 1e3;
        let ttft_ms = s
            .first_token_at
            .map(|t| t.duration_since(s.created).as_secs_f64() * 1e3)
            .unwrap_or(total_ms);
        let n = s.tokens.len().max(1) as f64;
        let (gather_calls, gather_bytes, _) = s.gather_stats();
        RequestResult {
            id: s.id,
            tokens: s.tokens.clone(),
            ttft_ms,
            total_ms,
            tpot_ms: (total_ms - ttft_ms).max(0.0) / n,
            breakdown: s.breakdown.clone(),
            avg_bits: s.avg_bits(),
            live_tokens: s.live_tokens(),
            ct_reuses: s.ct_reuse_count(),
            tbe_call_rate: s.tbe_stats().map(|t| t.call_rate()).unwrap_or(0.0),
            gather_calls,
            gather_bytes,
            preemptions: s.preemptions,
            swap_outs: s.swap_outs,
            swap_ins: s.swap_ins,
            restore_ns: s.restore_ns,
            error: None,
        }
    }
}

/// Handle for awaiting one submitted request.
pub struct RequestHandle {
    pub id: u64,
    rx: mpsc::Receiver<RequestResult>,
}

impl RequestHandle {
    pub fn wait(self) -> Result<RequestResult> {
        Ok(self.rx.recv()?)
    }
}

/// The serving coordinator (leader): owns the scheduler and the workers.
pub struct Coordinator {
    cfg: ServeConfig,
    scheduler: Arc<Scheduler>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    manifest: crate::model::Manifest,
}

impl Coordinator {
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        Coordinator::start_with_dir(cfg, &crate::model::default_artifacts_dir())
    }

    pub fn start_with_dir(cfg: ServeConfig, artifacts_dir: &str) -> Result<Coordinator> {
        let manifest = crate::model::Manifest::load(artifacts_dir)?;
        let pool = Arc::new(BlockPool::new(
            cfg.pool_bytes.unwrap_or(UNBOUNDED_POOL_BYTES),
        ));
        // suspend-to-host preemption: swapped sessions resume instead of
        // recomputing whenever their snapshot fits this host pool
        let swap = cfg.swap_bytes.map(|b| Arc::new(SwapPool::new(b)));
        let scheduler = Arc::new(Scheduler::with_swap(pool, swap));
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers.max(1) {
            let scheduler = Arc::clone(&scheduler);
            let chunk = cfg.chunk.max(1);
            let dir = artifacts_dir.to_string();
            let ready = ready_tx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("thinkv-decode-{w}"))
                    .spawn(move || {
                        let engine = match Engine::with_dir(&dir) {
                            Ok(e) => {
                                let _ = ready.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        worker_loop(&scheduler, &engine, chunk);
                    })
                    .expect("spawn decode worker"),
            );
        }
        drop(ready_tx);
        for _ in 0..cfg.workers.max(1) {
            ready_rx.recv()??;
        }
        Ok(Coordinator {
            cfg,
            scheduler,
            workers,
            next_id: AtomicU64::new(1),
            manifest,
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Submit a prompt; returns a handle to await the result. Fails fast
    /// when the request's KV demand can never fit the pool.
    pub fn submit(&self, prompt: Vec<i32>) -> Result<RequestHandle> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let session = Session::with_pool(
            id,
            prompt,
            &self.cfg,
            &self.manifest,
            Some(Arc::clone(self.scheduler.pool())),
        )?;
        if session.admission_bytes() > self.scheduler.pool().capacity() {
            anyhow::bail!(
                "request {id}: admission demand {} B exceeds pool capacity {} B",
                session.admission_bytes(),
                self.scheduler.pool().capacity()
            );
        }
        let (tx, rx) = mpsc::channel();
        self.scheduler.submit(session, tx);
        Ok(RequestHandle { id, rx })
    }

    /// Submit many prompts and wait for all (batch experiments).
    pub fn run_batch(&self, prompts: Vec<Vec<i32>>) -> Result<Vec<RequestResult>> {
        let handles: Vec<RequestHandle> = prompts
            .into_iter()
            .map(|p| self.submit(p))
            .collect::<Result<Vec<_>>>()?;
        handles.into_iter().map(|h| h.wait()).collect()
    }

    pub fn inflight(&self) -> u64 {
        self.scheduler.inflight()
    }

    /// The global KV block pool (memory accounting).
    pub fn pool(&self) -> &BlockPool {
        self.scheduler.pool()
    }

    /// Scheduler + pool counters (admissions, preemptions, queue depth,
    /// pool used/peak/free).
    pub fn sched_stats(&self) -> SchedSnapshot {
        self.scheduler.snapshot()
    }

    pub fn shutdown(mut self) {
        self.scheduler.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.scheduler.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

enum ChunkEnd {
    Yield,
    NeedMemory,
    Finished,
    Failed(String),
}

fn worker_loop(scheduler: &Scheduler, engine: &Engine, chunk: usize) {
    while let Some(mut item) = scheduler.next() {
        // advance by up to `chunk` steps (continuous-batching quantum)
        let mut end = ChunkEnd::Yield;
        for _ in 0..chunk {
            match item.session.step(engine) {
                Ok(StepOutcome::Running) => {}
                Ok(StepOutcome::Finished) => {
                    end = ChunkEnd::Finished;
                    break;
                }
                Ok(StepOutcome::NeedMemory) => {
                    end = ChunkEnd::NeedMemory;
                    break;
                }
                Err(e) => {
                    eprintln!("session {} failed: {e:#}", item.session.id);
                    item.session.finished_at = Some(std::time::Instant::now());
                    end = ChunkEnd::Failed(format!("{e:#}"));
                    break;
                }
            }
        }
        match end {
            ChunkEnd::Yield => scheduler.yield_back(item),
            ChunkEnd::NeedMemory => scheduler.cannot_grow(item),
            ChunkEnd::Finished => {
                let result = RequestResult::from_session(&item.session);
                let _ = item.done_tx.send(result);
                scheduler.complete(&mut item.session);
            }
            ChunkEnd::Failed(why) => {
                // the submitter must be able to tell a failed decode from
                // a short answer, and stats must not count it as success
                let mut result = RequestResult::from_session(&item.session);
                result.error = Some(why);
                let _ = item.done_tx.send(result);
                scheduler.complete_failed(&mut item.session);
            }
        }
    }
}
