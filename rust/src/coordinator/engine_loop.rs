//! The continuous-batching engine loop: N worker threads each owning a
//! PJRT engine pull **decode batches** of compatible sessions from the
//! memory-aware [`Scheduler`] ([`Scheduler::next_batch`]), advance the
//! whole batch by a chunk of steps — one fused
//! [`DecodeEngine::decode_batch`] call per step instead of one engine
//! call per session — and hand every member back (yield / preempt-retry
//! / complete). Batching is stream-invariant: a batched run produces
//! token streams identical to sequential execution (each member keeps
//! its own cache, sampler, and position; the fused call only amortizes
//! launches).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

use anyhow::Result;

use crate::kvcache::BlockPool;
use crate::metrics::{Breakdown, SchedSnapshot};
use crate::runtime::{BatchDecodeReq, DecodeEngine, Engine};

use super::config::ServeConfig;
use super::replica::Router;
use super::scheduler::{Entry, Scheduler};
use super::session::{Session, StepOutcome, StepPrep};

/// Default pool capacity when `ServeConfig::pool_bytes` is unset —
/// effectively unbounded, so memory accounting stays on without ever
/// refusing admission.
const UNBOUNDED_POOL_BYTES: u64 = u64::MAX / 2;

/// Prefix-trie granularity: prompts share in whole blocks of this many
/// tokens, matching the CT block-table block size `build_backend`
/// compiles caches with.
const PREFIX_BLOCK_TOKENS: usize = 8;

/// Final outcome of a request.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub tpot_ms: f64,
    pub breakdown: Breakdown,
    pub avg_bits: f64,
    pub live_tokens: usize,
    pub ct_reuses: u64,
    pub tbe_call_rate: f64,
    pub gather_calls: u64,
    pub gather_bytes: u64,
    /// Times the scheduler preempted this request with *recompute*
    /// (reset + replay). Zero for requests whose preemptions all
    /// suspended to host.
    pub preemptions: u64,
    /// Times this request was suspended to the host swap pool.
    pub swap_outs: u64,
    /// Times this request was restored from the host swap pool.
    pub swap_ins: u64,
    /// Wall time spent restoring this request's snapshots (swap-in).
    /// (`breakdown.prefill_chunks` / `breakdown.prefill_exec_ns` carry
    /// the TTFT decomposition: chunks the prompt was computed in and
    /// the engine time they took.)
    pub restore_ns: u64,
    /// Display name of the retention policy that served this request
    /// (`"none"` when no fp32 policy arena was configured).
    pub policy: &'static str,
    /// Positions the policy evicted from this request's cache.
    pub evicted: u64,
    /// Positions the policy never materialized (SkipKV axis).
    pub skipped: u64,
    /// KV bytes retained at completion.
    pub retained_bytes: u64,
    /// Set when the request terminated abnormally (e.g. its KV demand
    /// exceeded the block pool).
    pub error: Option<String>,
}

impl RequestResult {
    /// Snapshot a (finished) session into its result record.
    pub(crate) fn from_session(s: &Session) -> RequestResult {
        let total_ms = s
            .finished_at
            .unwrap_or_else(std::time::Instant::now)
            .duration_since(s.created)
            .as_secs_f64()
            * 1e3;
        let ttft_ms = s
            .first_token_at
            .map(|t| t.duration_since(s.created).as_secs_f64() * 1e3)
            .unwrap_or(total_ms);
        // the first token comes from prefill logits (its latency is
        // ttft), so `total - ttft` spans only the n-1 decode gaps
        let gaps = s.tokens.len().saturating_sub(1).max(1) as f64;
        let (gather_calls, gather_bytes, _) = s.gather_stats();
        RequestResult {
            id: s.id,
            tokens: s.tokens.clone(),
            ttft_ms,
            total_ms,
            tpot_ms: (total_ms - ttft_ms).max(0.0) / gaps,
            breakdown: s.breakdown.clone(),
            avg_bits: s.avg_bits(),
            live_tokens: s.live_tokens(),
            ct_reuses: s.ct_reuse_count(),
            tbe_call_rate: s.tbe_stats().map(|t| t.call_rate()).unwrap_or(0.0),
            gather_calls,
            gather_bytes,
            preemptions: s.preemptions,
            swap_outs: s.swap_outs,
            swap_ins: s.swap_ins,
            restore_ns: s.restore_ns,
            policy: s.policy_label,
            evicted: s.retention().evicted,
            skipped: s.retention().skipped,
            retained_bytes: s.retention().retained_bytes,
            error: None,
        }
    }
}

/// Handle for awaiting one submitted request.
#[must_use = "dropping a RequestHandle discards the request's only result receiver"]
pub struct RequestHandle {
    pub id: u64,
    rx: mpsc::Receiver<RequestResult>,
}

impl RequestHandle {
    pub fn wait(self) -> Result<RequestResult> {
        Ok(self.rx.recv()?)
    }
}

/// The serving coordinator (leader): owns the replica [`Router`] and
/// the per-replica decode workers.
pub struct Coordinator {
    cfg: ServeConfig,
    router: Arc<Router>,
    workers: Vec<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    next_id: AtomicU64,
    manifest: crate::model::Manifest,
}

/// How often the background rebalancer looks for a hot/cold replica
/// imbalance (fleet mode only).
const REBALANCE_INTERVAL: std::time::Duration = std::time::Duration::from_millis(2);

impl Coordinator {
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        Coordinator::start_with_dir(cfg, &crate::model::default_artifacts_dir())
    }

    pub fn start_with_dir(cfg: ServeConfig, artifacts_dir: &str) -> Result<Coordinator> {
        let manifest = crate::model::Manifest::load(artifacts_dir)?;
        // the replica fleet: per-replica block/swap pools, a fleet-global
        // prefix index (resident payloads charged once, to replica 0's
        // pool), suspend-to-host preemption per replica
        let router = Arc::new(Router::new(
            cfg.replicas.max(1),
            cfg.pool_bytes.unwrap_or(UNBOUNDED_POOL_BYTES),
            cfg.swap_bytes,
            cfg.prefix_share,
            PREFIX_BLOCK_TOKENS,
        ));
        for r in router.replicas() {
            let scheduler = r.scheduler();
            // stall-free chunked prefill: long prompts advance in
            // fixed-token chunks co-scheduled with fused decode steps
            if let Some(tokens) = cfg.prefill_chunk_tokens {
                scheduler.set_prefill_chunking(tokens.max(1), 0);
            }
            // SLO-aware goodput policy: admission, batch steering, and
            // victim selection order by TTFT-deadline slack, not FIFO
            if cfg.slo_aware {
                scheduler.set_policy(super::scheduler::SchedPolicy::Goodput);
            }
            // proactive idle swap-out (flag-gated): idle sessions park
            // in host memory before pool pressure forces preemption
            if let Some(k) = cfg.idle_swap_ticks {
                scheduler.set_idle_swap(k);
            }
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        let per_replica = cfg.workers.max(1);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for r in router.replicas() {
            for w in 0..per_replica {
                let scheduler = Arc::clone(r.scheduler());
                let chunk = cfg.chunk.max(1);
                let max_batch = cfg.max_decode_batch.max(1);
                let dir = artifacts_dir.to_string();
                let ready = ready_tx.clone();
                let rid = r.id();
                workers.push(
                    thread::Builder::new()
                        .name(format!("thinkv-decode-{rid}-{w}"))
                        .spawn(move || {
                            let engine = match Engine::with_dir(&dir) {
                                Ok(e) => {
                                    let _ = ready.send(Ok(()));
                                    e
                                }
                                Err(e) => {
                                    let _ = ready.send(Err(e));
                                    return;
                                }
                            };
                            worker_loop(&scheduler, &engine, chunk, max_batch);
                        })
                        .expect("spawn decode worker"),
                );
            }
        }
        drop(ready_tx);
        for _ in 0..router.replicas().len() * per_replica {
            ready_rx.recv()??;
        }
        // live rebalancer: migrate sessions off hot replicas while the
        // fleet is imbalanced (no-op thread never spawned for N = 1)
        if router.replicas().len() > 1 {
            let router = Arc::clone(&router);
            let stop = Arc::clone(&stop);
            workers.push(
                thread::Builder::new()
                    .name("thinkv-rebalance".into())
                    .spawn(move || {
                        while !stop.load(Ordering::SeqCst) {
                            router.rebalance();
                            thread::sleep(REBALANCE_INTERVAL);
                        }
                    })
                    .expect("spawn rebalancer"),
            );
        }
        Ok(Coordinator {
            cfg,
            router,
            workers,
            stop,
            next_id: AtomicU64::new(1),
            manifest,
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The replica fleet behind this coordinator.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a prompt; returns a handle to await the result. Fails fast
    /// when the request's KV demand can never fit the pool.
    pub fn submit(&self, prompt: Vec<i32>) -> Result<RequestHandle> {
        self.submit_inner(prompt, None)
    }

    /// [`Coordinator::submit`] with a streaming sink: every decode chunk
    /// flushes the tokens generated since the last flush as one frame
    /// into `frames`. The bounded channel is the per-connection
    /// backpressure: a slow consumer stalls only its own session's
    /// worker at chunk granularity, and a disconnected one detaches the
    /// sink instead of wedging the batch.
    pub fn submit_with_stream(
        &self,
        prompt: Vec<i32>,
        frames: mpsc::SyncSender<Vec<i32>>,
    ) -> Result<RequestHandle> {
        self.submit_inner(prompt, Some(frames))
    }

    fn submit_inner(
        &self,
        prompt: Vec<i32>,
        frames: Option<mpsc::SyncSender<Vec<i32>>>,
    ) -> Result<RequestHandle> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        // least-loaded-lane placement, decided before the session binds
        // to a pool (the key probe is side-effect free); a 1-replica
        // fleet always places on replica 0 — the legacy path
        let replica = if self.router.replicas().len() > 1 {
            let key = Session::probe_key(&self.cfg, &self.manifest)?;
            self.router.place(&key)
        } else {
            0
        };
        let scheduler = self.router.replicas()[replica].scheduler();
        let mut session = Session::with_parts(
            id,
            prompt,
            &self.cfg,
            &self.manifest,
            Some(Arc::clone(scheduler.pool())),
            self.router.prefix_index().cloned(),
        )?;
        if let Some(tx) = frames {
            session.set_stream(tx);
        }
        if session.admission_bytes() > scheduler.pool().capacity() {
            anyhow::bail!(
                "request {id}: admission demand {} B exceeds pool capacity {} B",
                session.admission_bytes(),
                scheduler.pool().capacity()
            );
        }
        let (tx, rx) = mpsc::channel();
        self.router.submit_to(replica, session, tx);
        Ok(RequestHandle { id, rx })
    }

    /// Submit many prompts and wait for all (batch experiments). A
    /// failed submit does **not** abandon the requests submitted before
    /// it: their sessions are already running against the pool and
    /// would send results into dropped receivers, so every prior handle
    /// is drained (awaited) before the submit error propagates.
    pub fn run_batch(&self, prompts: Vec<Vec<i32>>) -> Result<Vec<RequestResult>> {
        submit_then_drain(prompts, |p| self.submit(p), |h| h.wait())
    }

    pub fn inflight(&self) -> u64 {
        self.router.inflight()
    }

    /// Replica 0's KV block pool (memory accounting; per replica in a
    /// fleet — see [`Coordinator::router`] for the rest).
    pub fn pool(&self) -> &BlockPool {
        self.router.replicas()[0].scheduler().pool()
    }

    /// Fleet-merged scheduler + pool counters (admissions, preemptions,
    /// queue depth, pool used/peak/free, migrations), stamped with the
    /// configured retention-policy label so `stats` consumers see which
    /// arena served them.
    pub fn sched_stats(&self) -> SchedSnapshot {
        let mut snap = self.router.snapshot();
        snap.policy = self.cfg.policy_label();
        snap
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.router.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.router.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The submit-everything-then-await-everything body of
/// [`Coordinator::run_batch`], factored over closures so the drain
/// discipline is unit-testable without PJRT artifacts.
///
/// Invariants (the pre-fix code violated both):
/// * a failed submit stops submitting but still **awaits every handle
///   already submitted** — those sessions run to completion and their
///   receivers must outlive them — then propagates the submit error;
/// * a failed wait keeps draining the remaining handles (first wait
///   error wins) instead of dropping their receivers mid-flight.
fn submit_then_drain<H, R>(
    prompts: Vec<Vec<i32>>,
    mut submit: impl FnMut(Vec<i32>) -> Result<H>,
    mut wait: impl FnMut(H) -> Result<R>,
) -> Result<Vec<R>> {
    let mut handles = Vec::with_capacity(prompts.len());
    let mut submit_err = None;
    for p in prompts {
        match submit(p) {
            Ok(h) => handles.push(h),
            Err(e) => {
                submit_err = Some(e);
                break;
            }
        }
    }
    let mut results = Vec::with_capacity(handles.len());
    let mut wait_err = None;
    for h in handles {
        match wait(h) {
            Ok(r) => results.push(r),
            Err(e) => {
                if wait_err.is_none() {
                    wait_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = submit_err {
        return Err(e);
    }
    if let Some(e) = wait_err {
        return Err(e);
    }
    Ok(results)
}

/// Split a fused step's measured wall time across its `n` members: the
/// integer share plus one extra nanosecond for the first `total % n`
/// members, so the per-session attributions **sum exactly** to the
/// measured fused time (plain `total / n` silently dropped up to
/// `n - 1` ns per step per batch).
fn per_member_ns(total: u64, n: usize) -> impl Iterator<Item = u64> {
    let n64 = n as u64;
    let base = total / n64;
    let rem = (total % n64) as usize;
    (0..n).map(move |i| base + u64::from(i < rem))
}

enum ChunkEnd {
    Yield,
    NeedMemory,
    Finished,
    Failed(String),
}

/// Hand one session back to the scheduler / submitter according to how
/// its chunk ended.
fn dispatch(scheduler: &Scheduler, mut item: Entry, end: ChunkEnd) {
    // one streaming frame per chunk boundary: tokens generated since
    // the last flush (no-op for non-streaming sessions; recompute
    // replay never re-sends — the flushed high-water mark survives)
    item.session.flush_stream();
    match end {
        ChunkEnd::Yield => scheduler.yield_back(item),
        ChunkEnd::NeedMemory => scheduler.cannot_grow(item),
        ChunkEnd::Finished => {
            let result = RequestResult::from_session(&item.session);
            let _ = item.done_tx.send(result);
            scheduler.complete(&mut item.session);
        }
        ChunkEnd::Failed(why) => {
            // the submitter must be able to tell a failed decode from
            // a short answer, and stats must not count it as success
            let mut result = RequestResult::from_session(&item.session);
            result.error = Some(why);
            let _ = item.done_tx.send(result);
            scheduler.complete_failed(&mut item.session);
        }
    }
}

/// Advance a decode batch by up to `chunk` steps, one fused
/// [`DecodeEngine::decode_batch`] call per step, then hand every member
/// back to the scheduler (yield / preempt-retry / complete / fail).
///
/// Each step runs in three phases:
///
/// 1. **prepare** — with chunked prefill enabled, a member still owing
///    prompt tokens advances its prefill by **one chunk**
///    ([`Session::advance_prefill`], the batch's single prefill lane)
///    and sits out this step's fused decode; every other member runs
///    [`Session::begin_step`] (swap-in restore, growth reservation,
///    ring-buffer flush — plus the inline whole-prompt prefill when
///    chunking is off). Members that finish, fail, or cannot grow leave
///    the batch immediately so their bytes / results are released
///    mid-chunk.
/// 2. **fused decode** — one engine call covers every prepared member
///    (`note_fused_step` records the batch size for the stats
///    histogram; `note_prefill_chunk` records whether a prefill chunk
///    rode along — the interleave counter).
/// 3. **absorb** — every member runs [`Session::finish_step`] on its
///    own output (classification, append, eviction, sampling).
///
/// This is the whole worker body behind [`Coordinator`]; it is public
/// so artifact-free harnesses (e.g. the batched-vs-sequential stream
/// invariance property test) can drive the exact production code path
/// with a deterministic [`DecodeEngine`].
pub fn advance_batch(
    scheduler: &Scheduler,
    engine: &dyn DecodeEngine,
    chunk: usize,
    batch: Vec<Entry>,
) {
    let prefill_chunk = scheduler.prefill_chunk_tokens();
    let mut members = batch;
    // bracket the whole chunk with the engine's PJRT ledger: every
    // execute this worker causes (fused decode, fallback members,
    // prefill chunks inside begin_step/advance_prefill) lands in the
    // scheduler's global counters exactly once
    let es0 = engine.exec_stats();
    for _ in 0..chunk.max(1) {
        if members.is_empty() {
            break;
        }
        // phase 1: prepare every member for the fused call
        let mut preps: Vec<Option<(i32, i32, i32)>> = Vec::with_capacity(members.len());
        let mut exits: Vec<(usize, ChunkEnd)> = Vec::new();
        let mut prefill_ran = false;
        for (i, m) in members.iter_mut().enumerate() {
            // prefill lane: one chunk per step, then sit this fused
            // call out; batch formation admits at most one such member
            if let Some(c) = prefill_chunk {
                if !m.session.prefill_done() {
                    match m.session.advance_prefill(engine, c) {
                        Ok(_done) => {
                            // done or not, this member decodes from the
                            // next step at the earliest
                            preps.push(None);
                            prefill_ran = true;
                        }
                        Err(e) => {
                            eprintln!("session {} failed: {e:#}", m.session.id);
                            m.session.finished_at = Some(std::time::Instant::now());
                            preps.push(None);
                            exits.push((i, ChunkEnd::Failed(format!("{e:#}"))));
                        }
                    }
                    continue;
                }
            }
            match m.session.begin_step(engine) {
                Ok(StepPrep::Ready { token, pos, buf_idx }) => {
                    preps.push(Some((token, pos, buf_idx)));
                }
                Ok(StepPrep::Finished) => {
                    preps.push(None);
                    exits.push((i, ChunkEnd::Finished));
                }
                Ok(StepPrep::NeedMemory) => {
                    preps.push(None);
                    exits.push((i, ChunkEnd::NeedMemory));
                }
                Err(e) => {
                    eprintln!("session {} failed: {e:#}", m.session.id);
                    m.session.finished_at = Some(std::time::Instant::now());
                    preps.push(None);
                    exits.push((i, ChunkEnd::Failed(format!("{e:#}"))));
                }
            }
        }
        if prefill_ran {
            // interleaved = a fused decode runs in this same step
            scheduler.note_prefill_chunk(preps.iter().any(|p| p.is_some()));
        }
        // phase 2: one fused engine call over every prepared member
        let fused = {
            let reqs: Vec<BatchDecodeReq> = members
                .iter()
                .zip(&preps)
                .filter_map(|(m, p)| {
                    p.map(|(token, pos, buf_idx)| BatchDecodeReq {
                        token,
                        pos,
                        buf_idx,
                        view: m.session.cache_view(),
                    })
                })
                .collect();
            if reqs.is_empty() {
                None
            } else {
                let n = reqs.len();
                let t0 = std::time::Instant::now();
                let outs = engine.decode_batch(&reqs);
                let ns = t0.elapsed().as_nanos() as u64;
                Some((outs, ns, n))
            }
        };
        // phase 3: absorb per member
        match fused {
            None => {}
            Some((result, ns, n)) => {
                // an engine that returns the wrong number of outputs is
                // as unattributable as one that errors — same path
                let result = result.and_then(|outs| {
                    if outs.len() == n {
                        Ok(outs)
                    } else {
                        Err(anyhow::anyhow!(
                            "fused decode returned {} outputs for {} requests",
                            outs.len(),
                            n
                        ))
                    }
                });
                match result {
                    Ok(outs) => {
                        scheduler.note_fused_step(n);
                        // remainder-distributed attribution: per-session
                        // shares sum exactly to the measured fused time
                        let mut shares = per_member_ns(ns, n);
                        let mut oi = 0;
                        for (i, (m, p)) in members.iter_mut().zip(&preps).enumerate() {
                            if p.is_none() {
                                continue;
                            }
                            let out = &outs[oi];
                            oi += 1;
                            m.session.breakdown.decode_exec_ns +=
                                shares.next().expect("one share per prepared member");
                            match m.session.finish_step(out, engine) {
                                Ok(StepOutcome::Running) => {}
                                Ok(StepOutcome::Finished) => exits.push((i, ChunkEnd::Finished)),
                                Ok(StepOutcome::NeedMemory) => {
                                    exits.push((i, ChunkEnd::NeedMemory));
                                }
                                Err(e) => {
                                    eprintln!("session {} failed: {e:#}", m.session.id);
                                    m.session.finished_at = Some(std::time::Instant::now());
                                    exits.push((i, ChunkEnd::Failed(format!("{e:#}"))));
                                }
                            }
                        }
                    }
                    Err(e) => {
                        // a failed fused call fails every member that was
                        // in it: per-member attribution is impossible once
                        // the engine errors, and silent retry would hide
                        // real breakage
                        eprintln!("fused decode step failed: {e:#}");
                        let why = format!("{e:#}");
                        for (i, (m, p)) in members.iter_mut().zip(&preps).enumerate() {
                            if p.is_some() {
                                m.session.finished_at = Some(std::time::Instant::now());
                                exits.push((i, ChunkEnd::Failed(why.clone())));
                            }
                        }
                    }
                }
            }
        }
        // SLO bookkeeping: sync the scheduler clock to the engine's
        // deterministic time (when it meters one), then stamp the
        // first-token tick of every member that just produced its first
        // token — exited members are still present here, so a session
        // finishing this very step gets stamped before dispatch
        if let Some(t) = engine.logical_now() {
            scheduler.drive_clock(t);
        }
        for m in members.iter_mut() {
            if m.session.first_token_at.is_some() && m.session.slo.first_token_tick.is_none() {
                m.session.slo.first_token_tick = Some(scheduler.now_ticks());
            }
        }
        // retire exited members (highest index first so removals are
        // position-stable), releasing bytes/results mid-chunk
        exits.sort_by(|a, b| b.0.cmp(&a.0));
        for (i, end) in exits {
            let item = members.remove(i);
            dispatch(scheduler, item, end);
        }
    }
    scheduler.note_exec_stats(es0, engine.exec_stats());
    // chunk exhausted: everyone still running yields
    for item in members {
        dispatch(scheduler, item, ChunkEnd::Yield);
    }
}

fn worker_loop(scheduler: &Scheduler, engine: &Engine, chunk: usize, max_batch: usize) {
    while let Some(batch) = scheduler.next_batch(max_batch) {
        advance_batch(scheduler, engine, chunk, batch);
        // proactive idle swap-out (no-op unless --idle-swap-ticks set):
        // park idle sessions in host memory while we hold no batch
        scheduler.sweep_idle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::StepOutcome;
    use crate::coordinator::test_support::{tiny_cfg, tiny_manifest, FakeEngine};
    use std::time::{Duration, Instant};

    /// The first token comes from prefill logits (its latency is the
    /// ttft), so `tpot` must divide the post-ttft window by the n-1
    /// decode gaps — not by n (the pre-fix off-by-one, which understated
    /// tpot by (n-1)/n).
    #[test]
    fn tpot_divides_by_decode_gaps_not_token_count() {
        let man = tiny_manifest();
        let cfg = ServeConfig { max_new_tokens: 5, ..tiny_cfg() };
        let engine = FakeEngine::new(man.model.clone());
        let mut s = Session::new(1, vec![1, 2, 3], &cfg, &man).unwrap();
        loop {
            match s.step(&engine).unwrap() {
                StepOutcome::Finished => break,
                StepOutcome::Running => {}
                StepOutcome::NeedMemory => panic!("no pool bound"),
            }
        }
        assert_eq!(s.tokens.len(), 5);
        // pin the timeline: 100 ms total, 10 ms ttft -> 90 ms over 4 gaps
        let now = Instant::now();
        s.created = now - Duration::from_millis(100);
        s.first_token_at = Some(now - Duration::from_millis(90));
        s.finished_at = Some(now);
        let r = RequestResult::from_session(&s);
        let window = r.total_ms - r.ttft_ms;
        assert!(window > 80.0, "timeline pinned: {window}");
        assert!(
            (r.tpot_ms - window / 4.0).abs() < 1e-9,
            "5 tokens = 4 decode gaps: tpot {} vs window {}",
            r.tpot_ms,
            window
        );
        assert!(
            r.tpot_ms > window / 5.0 + 1.0,
            "must not divide by the token count"
        );

        // a single-token result degrades to the whole window, no panic
        let cfg1 = ServeConfig { max_new_tokens: 1, ..tiny_cfg() };
        let mut one = Session::new(2, vec![1], &cfg1, &man).unwrap();
        while !matches!(one.step(&engine).unwrap(), StepOutcome::Finished) {}
        assert_eq!(one.tokens.len(), 1);
        let r1 = RequestResult::from_session(&one);
        assert!(r1.tpot_ms >= 0.0);
    }

    /// Satellite regression: fused-step time attribution used plain
    /// `ns / n`, silently dropping up to `n - 1` ns per step per batch.
    /// The remainder-distributed shares must sum exactly to the
    /// measured time and differ by at most one nanosecond.
    #[test]
    fn fused_time_shares_sum_exactly() {
        for (total, n) in [(0u64, 1usize), (7, 3), (10, 4), (999_999_937, 6), (5, 8), (42, 42)] {
            let shares: Vec<u64> = per_member_ns(total, n).collect();
            assert_eq!(shares.len(), n);
            assert_eq!(shares.iter().sum::<u64>(), total, "total {total} over {n}");
            let max = *shares.iter().max().unwrap();
            let min = *shares.iter().min().unwrap();
            assert!(max - min <= 1, "shares must stay within 1 ns of each other");
            // truncation regression: the old `total / n` per member
            // summed to less than the measured time whenever n ∤ total
            if total % n as u64 != 0 {
                assert!(total / n as u64 * n as u64 < total);
            }
        }
    }

    /// Satellite regression: a failed submit mid-batch must drain the
    /// handles already submitted (their sessions keep running against
    /// the pool and must not send into dropped receivers) before the
    /// error propagates — and a failed wait must not drop later
    /// receivers either.
    #[test]
    fn run_batch_drains_submitted_handles_on_submit_failure() {
        use std::cell::RefCell;
        let waited = RefCell::new(Vec::new());
        let out = submit_then_drain(
            vec![vec![1], vec![2], vec![3], vec![4]],
            |p| {
                if p == vec![3] {
                    anyhow::bail!("pool too small")
                } else {
                    Ok(p[0])
                }
            },
            |h| {
                waited.borrow_mut().push(h);
                Ok(h)
            },
        );
        let err = out.expect_err("submit error must propagate");
        assert!(err.to_string().contains("pool too small"));
        assert_eq!(*waited.borrow(), vec![1, 2], "prior handles drained first");
        // prompt 4 was never submitted, so it is never awaited

        // wait errors drain everything and report the first failure
        let waited2 = RefCell::new(Vec::new());
        let out2 = submit_then_drain(
            vec![vec![1], vec![2], vec![3]],
            |p| Ok(p[0]),
            |h| {
                waited2.borrow_mut().push(h);
                if h == 2 {
                    anyhow::bail!("receiver gone")
                } else {
                    Ok(h)
                }
            },
        );
        assert!(out2.is_err());
        assert_eq!(*waited2.borrow(), vec![1, 2, 3], "every handle drained");

        // happy path unchanged
        let ok = submit_then_drain(vec![vec![5], vec![6]], |p| Ok(p[0]), |h| Ok(h + 10)).unwrap();
        assert_eq!(ok, vec![15, 16]);
    }
}
