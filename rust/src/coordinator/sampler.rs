//! Token sampling from decode-step logits (greedy / temperature / top-k).

use crate::util::rng::Rng;
use crate::util::stats::softmax;

#[derive(Debug, Clone)]
pub struct Sampler {
    pub temperature: f64,
    pub top_k: usize,
    rng: Rng,
}

impl Sampler {
    pub fn new(temperature: f64, top_k: usize, seed: u64) -> Sampler {
        Sampler { temperature, top_k, rng: Rng::new(seed) }
    }

    pub fn greedy(seed: u64) -> Sampler {
        Sampler::new(0.0, 0, seed)
    }

    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.temperature <= 0.0 {
            return crate::util::stats::argmax(logits) as i32;
        }
        let scaled: Vec<f32> = logits
            .iter()
            .map(|&x| x / self.temperature as f32)
            .collect();
        let mut probs = softmax(&scaled);
        if self.top_k > 0 && self.top_k < probs.len() {
            let top = crate::util::stats::top_k(&probs, self.top_k);
            let keep: std::collections::BTreeSet<usize> = top.into_iter().collect();
            for (i, p) in probs.iter_mut().enumerate() {
                if !keep.contains(&i) {
                    *p = 0.0;
                }
            }
        }
        let w: Vec<f64> = probs.iter().map(|&p| p as f64).collect();
        self.rng.weighted(&w) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::greedy(1);
        assert_eq!(s.sample(&[0.1, 5.0, 0.2]), 1);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut s = Sampler::new(1.0, 0, 2);
        let logits = vec![1.0f32, 1.0, 1.0, 1.0];
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&logits));
        }
        assert!(seen.len() >= 3, "{seen:?}");
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(1.0, 2, 3);
        let logits = vec![5.0f32, 4.9, -10.0, -10.0];
        for _ in 0..100 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "{t}");
        }
    }

    #[test]
    fn deterministic_with_seed() {
        let logits = vec![0.5f32, 1.0, 0.2, 3.0];
        let mut a = Sampler::new(0.9, 0, 7);
        let mut b = Sampler::new(0.9, 0, 7);
        for _ in 0..20 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }
}
