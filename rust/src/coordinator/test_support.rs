//! Shared fixtures for coordinator unit tests, re-using the crate-wide
//! [`crate::testkit`] causal engine fake and tiny manifest (so the
//! causality invariant prefix sharing relies on lives in exactly one
//! place), plus a coordinator-specific default serving config.

pub(crate) use crate::testkit::tiny_manifest;
pub(crate) use crate::testkit::CausalEngine as FakeEngine;

use super::config::{CompressionMode, ServeConfig};

pub(crate) fn tiny_cfg() -> ServeConfig {
    ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 64,
        max_new_tokens: 8,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    }
}
