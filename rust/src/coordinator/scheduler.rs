//! Memory-aware, preemptive request scheduler (the paper's Tables 2/3
//! serving discipline: admit until KV bytes run out, reclaim from the
//! youngest work when a running request must grow).
//!
//! Requests live in one of three places:
//!
//! * **waiting** — submitted but not admitted; their KV demand does not
//!   fit the [`BlockPool`] yet. FIFO, with preempted sessions re-queued
//!   at the front.
//! * **runnable** — admitted (their admission reserve is charged to the
//!   pool) and waiting for a decode worker.
//! * **held** — admitted and currently being advanced by a worker.
//!
//! (Plus **stalled**: admitted sessions starving for growth bytes whose
//! preemption victim is still held — parked until bytes free up.)
//!
//! Admission is byte-accurate: a session is admitted only when
//! [`Session::admission_bytes`] (an upper bound on its post-prefill
//! footprint) can be reserved; each decode step then pre-reserves its
//! worst-case growth and trues the reservation up afterwards, so
//! `pool.peak() <= pool.capacity()` always holds. When a running session
//! cannot grow ([`StepOutcome::NeedMemory`](super::session::StepOutcome)),
//! the **youngest admitted** session is preempted — its bytes released,
//! re-queued to waiting — so the oldest request always makes progress
//! and oversubscribed workloads drain instead of overflowing.
//! A session that cannot grow while it is the *only* admitted request
//! exceeds the pool by itself and is failed.
//!
//! **Preemption policy (swap vs recompute):** when the scheduler owns a
//! host-side [`SwapPool`], a preempted session first tries
//! [`Session::suspend_to`] — snapshot the compressed cache to host
//! memory and resume later with zero recompute steps. Only when the
//! snapshot does not fit the swap pool (or swapping is disabled) does
//! the session fall back to the recompute reset. Swapped sessions are
//! re-admitted with the *exact* device bytes recorded at suspend time,
//! so the pool stays byte-accurate across the round trip. The snapshot
//! copy itself runs **outside** the scheduler mutex
//! ([`Scheduler::cannot_grow`] / [`Scheduler::yield_back`] detach the
//! victim under the lock, then copy): a large fp32 swap-out must not
//! stall every worker for the duration of the memcpy.
//!
//! **Batch formation (cross-session batched decode):** workers pull a
//! *decode batch* via [`Scheduler::next_batch`] — the front runnable
//! session plus up to `max - 1` more whose
//! [`BatchKey`](crate::kvcache::BatchKey) matches (same compiled decode
//! executable), each extra member joining only after its worst-case
//! per-step growth is pre-reserved in the pool (the *growth bond*), so
//! one fused step can never over-commit the pool mid-batch. The bond is
//! credited to the member's reservation and trues up after its next
//! step.
//!
//! **Chunked prefill (stall-free batch formation):** with
//! [`Scheduler::set_prefill_chunking`] enabled, a decode batch carries
//! at most one not-yet-prefilled session and a per-step *token budget*
//! bounds what one fused step processes (decode members cost one token
//! each, the prefill chunk its length, Sarathi-style) — so a
//! long-prompt arrival advances chunk-by-chunk between its batch-mates'
//! decode steps instead of head-of-line-blocking the whole batch on an
//! inline whole-prompt prefill.
//!
//! **SLO-aware goodput policy ([`SchedPolicy::Goodput`]):** when
//! enabled, FIFO gives way to TTFT-deadline slack wherever ordering
//! matters — admission picks the tightest-slack waiter, batch formation
//! seeds each batch with the most urgent runnable session, preemption
//! prefers deadline-hopeless victims (and skips the swap-out copy for
//! them: the snapshot would be spent preserving progress for a request
//! that already lost), and terminating classed sessions are scored
//! against their [`SloTarget`](super::config::SloTarget) into global
//! and per-class goodput / violation books. The scheduler clock is
//! wall-clock milliseconds by default; a deterministic harness drives
//! it with [`Scheduler::drive_clock`] instead, so trace replays are
//! bit-reproducible from a seed.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar};
use std::time::Instant;

use crate::kvcache::{BatchKey, BlockPool, PrefixIndex, SwapPool};
use crate::metrics::{SchedSnapshot, SloClassSnap};
use crate::runtime::ExecStats;
use crate::sim::{GpuProfile, LrmProfile, ServingCost};
use crate::syncx::{rank, RankedMutex};

use super::engine_loop::RequestResult;
use super::session::Session;

/// Which objective admission, batch formation, and preemption steer
/// toward.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Throughput-greedy FIFO everywhere (the pre-SLO behavior).
    #[default]
    Throughput,
    /// Goodput: order by TTFT-deadline slack, prefer deadline-hopeless
    /// preemption victims, and skip the swap copy for them.
    Goodput,
}

/// Per-tenant-class SLO ledger: verdict counts plus raw latency
/// samples, reduced to percentiles at snapshot time.
#[derive(Default)]
struct ClassBook {
    name: String,
    goodput: u64,
    violations: u64,
    ttft: Vec<u64>,
    tpot_milli: Vec<u64>,
}

/// Nearest-rank percentile over an already-sorted sample: element
/// `⌈p·n/100⌉ − 1`, or 0 on an empty sample.
fn pct(sorted: &[u64], p: usize) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    sorted[((sorted.len() * p + 99) / 100).max(1) - 1]
}

/// Deadline-slack ordering key: urgent targeted sessions first (by
/// ascending TTFT slack), then untargeted / already-served ones (FIFO
/// by queue index), deadline-hopeless ones last.
fn slack_key(s: &Session, now: u64, idx: usize) -> (u8, i64, usize) {
    match s.slo.ttft_slack(now) {
        Some(sl) if sl < 0 => (2, sl, idx),
        Some(sl) => (0, sl, idx),
        None => (1, 0, idx),
    }
}

/// One scheduled request: the session plus its completion channel.
pub struct Entry {
    pub session: Session,
    pub done_tx: mpsc::Sender<RequestResult>,
}

struct Inner {
    waiting: VecDeque<Entry>,
    runnable: VecDeque<Entry>,
    /// Starving sessions parked while their preempt-marked victim is
    /// still held by a worker — re-queued to runnable as soon as any
    /// bytes come back (prevents a busy retry loop through `next`).
    stalled: VecDeque<Entry>,
    /// Admitted session id -> admission sequence number (age order).
    admitted: BTreeMap<u64, u64>,
    /// Admitted ids currently held by a decode worker.
    held: BTreeSet<u64>,
    /// Held ids asked to vacate at their next yield (preemption marks).
    preempt_marks: BTreeSet<u64>,
    /// Admitted ids whose last step could not reserve KV growth. While
    /// any session is starving, admission is paused so freed bytes reach
    /// the starving session instead of bouncing its victim straight back
    /// in (which would ping-pong preemptions forever).
    starving: BTreeSet<u64>,
    /// Preemptions in flight: victims already detached from `admitted`
    /// whose snapshot copy is still running outside the lock, so their
    /// pool bytes have not come back yet. While non-zero, a session
    /// that finds itself "alone" in the pool parks instead of failing —
    /// the in-flight victim's bytes (and its unstall) are guaranteed to
    /// arrive.
    pending_preempts: usize,
    next_admit_seq: u64,
}

impl Inner {
    /// Drop every piece of tracking state for a session that is leaving
    /// the admitted set (completion, failure, or preemption).
    fn forget(&mut self, id: u64) {
        self.held.remove(&id);
        self.admitted.remove(&id);
        self.preempt_marks.remove(&id);
        self.starving.remove(&id);
    }

    /// Pool bytes were just released: stalled sessions get to retry
    /// (ahead of anything already runnable).
    fn unstall(&mut self) {
        while let Some(entry) = self.stalled.pop_back() {
            self.runnable.push_front(entry);
        }
    }
}

/// Decode-batch sizes above this all land in the last histogram bucket.
pub(crate) const BATCH_HIST_BUCKETS: usize = 16;

/// Lane starvation bound: after this many consecutive batches seeded
/// off the FIFO front (because a wider lane existed elsewhere), the
/// front entry's lane is forced regardless of width, so a lone session
/// in a narrow lane is never starved by a perpetually-wide one.
const LANE_SKIP_BOUND: u64 = 4;

/// Resume-ordering starvation bound: a preempted session that has
/// waited this many scheduler ticks is never jumped by a cheaper
/// resume, regardless of its modeled restore cost.
pub(crate) const RESUME_AGE_BOUND_TICKS: u64 = 250;

pub struct Scheduler {
    pool: Arc<BlockPool>,
    /// Host-side pool for suspend-to-host preemption; `None` = every
    /// preemption recomputes (PR 1 behavior).
    swap: Option<Arc<SwapPool>>,
    /// Cross-session prefix index; `None` = no sharing. Owned here so
    /// admission pressure can reclaim *unreferenced* resident prefixes
    /// before refusing admission or preempting a live session —
    /// eviction/preemption never reclaims a prefix any session (running
    /// or suspended) still references.
    prefix: Option<Arc<PrefixIndex>>,
    /// The scheduler's one big lock, ranked [`rank::SCHED_INNER`] —
    /// the *lowest* rank in the crate's lock hierarchy, because the
    /// admission / finish / CoW-drain paths take every other lock
    /// (prefix trie root, residency cells, SLO book) while holding it.
    inner: RankedMutex<Inner>,
    cv: Condvar,
    stop: AtomicBool,
    inflight: AtomicU64,
    admissions: AtomicU64,
    preemptions: AtomicU64,
    completions: AtomicU64,
    failures: AtomicU64,
    /// Fused decode steps executed (one engine call per batch per step).
    fused_steps: AtomicU64,
    /// Session-steps advanced by fused calls (sum of batch sizes).
    fused_sessions: AtomicU64,
    /// Histogram of decode-batch sizes: bucket `i` counts fused steps
    /// whose batch held `i + 1` sessions (last bucket absorbs larger).
    batch_hist: [AtomicU64; BATCH_HIST_BUCKETS],
    /// Chunked-prefill policy: tokens one prefill chunk advances per
    /// fused step (0 = disabled, whole-prompt prefill inside the first
    /// decode step — the pre-chunking behavior).
    prefill_chunk_tokens: AtomicUsize,
    /// Per-fused-step token budget for batch formation: decode members
    /// cost one token each, a prefill chunk its token count (0 = auto:
    /// chunk tokens + batch cap, which never refuses a decode member).
    step_token_budget: AtomicUsize,
    /// Prefill chunks executed by workers (chunked mode only).
    prefill_chunks: AtomicU64,
    /// Fused steps that advanced decode members and a prefill chunk in
    /// the same step (the stall-free interleave).
    prefill_interleaved: AtomicU64,
    /// Actual PJRT decode executes, diffed from the engines' ledgers by
    /// the workers (fused batch = 1; per-member fallback = 1 each).
    pjrt_decode_execs: AtomicU64,
    /// PJRT prefill executes (whole-prompt + per-chunk).
    pjrt_prefill_execs: AtomicU64,
    /// Decode executes that took the counted per-member fallback.
    pjrt_fallback_execs: AtomicU64,
    /// Engine prefill-memo hits (chunk served with no execute).
    prefill_memo_hits: AtomicU64,
    /// Engine prefill-memo / chunk-state LRU evictions.
    prefill_memo_evicts: AtomicU64,
    /// [`SchedPolicy::Goodput`] flag: deadline-slack ordering replaces
    /// FIFO when set.
    goodput_mode: AtomicBool,
    /// Epoch for the wall-clock tick source (milliseconds since
    /// construction) used until a logical clock drives the scheduler.
    epoch: Instant,
    /// Deterministic logical clock, advanced monotonically by
    /// [`Scheduler::drive_clock`]; once any drive has happened it
    /// replaces the wall clock as the tick source for good.
    clock: AtomicU64,
    /// True once `drive_clock` ran (the run is on logical time).
    logical: AtomicBool,
    /// Retention-arena counters folded from each session's backend at
    /// termination ([`Session::retention`]): positions the live policy
    /// evicted, positions it never materialized (SkipKV axis), and the
    /// bytes still retained when the session finished.
    policy_evictions: AtomicU64,
    policy_skips: AtomicU64,
    policy_retained_bytes: AtomicU64,
    /// Classed sessions that terminated meeting their SLO target.
    goodput: AtomicU64,
    /// Classed sessions that terminated missing it (failures included).
    slo_violations: AtomicU64,
    /// Per-class goodput/violation counts and latency samples. Ranked
    /// [`rank::SLO_BOOK`]: `note_slo_outcome` takes it while holding
    /// the scheduler lock (finish path), never the other way around.
    slo_book: RankedMutex<Vec<ClassBook>>,
    /// Serving-time cost model pricing the swap-vs-recompute resume
    /// ordering (satellite of the replica tier; fixed A100 anchor).
    cost: ServingCost,
    /// High-water mark of the widest per-`BatchKey` runnable lane seen
    /// during batch formation.
    lane_peak: AtomicU64,
    /// Batches whose seed jumped off the FIFO front to a wider lane.
    lane_switches: AtomicU64,
    /// Consecutive batches that skipped the FIFO front's lane (bounded
    /// by [`LANE_SKIP_BOUND`]).
    lane_skip_run: AtomicU64,
    /// Proactive idle swap-out threshold in scheduler ticks (0 = off).
    idle_swap_ticks: AtomicU64,
    /// Sessions proactively suspended by [`Scheduler::sweep_idle`].
    idle_swapouts: AtomicU64,
}

impl Scheduler {
    pub fn new(pool: Arc<BlockPool>) -> Scheduler {
        Scheduler::with_swap(pool, None)
    }

    /// A scheduler whose preemptions suspend to `swap` when the victim's
    /// cache snapshot fits, recomputing otherwise.
    pub fn with_swap(pool: Arc<BlockPool>, swap: Option<Arc<SwapPool>>) -> Scheduler {
        Scheduler::with_prefix(pool, swap, None)
    }

    /// [`Scheduler::with_swap`] plus a cross-session prefix index (must
    /// account against the same `pool`).
    pub fn with_prefix(
        pool: Arc<BlockPool>,
        swap: Option<Arc<SwapPool>>,
        prefix: Option<Arc<PrefixIndex>>,
    ) -> Scheduler {
        Scheduler {
            pool,
            swap,
            prefix,
            inner: RankedMutex::new(
                &rank::SCHED_INNER,
                Inner {
                    waiting: VecDeque::new(),
                    runnable: VecDeque::new(),
                    stalled: VecDeque::new(),
                    admitted: BTreeMap::new(),
                    held: BTreeSet::new(),
                    preempt_marks: BTreeSet::new(),
                    starving: BTreeSet::new(),
                    pending_preempts: 0,
                    next_admit_seq: 0,
                },
            ),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            admissions: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            completions: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            fused_steps: AtomicU64::new(0),
            fused_sessions: AtomicU64::new(0),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            prefill_chunk_tokens: AtomicUsize::new(0),
            step_token_budget: AtomicUsize::new(0),
            prefill_chunks: AtomicU64::new(0),
            prefill_interleaved: AtomicU64::new(0),
            pjrt_decode_execs: AtomicU64::new(0),
            pjrt_prefill_execs: AtomicU64::new(0),
            pjrt_fallback_execs: AtomicU64::new(0),
            prefill_memo_hits: AtomicU64::new(0),
            prefill_memo_evicts: AtomicU64::new(0),
            policy_evictions: AtomicU64::new(0),
            policy_skips: AtomicU64::new(0),
            policy_retained_bytes: AtomicU64::new(0),
            goodput_mode: AtomicBool::new(false),
            epoch: Instant::now(),
            clock: AtomicU64::new(0),
            logical: AtomicBool::new(false),
            goodput: AtomicU64::new(0),
            slo_violations: AtomicU64::new(0),
            slo_book: RankedMutex::new(&rank::SLO_BOOK, Vec::new()),
            cost: ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::r1_llama_8b()),
            lane_peak: AtomicU64::new(0),
            lane_switches: AtomicU64::new(0),
            lane_skip_run: AtomicU64::new(0),
            idle_swap_ticks: AtomicU64::new(0),
            idle_swapouts: AtomicU64::new(0),
        }
    }

    /// Switch the scheduling objective (default
    /// [`SchedPolicy::Throughput`] — the pre-SLO FIFO behavior).
    pub fn set_policy(&self, policy: SchedPolicy) {
        self.goodput_mode.store(policy == SchedPolicy::Goodput, Ordering::SeqCst);
    }

    /// The active scheduling objective.
    pub fn policy(&self) -> SchedPolicy {
        if self.goodput_policy() {
            SchedPolicy::Goodput
        } else {
            SchedPolicy::Throughput
        }
    }

    fn goodput_policy(&self) -> bool {
        self.goodput_mode.load(Ordering::SeqCst)
    }

    /// Advance the deterministic logical clock (monotonic `fetch_max`).
    /// The first drive switches the scheduler's tick source from
    /// wall-clock milliseconds to this clock permanently — mixing the
    /// two would break bit-reproducible replays.
    pub fn drive_clock(&self, ticks: u64) {
        self.clock.fetch_max(ticks, Ordering::SeqCst);
        self.logical.store(true, Ordering::SeqCst);
    }

    /// Current scheduler time in ticks: the logical clock when driven,
    /// wall-clock milliseconds since construction otherwise.
    pub fn now_ticks(&self) -> u64 {
        if self.logical.load(Ordering::SeqCst) {
            self.clock.load(Ordering::SeqCst)
        } else {
            self.epoch.elapsed().as_millis() as u64
        }
    }

    /// The deterministic clock value when this scheduler is on logical
    /// time, `None` while it still runs on wall clock. The router uses
    /// this to carry a migrating session's SLO clock to the destination
    /// replica without ever mixing tick sources.
    pub fn logical_clock(&self) -> Option<u64> {
        if self.logical.load(Ordering::SeqCst) {
            Some(self.clock.load(Ordering::SeqCst))
        } else {
            None
        }
    }

    /// Enable proactive idle swap-out: a prefilled runnable session not
    /// pulled by any worker for `ticks` scheduler ticks is suspended to
    /// the swap pool by [`Scheduler::sweep_idle`] before pool pressure
    /// forces a preemption. 0 disables (the default). No-op without a
    /// swap pool.
    pub fn set_idle_swap(&self, ticks: u64) {
        self.idle_swap_ticks.store(ticks, Ordering::SeqCst);
    }

    /// Enable Sarathi-style chunked prefill: each decode batch carries
    /// **at most one** not-yet-prefilled session, whose prompt advances
    /// `tokens` per fused step interleaved with its batch-mates' decode
    /// (instead of one inline whole-prompt prefill head-of-line-blocking
    /// the batch). `budget` caps the total tokens one fused step may
    /// process — decode members cost 1 each, the prefill chunk its
    /// length; pass 0 for the non-binding default (`tokens` + batch
    /// cap). `tokens == 0` disables chunking.
    pub fn set_prefill_chunking(&self, tokens: usize, budget: usize) {
        self.prefill_chunk_tokens.store(tokens, Ordering::SeqCst);
        self.step_token_budget.store(budget, Ordering::SeqCst);
    }

    /// Tokens per prefill chunk; `None` = chunking disabled.
    pub fn prefill_chunk_tokens(&self) -> Option<usize> {
        match self.prefill_chunk_tokens.load(Ordering::SeqCst) {
            0 => None,
            n => Some(n),
        }
    }

    /// The per-fused-step token budget batch formation enforces.
    fn token_budget(&self, max_batch: usize) -> usize {
        match self.step_token_budget.load(Ordering::SeqCst) {
            0 => match self.prefill_chunk_tokens() {
                // auto: one chunk plus a full decode batch always fits
                Some(c) => c.saturating_add(max_batch),
                None => usize::MAX,
            },
            b => b,
        }
    }

    /// Record one prefill chunk run by a worker; `interleaved` = the
    /// same fused step also advanced decode members.
    pub fn note_prefill_chunk(&self, interleaved: bool) {
        self.prefill_chunks.fetch_add(1, Ordering::SeqCst);
        if interleaved {
            self.prefill_interleaved.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// What one scheduling slot of `s` costs the per-step token budget:
    /// a decode step is one token; a prefill chunk costs the tokens it
    /// will actually advance.
    fn step_cost(&self, s: &Session) -> usize {
        if s.prefill_done() {
            return 1;
        }
        match self.prefill_chunk_tokens() {
            Some(c) => c.min(s.prefill_remaining()).max(1),
            // chunking off: the member whole-prompt-prefills inline on
            // its first step (pre-chunking behavior, budget-exempt)
            None => 1,
        }
    }

    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// The host-side swap pool, when suspend-to-host is enabled.
    pub fn swap_pool(&self) -> Option<&Arc<SwapPool>> {
        self.swap.as_ref()
    }

    /// The cross-session prefix index, when sharing is enabled.
    pub fn prefix_index(&self) -> Option<&Arc<PrefixIndex>> {
        self.prefix.as_ref()
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Enqueue a request; it is admitted as soon as its KV demand fits.
    /// Stamps the session's SLO submission tick — TTFT slack is
    /// measured from here, queueing time included.
    pub fn submit(&self, mut session: Session, done_tx: mpsc::Sender<RequestResult>) {
        session.slo.submitted_at = self.now_ticks();
        session.last_ran_tick = session.slo.submitted_at;
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let mut inner = self.inner.lock();
        inner.waiting.push_back(Entry { session, done_tx });
        self.try_admit(&mut inner);
        self.cv.notify_all();
    }

    /// Re-enqueue a session migrated from another replica. Identical to
    /// [`Scheduler::submit`] except that the SLO submission stamp is
    /// **preserved** (the request's TTFT clock started on the source
    /// replica) and the session joins the cost-ordered resume region at
    /// the front of the waiting line rather than the FIFO tail — it was
    /// already admitted once and carries restorable progress.
    pub fn resubmit(&self, mut session: Session, done_tx: mpsc::Sender<RequestResult>) {
        session.last_ran_tick = self.now_ticks();
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let mut inner = self.inner.lock();
        self.requeue_resume(&mut inner, Entry { session, done_tx });
        self.try_admit(&mut inner);
        self.cv.notify_all();
    }

    /// Admit waiting sessions while their admission reserve fits — FIFO
    /// under the throughput policy, tightest-TTFT-slack first under
    /// goodput (hopeless and untargeted waiters admit last). Paused
    /// while any admitted session is starving for growth bytes.
    fn try_admit(&self, inner: &mut Inner) {
        if !inner.starving.is_empty() {
            return;
        }
        let goodput = self.goodput_policy();
        loop {
            let pick = if goodput && inner.waiting.len() > 1 {
                let now = self.now_ticks();
                (0..inner.waiting.len())
                    .min_by_key(|&i| slack_key(&inner.waiting[i].session, now, i))
                    .expect("waiting is non-empty")
            } else {
                0
            };
            let Some(cand) = inner.waiting.get(pick) else { break };
            let need = cand.session.admission_bytes();
            let lease = self.pool.lease(need).or_else(|| {
                // before refusing: reclaim resident prefixes no session
                // references any more, then retry once
                let reclaimed = self
                    .prefix
                    .as_ref()
                    .map_or(0, |p| p.reclaim_unreferenced(need.saturating_sub(self.pool.free())));
                if reclaimed == 0 {
                    None
                } else {
                    self.pool.lease(need)
                }
            });
            let Some(lease) = lease else { break };
            let mut entry = inner.waiting.remove(pick).expect("index valid");
            entry.session.grant(lease);
            entry.session.resume_cost_ns = None;
            let seq = inner.next_admit_seq;
            inner.next_admit_seq += 1;
            inner.admitted.insert(entry.session.id, seq);
            inner.runnable.push_back(entry);
            self.admissions.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Blocking pull of the next runnable session; `None` on shutdown.
    /// Equivalent to a singleton [`Scheduler::next_batch`] pull (no
    /// growth bond is taken for the front session).
    pub fn next(&self) -> Option<Entry> {
        self.next_batch(1).map(|mut batch| batch.pop().expect("batch is non-empty"))
    }

    /// Blocking pull of a **decode batch**: the front runnable session
    /// plus up to `max - 1` more compatible ones
    /// ([`Session::compat_key`] — same compiled decode executable), so
    /// a worker can advance them all with one fused
    /// [`crate::runtime::DecodeEngine::decode_batch`] call per step.
    /// `None` on shutdown.
    ///
    /// Every *extra* member joins only after its worst-case per-step
    /// growth ([`Session::step_headroom_bytes`]) has been reserved in
    /// the pool — the batch **growth bond**. The bond is credited to
    /// the member's reservation (and trues up after its next step), so
    /// batch formation never over-commits the pool: a fused step's
    /// growth is fully paid for before the engine call. When a bond
    /// cannot be reserved the batch simply stops growing; the leftover
    /// sessions stay runnable for other workers.
    ///
    /// With chunked prefill enabled ([`Scheduler::set_prefill_chunking`])
    /// batch formation is Sarathi-style: each batch carries **at most
    /// one** not-yet-prefilled session (the prefill lane), and members
    /// join only while the per-step **token budget** holds — decode
    /// members cost one token, the prefill chunk its length — so a fused
    /// step's engine time is bounded by design and TPOT of running
    /// members stays flat while a long prompt prefills.
    ///
    /// Preempt-marked sessions are never pulled *into* a batch as extra
    /// members — they are about to vacate their bytes.
    pub fn next_batch(&self, max: usize) -> Option<Vec<Entry>> {
        let max = max.max(1);
        let chunked = self.prefill_chunk_tokens().is_some();
        let goodput = self.goodput_policy();
        let budget = self.token_budget(max);
        let mut inner = self.inner.lock();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return None;
            }
            self.try_admit(&mut inner);
            // goodput: seed the batch with the most urgent runnable
            // session (tightest TTFT slack) instead of the FIFO front;
            // hopeless sessions sort last, so salvageable deadlines run
            // ahead of already-lost ones
            if goodput && inner.runnable.len() > 1 {
                let now = self.now_ticks();
                let best = (0..inner.runnable.len())
                    .min_by_key(|&i| slack_key(&inner.runnable[i].session, now, i))
                    .expect("runnable is non-empty");
                if best != 0 {
                    let urgent = inner.runnable.remove(best).expect("index valid");
                    inner.runnable.push_front(urgent);
                }
            }
            // Per-`BatchKey` lanes: tally runnable width per compat key
            // and, under the throughput policy, seed the batch from the
            // *widest* lane (ties go to the FIFO front's lane) instead
            // of blindly from the front — a lone fp32 session can no
            // longer cap batch width for a quant-heavy queue. A skip
            // run longer than [`LANE_SKIP_BOUND`] forces the front's
            // lane so narrow lanes are bounded-starved, not starved.
            // Goodput mode keeps its slack-ordered seed (urgency beats
            // width) but still feeds the lane gauges.
            if inner.runnable.len() > 1 {
                // (key, width, first index) in front-to-back order, so
                // widths[0] is always the front entry's lane
                let mut widths: Vec<(BatchKey, usize, usize)> = Vec::new();
                for (i, e) in inner.runnable.iter().enumerate() {
                    let k = e.session.compat_key();
                    match widths.iter_mut().find(|(wk, _, _)| *wk == k) {
                        Some((_, n, _)) => *n += 1,
                        None => widths.push((k, 1, i)),
                    }
                }
                let widest = widths.iter().map(|w| w.1).max().unwrap_or(1);
                self.lane_peak.fetch_max(widest as u64, Ordering::SeqCst);
                if !goodput && widths.len() > 1 {
                    let skips = self.lane_skip_run.load(Ordering::SeqCst);
                    if widths[0].1 < widest && skips < LANE_SKIP_BOUND {
                        let lead = widths
                            .iter()
                            .find(|w| w.1 == widest)
                            .expect("a widest lane exists")
                            .2;
                        let seed = inner.runnable.remove(lead).expect("index valid");
                        inner.runnable.push_front(seed);
                        self.lane_skip_run.store(skips + 1, Ordering::SeqCst);
                        self.lane_switches.fetch_add(1, Ordering::SeqCst);
                    } else {
                        self.lane_skip_run.store(0, Ordering::SeqCst);
                    }
                }
            } else if inner.runnable.len() == 1 {
                self.lane_peak.fetch_max(1, Ordering::SeqCst);
            }
            if let Some(first) = inner.runnable.pop_front() {
                inner.held.insert(first.session.id);
                let key = first.session.compat_key();
                // the front session always runs (its cost can exceed the
                // budget but never starves it out of a batch)
                let mut has_prefill = chunked && !first.session.prefill_done();
                let mut tokens_used = self.step_cost(&first.session);
                let mut batch = vec![first];
                // single forward scan (the lock is held): skip
                // incompatible / preempt-marked / over-budget sessions,
                // pull each eligible one as soon as its bond is
                // reserved. While any session is starving, freed bytes
                // must reach it — don't capture them as growth bonds
                // (same gate as try_admit), so the batch stays a
                // singleton.
                let mut i = 0;
                while batch.len() < max && i < inner.runnable.len() && inner.starving.is_empty() {
                    let s = &inner.runnable[i].session;
                    if s.compat_key() != key || inner.preempt_marks.contains(&s.id) {
                        i += 1;
                        continue;
                    }
                    // one prefill lane per batch (Sarathi): a second
                    // unprefilled session waits for a later batch
                    if chunked && !s.prefill_done() && has_prefill {
                        i += 1;
                        continue;
                    }
                    let cost = self.step_cost(s);
                    if tokens_used.saturating_add(cost) > budget {
                        i += 1;
                        continue;
                    }
                    let Some(bond) = self.pool.lease(s.step_headroom_bytes()) else {
                        break;
                    };
                    let mut entry = inner.runnable.remove(i).expect("index valid");
                    entry.session.add_growth_bond(bond);
                    inner.held.insert(entry.session.id);
                    has_prefill |= chunked && !entry.session.prefill_done();
                    tokens_used += cost;
                    batch.push(entry);
                }
                return Some(batch);
            }
            inner = inner.wait_on(&self.cv);
        }
    }

    /// Fold a worker's engine-ledger delta (before/after one fused step
    /// or prefill chunk) into the global PJRT-execute counters.
    /// Saturating per field: worker engines are thread-local, so each
    /// delta is exact, but a restarted engine must not underflow.
    pub fn note_exec_stats(&self, before: ExecStats, after: ExecStats) {
        let d = |a: u64, b: u64| a.saturating_sub(b);
        self.pjrt_decode_execs
            .fetch_add(d(after.decode_executes, before.decode_executes), Ordering::SeqCst);
        self.pjrt_prefill_execs
            .fetch_add(d(after.prefill_executes, before.prefill_executes), Ordering::SeqCst);
        self.pjrt_fallback_execs
            .fetch_add(d(after.fallback_executes, before.fallback_executes), Ordering::SeqCst);
        self.prefill_memo_hits
            .fetch_add(d(after.prefill_memo_hits, before.prefill_memo_hits), Ordering::SeqCst);
        self.prefill_memo_evicts.fetch_add(
            d(after.prefill_memo_evictions, before.prefill_memo_evictions),
            Ordering::SeqCst,
        );
    }

    /// Record one fused decode step that advanced `batch` sessions.
    pub fn note_fused_step(&self, batch: usize) {
        if batch == 0 {
            return;
        }
        self.fused_steps.fetch_add(1, Ordering::SeqCst);
        self.fused_sessions.fetch_add(batch as u64, Ordering::SeqCst);
        let bucket = batch.min(BATCH_HIST_BUCKETS) - 1;
        self.batch_hist[bucket].fetch_add(1, Ordering::SeqCst);
    }

    /// Return a still-running session after a chunk of steps. Honors any
    /// pending preemption mark set while the worker held it (the
    /// snapshot copy runs after the scheduler lock is released).
    pub fn yield_back(&self, mut entry: Entry) {
        entry.session.last_ran_tick = self.now_ticks();
        let mut inner = self.inner.lock();
        inner.held.remove(&entry.session.id);
        // the session ran a full chunk, so it is no longer starving (a
        // still-starved step re-enters through cannot_grow instead)
        inner.starving.remove(&entry.session.id);
        if inner.preempt_marks.remove(&entry.session.id) {
            inner.forget(entry.session.id);
            inner.pending_preempts += 1;
            drop(inner);
            self.preempt_unlocked(entry);
            return;
        }
        inner.runnable.push_back(entry);
        self.try_admit(&mut inner);
        self.cv.notify_all();
    }

    /// A session's decode step could not reserve its KV growth. First
    /// reclaim unreferenced shared prefixes; if that frees anything the
    /// caller simply retries. Otherwise preempt the youngest admitted
    /// session (possibly the caller itself); fail the request outright
    /// if it is alone and still cannot grow.
    pub fn cannot_grow(&self, entry: Entry) {
        if let Some(p) = &self.prefix {
            // prefix cache yields before any live session is preempted
            // (only entries with zero refs are ever reclaimed)
            if p.reclaim_unreferenced(entry.session.step_headroom_bytes()) > 0 {
                let mut inner = self.inner.lock();
                inner.held.remove(&entry.session.id);
                inner.runnable.push_front(entry);
                self.cv.notify_all();
                return;
            }
        }
        let mut inner = self.inner.lock();
        inner.held.remove(&entry.session.id);
        let my_seq = *inner.admitted.get(&entry.session.id).expect("caller is admitted");
        let youngest = inner
            .admitted
            .iter()
            .filter(|(id, _)| **id != entry.session.id)
            .max_by_key(|(_, seq)| **seq)
            .map(|(id, seq)| (*id, *seq));
        // Goodput mode steers the choice toward a victim whose deadline
        // is already lost (or, failing that, the most slack to spare) —
        // but only among *younger* sessions reachable in the runnable /
        // stalled queues, so the oldest-always-progresses guarantee and
        // the held-victim mark path stay exactly as before.
        let victim = match (self.goodput_policy(), youngest) {
            (true, Some(_)) => self
                .goodput_victim(&inner, my_seq)
                .map(|vid| (vid, *inner.admitted.get(&vid).expect("victim admitted")))
                .or(youngest),
            (_, y) => y,
        };
        match victim {
            None if inner.pending_preempts == 0 => {
                // Alone in the pool and still out of memory: this single
                // request's KV demand exceeds the pool.
                self.fail(&mut inner, entry, "KV demand exceeds the block pool capacity");
                self.try_admit(&mut inner);
                self.cv.notify_all();
            }
            None => {
                // Looks alone, but a detached victim's snapshot copy is
                // still running outside the lock and its pool bytes are
                // about to come back: park instead of failing (the
                // copy's requeue unstalls us).
                inner.starving.insert(entry.session.id);
                inner.stalled.push_back(entry);
            }
            Some((vid, vseq)) if vseq > my_seq => {
                // Victim is younger than the caller: preempt it now if it
                // sits in the runnable or stalled queues, otherwise mark
                // it so its worker vacates it at the next chunk boundary.
                // Either way the caller parks in `stalled` until the
                // victim's bytes come back (the unstall wakes it first).
                inner.starving.insert(entry.session.id);
                inner.stalled.push_back(entry);
                if let Some(idx) = inner.runnable.iter().position(|e| e.session.id == vid) {
                    let victim = inner.runnable.remove(idx).expect("index valid");
                    inner.forget(vid);
                    inner.pending_preempts += 1;
                    drop(inner);
                    self.preempt_unlocked(victim);
                } else if let Some(idx) = inner.stalled.iter().position(|e| e.session.id == vid) {
                    // A stalled victim holds bytes and no worker, so a
                    // preemption mark would never be honored (marks are
                    // only checked at yield_back chunk boundaries, which
                    // a parked session never reaches) — two mutually
                    // starving sessions would livelock. Preempt it
                    // directly instead.
                    let victim = inner.stalled.remove(idx).expect("index valid");
                    inner.forget(vid);
                    inner.pending_preempts += 1;
                    drop(inner);
                    self.preempt_unlocked(victim);
                } else {
                    inner.preempt_marks.insert(vid);
                    self.cv.notify_all();
                }
            }
            _ => {
                // The caller is the youngest: vacate itself.
                inner.forget(entry.session.id);
                inner.pending_preempts += 1;
                drop(inner);
                self.preempt_unlocked(entry);
            }
        }
    }

    /// Vacate a session already detached from the admitted set and
    /// requeue it (front of the waiting line): suspend-to-host when the
    /// swap pool is present and the snapshot fits, recompute reset
    /// otherwise. Freed bytes wake any stalled (starving) sessions
    /// first.
    ///
    /// Runs **without** the scheduler mutex: the snapshot is a
    /// potentially large copy (an fp32 victim moves its whole live
    /// cache), and holding the lock across it would stall every worker
    /// for the duration. The caller owns `entry` exclusively — it is in
    /// no queue and not in `admitted` — so the only shared state the
    /// copy touches is the byte-atomic pools.
    fn preempt_unlocked(&self, mut entry: Entry) {
        // resume-cost inputs must be read before the suspend/reset
        // mutates them: the live device footprint prices the swap round
        // trip, the current position the recompute replay
        let live_bytes = entry.session.bytes_used().max(entry.session.admission_bytes());
        let replay_steps = entry.session.pos.max(1);
        // A deadline-hopeless victim under the goodput policy skips the
        // swap-out copy: host bytes and memcpy time would be spent
        // preserving progress for a request that already lost its SLO.
        let hopeless = self.goodput_policy() && entry.session.slo.hopeless(self.now_ticks());
        let swapped = match &self.swap {
            Some(sp) if !hopeless => entry.session.suspend_to(sp),
            _ => false,
        };
        if !swapped {
            entry.session.reset_for_preemption();
        }
        self.price_resume(&mut entry.session, live_bytes, replay_steps);
        self.preemptions.fetch_add(1, Ordering::SeqCst);
        let mut inner = self.inner.lock();
        inner.pending_preempts -= 1;
        self.requeue_resume(&mut inner, entry);
        inner.unstall();
        self.try_admit(&mut inner);
        self.cv.notify_all();
    }

    /// Stamp a vacated session's modeled resume cost —
    /// `min(`[`ServingCost::swap_roundtrip_ms`]`, `[`ServingCost::recompute_ms`]`)`
    /// in nanoseconds of modeled serving time — plus the tick it was
    /// vacated at, for the cost-ordered requeue's starvation age bound.
    pub(crate) fn price_resume(&self, session: &mut Session, live_bytes: u64, replay: usize) {
        let swap_ms = self.cost.swap_roundtrip_ms(live_bytes as f64);
        let rec_ms = self.cost.recompute_ms(1, live_bytes as f64, replay.max(1));
        session.resume_cost_ns = Some((swap_ms.min(rec_ms) * 1e6) as u64);
        session.preempted_at_tick = self.now_ticks();
    }

    /// Cost-ordered resume requeue (replaces the old unconditional
    /// `waiting.push_front`): vacated sessions form a contiguous region
    /// at the front of the waiting line, ordered by ascending modeled
    /// resume cost (`min(restore-bytes, recompute-steps)` serving time),
    /// always ahead of fresh arrivals. A resume that has already waited
    /// [`RESUME_AGE_BOUND_TICKS`] is never jumped by a cheaper one, so
    /// an expensive fp32 restore cannot be starved by a stream of cheap
    /// quant resumes.
    fn requeue_resume(&self, inner: &mut Inner, entry: Entry) {
        let my_cost = entry.session.resume_cost_ns.unwrap_or(0);
        let now = self.now_ticks();
        let mut idx = 0;
        while idx < inner.waiting.len() {
            let s = &inner.waiting[idx].session;
            // fresh arrivals (no resume cost) end the resume region
            let Some(c) = s.resume_cost_ns else { break };
            let aged = now.saturating_sub(s.preempted_at_tick) >= RESUME_AGE_BOUND_TICKS;
            if aged || c <= my_cost {
                idx += 1;
            } else {
                break;
            }
        }
        inner.waiting.insert(idx, entry);
    }

    /// Proactive idle swap-out sweep ([`Scheduler::set_idle_swap`]):
    /// suspend every prefilled runnable session that no worker has
    /// pulled for the configured number of ticks, releasing its device
    /// bytes to the pool *before* pressure forces a preemption — so
    /// admission and migration find free bytes instead of triggering
    /// preemption storms. Returns the number of sessions suspended.
    /// Swapped sessions rejoin the waiting line through the same
    /// cost-ordered resume region as preemption victims (they hold
    /// restorable progress), but count as `idle_swapouts`, not
    /// preemptions. Workers call this once per batch pull; deterministic
    /// harnesses call it explicitly.
    pub fn sweep_idle(&self) -> usize {
        let k = self.idle_swap_ticks.load(Ordering::SeqCst);
        let Some(swap) = self.swap.as_ref() else { return 0 };
        if k == 0 {
            return 0;
        }
        let now = self.now_ticks();
        let mut victims = Vec::new();
        {
            let mut inner = self.inner.lock();
            let mut i = 0;
            while i < inner.runnable.len() {
                let s = &inner.runnable[i].session;
                let idle = s.prefill_done()
                    && !s.is_suspended()
                    && !inner.preempt_marks.contains(&s.id)
                    && now.saturating_sub(s.last_ran_tick) >= k;
                if idle {
                    // detach but stay admitted until the copy succeeds;
                    // pending_preempts keeps the "alone -> fail" path
                    // parked while the copy runs outside the lock
                    let e = inner.runnable.remove(i).expect("index valid");
                    inner.pending_preempts += 1;
                    victims.push(e);
                } else {
                    i += 1;
                }
            }
        }
        let mut swapped = 0;
        for mut entry in victims {
            let live_bytes = entry.session.bytes_used().max(entry.session.admission_bytes());
            let replay_steps = entry.session.pos.max(1);
            if entry.session.suspend_to(swap) {
                swapped += 1;
                self.idle_swapouts.fetch_add(1, Ordering::SeqCst);
                self.price_resume(&mut entry.session, live_bytes, replay_steps);
                entry.session.last_ran_tick = self.now_ticks();
                let mut inner = self.inner.lock();
                inner.forget(entry.session.id);
                inner.pending_preempts -= 1;
                self.requeue_resume(&mut inner, entry);
                inner.unstall();
                self.try_admit(&mut inner);
                self.cv.notify_all();
            } else {
                // snapshot didn't fit: put it back exactly as it was
                // (still admitted, bytes untouched) — idle swap-out is
                // opportunistic and must never degrade to a recompute
                let mut inner = self.inner.lock();
                inner.pending_preempts -= 1;
                entry.session.last_ran_tick = self.now_ticks();
                inner.runnable.push_back(entry);
                inner.unstall();
                self.cv.notify_all();
            }
        }
        swapped
    }

    /// Detach one migratable session for the router: the youngest
    /// prefilled, unmarked runnable session (back of the queue — least
    /// progress at risk, and the FIFO front keeps its
    /// oldest-always-progresses guarantee). The entry leaves this
    /// scheduler's admitted set and inflight count but still holds its
    /// pool reservation; the router must either suspend it and
    /// [`Scheduler::resubmit`] it elsewhere (then call
    /// [`Scheduler::migration_release`] here so freed bytes wake
    /// stalled sessions), or hand it back via
    /// [`Scheduler::return_from_migration`]. `None` when nothing is
    /// safely migratable (empty queue, mid-prefill only, or starving
    /// sessions whose byte accounting a detach would race).
    pub fn take_for_migration(&self) -> Option<Entry> {
        let mut inner = self.inner.lock();
        if !inner.starving.is_empty() {
            return None;
        }
        let idx = inner.runnable.iter().rposition(|e| {
            e.session.prefill_done() && !inner.preempt_marks.contains(&e.session.id)
        })?;
        let entry = inner.runnable.remove(idx).expect("index valid");
        inner.forget(entry.session.id);
        inner.pending_preempts += 1;
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        Some(entry)
    }

    /// The source-side epilogue of a migration: the victim taken by
    /// [`Scheduler::take_for_migration`] has been suspended (its device
    /// bytes came back to this pool) and resubmitted on another
    /// replica. Wake stalled sessions and admit against the freed
    /// bytes.
    pub fn migration_release(&self) {
        let mut inner = self.inner.lock();
        inner.pending_preempts -= 1;
        inner.unstall();
        self.try_admit(&mut inner);
        self.cv.notify_all();
    }

    /// Abort a migration: re-admit the untouched victim exactly where
    /// it came from (back of runnable, still holding its reservation).
    pub fn return_from_migration(&self, entry: Entry) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let mut inner = self.inner.lock();
        inner.pending_preempts -= 1;
        let seq = inner.next_admit_seq;
        inner.next_admit_seq += 1;
        inner.admitted.insert(entry.session.id, seq);
        inner.runnable.push_back(entry);
        self.cv.notify_all();
    }

    /// Point-in-time per-`BatchKey` lane occupancy over the runnable
    /// queue, front-to-back — the router's least-loaded-lane placement
    /// input.
    pub fn lane_occupancy(&self) -> Vec<(BatchKey, usize)> {
        let inner = self.inner.lock();
        let mut widths: Vec<(BatchKey, usize)> = Vec::new();
        for e in inner.runnable.iter().chain(inner.stalled.iter()) {
            let k = e.session.compat_key();
            match widths.iter_mut().find(|(wk, _)| *wk == k) {
                Some((_, n)) => *n += 1,
                None => widths.push((k, 1)),
            }
        }
        widths
    }

    /// Total sessions queued or admitted (the router's load tiebreak).
    pub fn load(&self) -> usize {
        let inner = self.inner.lock();
        inner.waiting.len() + inner.runnable.len() + inner.stalled.len() + inner.held.len()
    }

    /// Goodput-mode preemption choice: among admitted sessions younger
    /// than `my_seq` that sit in the runnable or stalled queues (so
    /// they can be preempted directly), pick a deadline-hopeless one
    /// first (its SLO is already lost — evicting it costs no goodput),
    /// then an untargeted one, then the targeted one with the most
    /// TTFT slack to spare; age breaks ties (youngest first). `None`
    /// when no such session exists — the caller falls back to the
    /// youngest-by-age rule.
    fn goodput_victim(&self, inner: &Inner, my_seq: u64) -> Option<u64> {
        let now = self.now_ticks();
        let mut best: Option<(u8, i64, u64, u64)> = None; // (rank, slack, seq, id)
        for e in inner.runnable.iter().chain(inner.stalled.iter()) {
            let seq = match inner.admitted.get(&e.session.id) {
                Some(s) if *s > my_seq => *s,
                _ => continue,
            };
            let (rank, slack) = match e.session.slo.ttft_slack(now) {
                Some(s) if s < 0 => (0u8, s), // hopeless: preempt first
                None => (1, 0),               // no live TTFT deadline
                Some(s) => (2, s),
            };
            let better = match best {
                None => true,
                Some((br, bs, bq, _)) => {
                    rank < br
                        || (rank == br
                            && match rank {
                                0 => slack < bs, // most hopeless
                                2 => slack > bs, // most slack to spare
                                _ => seq > bq,   // youngest
                            })
                        || (rank == br && slack == bs && seq > bq)
                }
            };
            if better {
                best = Some((rank, slack, seq, e.session.id));
            }
        }
        best.map(|(_, _, _, id)| id)
    }

    /// Stamp a terminating session's finish tick and, when it carries a
    /// tenant class with a live target, score it: met-SLO terminations
    /// count toward goodput, everything else (hard failures included)
    /// toward violations — in the global pair and the per-class book
    /// together, so the class counts always sum to the global ones.
    fn note_slo_outcome(&self, session: &mut Session, failed: bool) {
        if session.slo.finished_tick.is_none() {
            session.slo.finished_tick = Some(self.now_ticks());
        }
        if !session.slo.classed() {
            return;
        }
        let met = !failed && session.slo.met(session.tokens.len()).unwrap_or(false);
        if met {
            self.goodput.fetch_add(1, Ordering::SeqCst);
        } else {
            self.slo_violations.fetch_add(1, Ordering::SeqCst);
        }
        let mut book = self.slo_book.lock();
        let idx = match book.iter().position(|c| c.name == session.slo.class) {
            Some(i) => i,
            None => {
                book.push(ClassBook { name: session.slo.class.clone(), ..ClassBook::default() });
                book.len() - 1
            }
        };
        let cb = &mut book[idx];
        if met {
            cb.goodput += 1;
        } else {
            cb.violations += 1;
        }
        if let Some(t) = session.slo.ttft() {
            cb.ttft.push(t);
        }
        if let Some(t) = session.slo.tpot_milli(session.tokens.len()) {
            cb.tpot_milli.push(t);
        }
    }

    /// Fold a terminating session's retention counters into the global
    /// tallies (before its pool release, while the backend's byte
    /// accounting is still live).
    fn fold_retention(&self, session: &Session) {
        let r = session.retention();
        self.policy_evictions.fetch_add(r.evicted, Ordering::SeqCst);
        self.policy_skips.fetch_add(r.skipped, Ordering::SeqCst);
        self.policy_retained_bytes.fetch_add(r.retained_bytes, Ordering::SeqCst);
    }

    /// Terminate a request with an error result.
    fn fail(&self, inner: &mut Inner, mut entry: Entry, why: &str) {
        inner.forget(entry.session.id);
        self.fold_retention(&entry.session);
        entry.session.release_pool();
        entry.session.finished_at = Some(std::time::Instant::now());
        self.note_slo_outcome(&mut entry.session, true);
        let mut result = RequestResult::from_session(&entry.session);
        result.error = Some(why.to_string());
        let _ = entry.done_tx.send(result);
        self.failures.fetch_add(1, Ordering::SeqCst);
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        inner.unstall();
    }

    fn finish(&self, session: &mut Session, counter: &AtomicU64, failed: bool) {
        let mut inner = self.inner.lock();
        inner.forget(session.id);
        self.fold_retention(session);
        session.release_pool();
        self.note_slo_outcome(session, failed);
        counter.fetch_add(1, Ordering::SeqCst);
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        inner.unstall();
        self.try_admit(&mut inner);
        self.cv.notify_all();
    }

    /// Bookkeeping for a successfully finished session (the worker sends
    /// the result).
    pub fn complete(&self, session: &mut Session) {
        self.finish(session, &self.completions, false);
    }

    /// Bookkeeping for a session that terminated with a decode error
    /// (the worker sends the error result) — counted as a failure, not a
    /// completion, so `stats` distinguishes the two.
    pub fn complete_failed(&self, session: &mut Session) {
        self.finish(session, &self.failures, true);
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// Point-in-time counters for metrics / the server `stats` command.
    pub fn snapshot(&self) -> SchedSnapshot {
        let swap = self.swap.as_ref().map(|s| s.stats()).unwrap_or_default();
        let pool_audit = self.pool.audit();
        let prefix = self.prefix.as_ref().map(|p| p.stats()).unwrap_or_default();
        // per-class books reduce to nearest-rank percentiles here so the
        // snapshot stays a flat, Eq-comparable value (the book lock is
        // released before the scheduler lock is taken — same order as
        // the finish path, never inverted)
        let slo_classes: Vec<SloClassSnap> = {
            let book = self.slo_book.lock();
            book.iter()
                .map(|c| {
                    let mut ttft = c.ttft.clone();
                    ttft.sort_unstable();
                    let mut tpot = c.tpot_milli.clone();
                    tpot.sort_unstable();
                    SloClassSnap {
                        name: c.name.clone(),
                        goodput: c.goodput,
                        violations: c.violations,
                        ttft_p50: pct(&ttft, 50),
                        ttft_p99: pct(&ttft, 99),
                        tpot_p50_milli: pct(&tpot, 50),
                        tpot_p99_milli: pct(&tpot, 99),
                    }
                })
                .collect()
        };
        let inner = self.inner.lock();
        // queued prefill work: sessions in any scheduler queue still
        // owing prompt tokens (held members are not visible here)
        let prefill_queue_depth = inner
            .waiting
            .iter()
            .chain(inner.runnable.iter())
            .chain(inner.stalled.iter())
            .filter(|e| !e.session.prefill_done())
            .count();
        // distinct per-`BatchKey` runnable lanes right now (gauge)
        let lanes = {
            let mut keys: Vec<BatchKey> = Vec::new();
            for e in inner.runnable.iter() {
                let k = e.session.compat_key();
                if !keys.contains(&k) {
                    keys.push(k);
                }
            }
            keys.len()
        };
        SchedSnapshot {
            pool_capacity: self.pool.capacity(),
            pool_used: self.pool.used(),
            pool_peak: self.pool.peak(),
            pool_free: self.pool.free(),
            pool_leases: pool_audit.live,
            pool_leased_bytes: pool_audit.leased,
            admissions: self.admissions.load(Ordering::SeqCst),
            preemptions: self.preemptions.load(Ordering::SeqCst),
            completions: self.completions.load(Ordering::SeqCst),
            rejections: self.failures.load(Ordering::SeqCst),
            queue_depth: inner.waiting.len(),
            running: inner.admitted.len(),
            inflight: self.inflight.load(Ordering::SeqCst),
            fused_steps: self.fused_steps.load(Ordering::SeqCst),
            fused_sessions: self.fused_sessions.load(Ordering::SeqCst),
            batch_hist: self.batch_hist.iter().map(|b| b.load(Ordering::SeqCst)).collect(),
            prefill_chunk_tokens: self.prefill_chunk_tokens.load(Ordering::SeqCst),
            prefill_chunks: self.prefill_chunks.load(Ordering::SeqCst),
            prefill_interleaved_steps: self.prefill_interleaved.load(Ordering::SeqCst),
            prefill_queue_depth,
            swap_capacity: swap.capacity,
            swap_used: swap.used,
            swap_peak: swap.peak,
            swap_outs: swap.swap_outs,
            swap_ins: swap.swap_ins,
            swap_bytes_out: swap.bytes_out,
            swap_bytes_in: swap.bytes_in,
            swap_restore_ns: swap.restore_ns,
            swap_fallbacks: swap.fallbacks,
            prefix_enabled: self.prefix.is_some(),
            prefix_hits: prefix.hits,
            prefix_misses: prefix.misses,
            prefix_inserts: prefix.inserts,
            prefix_publish_fails: prefix.publish_fails,
            prefix_cow_faults: prefix.cow_faults,
            prefix_cow_denied: prefix.cow_denied,
            prefix_reclaims: prefix.reclaims,
            prefix_resident_bytes: prefix.resident_bytes,
            prefix_resident_entries: prefix.resident_entries,
            prefix_alias_hits: prefix.alias_hits,
            prefix_alias_bytes: prefix.alias_bytes,
            pjrt_decode_executes: self.pjrt_decode_execs.load(Ordering::SeqCst),
            pjrt_prefill_executes: self.pjrt_prefill_execs.load(Ordering::SeqCst),
            pjrt_fallback_executes: self.pjrt_fallback_execs.load(Ordering::SeqCst),
            prefill_memo_hits: self.prefill_memo_hits.load(Ordering::SeqCst),
            prefill_memo_evictions: self.prefill_memo_evicts.load(Ordering::SeqCst),
            // the retention-policy label is config-scoped, not visible
            // here — `Coordinator::sched_stats` stamps it
            policy: String::new(),
            policy_evictions: self.policy_evictions.load(Ordering::SeqCst),
            policy_skips: self.policy_skips.load(Ordering::SeqCst),
            policy_retained_bytes: self.policy_retained_bytes.load(Ordering::SeqCst),
            sched_policy_goodput: self.goodput_policy(),
            goodput: self.goodput.load(Ordering::SeqCst),
            slo_violations: self.slo_violations.load(Ordering::SeqCst),
            slo_classes,
            lanes,
            lane_peak: self.lane_peak.load(Ordering::SeqCst),
            lane_switches: self.lane_switches.load(Ordering::SeqCst),
            idle_swapouts: self.idle_swapouts.load(Ordering::SeqCst),
            // replica-fleet counters live on the router; a bare
            // scheduler is a one-replica fleet that never migrates
            replicas: 1,
            migrations: 0,
            migration_bytes: 0,
            migration_ns: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{CompressionMode, ServeConfig, SloTarget};
    use crate::model::{Manifest, ModelConfig};

    /// Hand-built manifest: tiny dims, no artifact files needed (the
    /// scheduler never touches the engine).
    fn tiny_manifest() -> Manifest {
        Manifest {
            model: ModelConfig {
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 1,
                d_head: 16,
                d_ffn: 64,
                rope_base: 10000.0,
                buf_slots: 16,
                prefill_len: 32,
                obs_window: 8,
                group_size: 16,
            },
            quant_caps: vec![128],
            fp32_caps: vec![256],
            batch_widths: vec![],
            prefill_chunk_lens: vec![],
            micro_c: 128,
            golden_attn_c: 128,
            artifacts_dir: ".".into(),
            weights: vec![],
            seed: 0,
        }
    }

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            mode: CompressionMode::thinkv_default(),
            budget: 64,
            max_new_tokens: 8,
            workers: 1,
            temperature: 0.0,
            ..ServeConfig::default()
        }
    }

    fn mk_session(id: u64, cfg: &ServeConfig, man: &Manifest, pool: &Arc<BlockPool>) -> Session {
        Session::with_pool(id, vec![1, 2, 3], cfg, man, Some(Arc::clone(pool))).unwrap()
    }

    /// Oversubscribed submission: only as many sessions are admitted as
    /// the pool can hold; completions free bytes and admit the rest, and
    /// the pool never exceeds capacity.
    #[test]
    fn admission_queues_until_bytes_free() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        assert!(per > 0);
        // room for exactly two admission reserves
        let pool = Arc::new(BlockPool::new(2 * per + per / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        let (tx, rx) = mpsc::channel();
        for id in 1..=5u64 {
            sched.submit(mk_session(id, &cfg, &man, &pool), tx.clone());
        }
        let snap = sched.snapshot();
        assert_eq!(snap.running, 2, "admission must stop at pool capacity");
        assert_eq!(snap.queue_depth, 3);
        assert!(snap.pool_peak <= snap.pool_capacity);

        // drain: fake-finish each admitted session; the freed bytes admit
        // the next waiter
        let mut done = 0;
        while done < 5 {
            let mut entry = sched.next().expect("runnable session");
            entry.session.finished_at = Some(std::time::Instant::now());
            let _ = entry.done_tx.send(RequestResult::from_session(&entry.session));
            sched.complete(&mut entry.session);
            done += 1;
        }
        drop(tx);
        assert_eq!(rx.iter().count(), 5, "every request must complete");
        let snap = sched.snapshot();
        assert_eq!(snap.completions, 5);
        assert_eq!(snap.admissions, 5);
        assert_eq!(snap.pool_used, 0, "all bytes returned at quiescence");
        assert!(snap.pool_peak <= snap.pool_capacity);
    }

    /// cannot_grow preempts the youngest admitted session, pauses
    /// admission while the caller is starving, and resumes it once the
    /// caller gets its chunk in.
    #[test]
    fn preemption_evicts_youngest_first() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let pool = Arc::new(BlockPool::new(2 * per));
        let sched = Scheduler::new(Arc::clone(&pool));
        let (tx, _rx) = mpsc::channel();
        for id in 1..=3u64 {
            sched.submit(mk_session(id, &cfg, &man, &pool), tx.clone());
        }
        assert_eq!(sched.snapshot().running, 2, "pool fits two admissions");
        // oldest session (id 1) cannot grow -> youngest admitted (id 2)
        // is evicted, and its freed bytes are NOT handed to waiters while
        // the starved caller has not run again
        let entry = sched.next().expect("oldest session");
        assert_eq!(entry.session.id, 1);
        sched.cannot_grow(entry);
        let snap = sched.snapshot();
        assert_eq!(snap.preemptions, 1);
        assert_eq!(snap.running, 1, "victim no longer admitted");
        assert_eq!(snap.queue_depth, 2, "admission paused while starving");
        assert_eq!(snap.pool_used, per, "victim bytes returned");
        // the starved session retries first; once it yields, admission
        // resumes with the preempted session at the head of the line
        let retry = sched.next().expect("starved session requeued");
        assert_eq!(retry.session.id, 1);
        assert_eq!(retry.session.preemptions, 0, "caller was not reset");
        sched.yield_back(retry);
        let snap = sched.snapshot();
        assert_eq!(snap.admissions, 3, "victim re-admitted after the yield");
        assert_eq!(snap.running, 2);
        assert_eq!(snap.queue_depth, 1);
        assert!(snap.pool_peak <= snap.pool_capacity);

        // a session that cannot grow while alone is failed, not looped
        // (fresh pool: the first scheduler's sessions still hold bytes)
        let pool2 = Arc::new(BlockPool::new(2 * per));
        let sched2 = Scheduler::new(Arc::clone(&pool2));
        let (tx2, rx2) = mpsc::channel();
        sched2.submit(mk_session(9, &cfg, &man, &pool2), tx2);
        let alone = sched2.next().unwrap();
        sched2.cannot_grow(alone);
        let r = rx2.recv().expect("failure result delivered");
        assert!(r.error.is_some());
        assert_eq!(sched2.snapshot().rejections, 1);
    }

    /// Suspend-to-host preemption: the victim's cache is snapshotted
    /// into the swap pool (device bytes released, host bytes charged),
    /// it is re-admitted with its exact suspend-time footprint, and the
    /// resume restores it with zero recompute resets — generated tokens
    /// and position survive the round trip.
    #[test]
    fn preemption_swaps_to_host_and_resumes_byte_accurately() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let pool = Arc::new(BlockPool::new(2 * per));
        let swap = Arc::new(SwapPool::new(64 << 20));
        let sched = Scheduler::with_swap(Arc::clone(&pool), Some(Arc::clone(&swap)));
        let (tx, _rx) = mpsc::channel();
        sched.submit(mk_session(1, &cfg, &man, &pool), tx.clone());
        sched.submit(mk_session(2, &cfg, &man, &pool), tx.clone());
        // both sessions fake a prefill so they own cache slabs
        let mut a = sched.next().unwrap();
        let mut b = sched.next().unwrap();
        assert_eq!((a.session.id, b.session.id), (1, 2));
        a.session.test_fake_prefill();
        b.session.test_fake_prefill();
        let b_bytes = b.session.bytes_used();
        assert!(b_bytes > 0);
        sched.yield_back(b); // victim sits in the runnable queue
        sched.cannot_grow(a); // preempts youngest (id 2) via swap
        let snap = sched.snapshot();
        assert_eq!(snap.preemptions, 1);
        assert_eq!(snap.swap_outs, 1);
        assert_eq!(snap.swap_fallbacks, 0);
        assert!(snap.swap_used > 0, "snapshot charged to the swap pool");
        assert_eq!(snap.queue_depth, 1);
        assert_eq!(snap.running, 1);
        // the starved caller retries, yields, and the victim re-admits
        // with need == its suspend-time device footprint
        let a = sched.next().unwrap();
        assert_eq!(a.session.id, 1);
        sched.yield_back(a);
        let snap = sched.snapshot();
        assert_eq!(snap.running, 2, "swapped session re-admitted");
        assert!(snap.pool_peak <= snap.pool_capacity);
        let mut b = loop {
            let e = sched.next().expect("runnable");
            if e.session.id == 2 {
                break e;
            }
            sched.yield_back(e);
        };
        assert!(b.session.is_suspended());
        assert_eq!(b.session.admission_bytes(), b_bytes, "byte-accurate re-admission");
        // resume = restore the snapshot; no engine, no recompute
        b.session.resume_from_swap().unwrap();
        assert!(!b.session.is_suspended());
        assert_eq!(b.session.preemptions, 0, "never reset for recompute");
        assert_eq!(b.session.swap_outs, 1);
        assert_eq!(b.session.swap_ins, 1);
        assert_eq!(b.session.bytes_used(), b_bytes, "bit-accurate restore");
        assert_eq!(b.session.pos, man.model.prefill_len);
        assert_eq!(b.session.tokens.len(), 1, "generated tokens survive");
        let snap = sched.snapshot();
        assert_eq!(snap.swap_ins, 1);
        assert_eq!(snap.swap_used, 0, "swap bytes returned on resume");
        assert_eq!(snap.swap_bytes_in, snap.swap_bytes_out);
    }

    /// When the snapshot does not fit the swap pool, preemption falls
    /// back to the recompute reset and counts a fallback.
    #[test]
    fn swap_falls_back_to_recompute_when_pool_too_small() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let pool = Arc::new(BlockPool::new(2 * per));
        let swap = Arc::new(SwapPool::new(1)); // nothing fits
        let sched = Scheduler::with_swap(Arc::clone(&pool), Some(swap));
        let (tx, _rx) = mpsc::channel();
        sched.submit(mk_session(1, &cfg, &man, &pool), tx.clone());
        sched.submit(mk_session(2, &cfg, &man, &pool), tx.clone());
        let a = sched.next().unwrap();
        let mut b = sched.next().unwrap();
        b.session.test_fake_prefill();
        sched.yield_back(b);
        sched.cannot_grow(a);
        let snap = sched.snapshot();
        assert_eq!(snap.preemptions, 1);
        assert_eq!(snap.swap_outs, 0);
        assert_eq!(snap.swap_fallbacks, 1);
        assert_eq!(snap.swap_used, 0);
    }

    /// next_batch groups runnable sessions by batching compatibility
    /// key (cache family + compiled capacity): quant and fp32 sessions
    /// never share a fused call.
    #[test]
    fn batch_formation_groups_by_compat_key() {
        let man = tiny_manifest();
        let quant_cfg = tiny_cfg();
        let fp32_cfg = ServeConfig { mode: CompressionMode::FullKv, ..tiny_cfg() };
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        let (tx, _rx) = mpsc::channel();
        for (id, cfg) in [(1u64, &quant_cfg), (2, &fp32_cfg), (3, &quant_cfg), (4, &fp32_cfg)] {
            sched.submit(mk_session(id, cfg, &man, &pool), tx.clone());
        }
        let batch = sched.next_batch(4).expect("quant batch");
        let ids: Vec<u64> = batch.iter().map(|e| e.session.id).collect();
        assert_eq!(ids, vec![1, 3], "front session plus its compatible peer");
        let key = batch[0].session.compat_key();
        assert!(batch.iter().all(|e| e.session.compat_key() == key));
        let batch2 = sched.next_batch(4).expect("fp32 batch");
        let ids2: Vec<u64> = batch2.iter().map(|e| e.session.id).collect();
        assert_eq!(ids2, vec![2, 4]);
        assert_ne!(batch2[0].session.compat_key(), key);
        assert_eq!(sched.snapshot().running, 4, "all four held by workers");
    }

    /// Batch formation pre-reserves each extra member's worst-case step
    /// growth (the growth bond): with room for exactly one bond the
    /// batch stops at two members, with no bond room it stays at one,
    /// and the pool never exceeds capacity.
    #[test]
    fn batch_formation_never_overcommits_pool() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let hr = probe.step_headroom_bytes();
        assert!(hr > 0 && per > hr);

        // room for two admission reserves plus exactly one growth bond
        let pool = Arc::new(BlockPool::new(2 * per + hr));
        let sched = Scheduler::new(Arc::clone(&pool));
        let (tx, _rx) = mpsc::channel();
        for id in 1..=3u64 {
            sched.submit(mk_session(id, &cfg, &man, &pool), tx.clone());
        }
        assert_eq!(sched.snapshot().running, 2, "third admission must wait");
        let batch = sched.next_batch(4).expect("batch");
        assert_eq!(batch.len(), 2, "bond room for exactly one extra member");
        assert_eq!(pool.used(), pool.capacity(), "admissions + one bond");
        assert!(sched.snapshot().pool_peak <= pool.capacity());

        // fake-finish the batch: every byte (reserves + bond) returns,
        // which admits the third session
        for mut e in batch {
            e.session.finished_at = Some(std::time::Instant::now());
            let _ = e.done_tx.send(RequestResult::from_session(&e.session));
            sched.complete(&mut e.session);
        }
        let snap = sched.snapshot();
        assert_eq!(snap.running, 1, "freed bytes admit the waiter");
        assert_eq!(snap.pool_used, per);
        assert!(snap.pool_peak <= snap.pool_capacity);

        // no bond room at all: batches stay singleton and the leftover
        // session remains runnable for another worker
        let pool2 = Arc::new(BlockPool::new(2 * per));
        let sched2 = Scheduler::new(Arc::clone(&pool2));
        let (tx2, _rx2) = mpsc::channel();
        sched2.submit(mk_session(8, &cfg, &man, &pool2), tx2.clone());
        sched2.submit(mk_session(9, &cfg, &man, &pool2), tx2.clone());
        let b1 = sched2.next_batch(4).expect("first singleton");
        assert_eq!(b1.len(), 1);
        let b2 = sched2.next_batch(4).expect("second singleton");
        assert_eq!(b2.len(), 1);
        assert_eq!(b2[0].session.id, 9);
        assert!(pool2.used() <= pool2.capacity());
    }

    /// Chunked-prefill batch formation is Sarathi-style: at most one
    /// not-yet-prefilled session per batch (the prefill lane), while
    /// prefilled sessions still fuse alongside it. With chunking off,
    /// unprefilled sessions group freely (pre-chunking behavior).
    #[test]
    fn batch_carries_at_most_one_prefill_lane() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        sched.set_prefill_chunking(8, 0);
        let (tx, _rx) = mpsc::channel();
        for id in 1..=3u64 {
            sched.submit(mk_session(id, &cfg, &man, &pool), tx.clone());
        }
        // all three owe prefill: the batch stays a singleton
        let b = sched.next_batch(4).expect("batch");
        assert_eq!(b.len(), 1, "one prefill lane per batch");
        assert_eq!(b[0].session.id, 1);
        assert_eq!(sched.snapshot().prefill_queue_depth, 2, "queued prefill gauge");
        // a prefilled session fuses with the (single) prefill lane
        let mut first = b.into_iter().next().unwrap();
        first.session.test_fake_prefill();
        sched.yield_back(first);
        let b2 = sched.next_batch(4).expect("batch");
        let ids: Vec<u64> = b2.iter().map(|e| e.session.id).collect();
        assert_eq!(ids, vec![2, 1], "prefill lane (2) plus a decode member (1)");
        assert_eq!(
            b2.iter().filter(|e| !e.session.prefill_done()).count(),
            1,
            "exactly one prefill member"
        );
        for e in b2 {
            sched.yield_back(e);
        }

        // chunking off: three unprefilled sessions form one batch
        let pool2 = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched2 = Scheduler::new(Arc::clone(&pool2));
        let (tx2, _rx2) = mpsc::channel();
        for id in 1..=3u64 {
            sched2.submit(mk_session(id, &cfg, &man, &pool2), tx2.clone());
        }
        assert_eq!(sched2.next_batch(4).expect("batch").len(), 3);
    }

    /// The per-step token budget bounds what one fused step processes:
    /// decode members cost one token each, a prefill chunk its length.
    /// A tight budget sheds decode members; the auto budget (0) admits
    /// one chunk plus a full decode batch.
    #[test]
    fn token_budget_caps_decode_members_alongside_prefill_chunk() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        // chunk 8, budget 9: one chunk + exactly one decode member
        sched.set_prefill_chunking(8, 9);
        let (tx, _rx) = mpsc::channel();
        for id in 1..=4u64 {
            sched.submit(mk_session(id, &cfg, &man, &pool), tx.clone());
        }
        // prefill sessions 2..4 by hand so only id 1 owes prompt work
        let mut held = Vec::new();
        for _ in 0..4 {
            held.push(sched.next().expect("runnable"));
        }
        for e in held.iter_mut().skip(1) {
            e.session.test_fake_prefill();
        }
        for e in held {
            sched.yield_back(e);
        }
        let b = sched.next_batch(4).expect("batch");
        let ids: Vec<u64> = b.iter().map(|e| e.session.id).collect();
        assert_eq!(ids, vec![1, 2], "chunk (8) + one decode token hits the budget of 9");
        for e in b {
            sched.yield_back(e);
        }
        // auto budget: chunk (8) + batch cap (4) = 12 fits all four
        sched.set_prefill_chunking(8, 0);
        let b2 = sched.next_batch(4).expect("batch");
        assert_eq!(b2.len(), 4, "auto budget never sheds decode members");
        assert_eq!(
            b2.iter().filter(|e| !e.session.prefill_done()).count(),
            1,
            "still exactly one prefill member"
        );
    }

    /// Prefill-lane counters: chunks run and interleaved steps surface
    /// in the snapshot.
    #[test]
    fn prefill_chunk_counters_surface() {
        let sched = Scheduler::new(Arc::new(BlockPool::new(1024)));
        sched.set_prefill_chunking(16, 0);
        sched.note_prefill_chunk(true);
        sched.note_prefill_chunk(true);
        sched.note_prefill_chunk(false);
        let snap = sched.snapshot();
        assert_eq!(snap.prefill_chunk_tokens, 16);
        assert_eq!(snap.prefill_chunks, 3);
        assert_eq!(snap.prefill_interleaved_steps, 2);
    }

    /// Fused-step counters: totals and the batch-size histogram.
    #[test]
    fn fused_step_counters_and_histogram() {
        let sched = Scheduler::new(Arc::new(BlockPool::new(1024)));
        sched.note_fused_step(1);
        sched.note_fused_step(4);
        sched.note_fused_step(4);
        sched.note_fused_step(100); // clamps into the last bucket
        sched.note_fused_step(0); // ignored
        let snap = sched.snapshot();
        assert_eq!(snap.fused_steps, 4);
        assert_eq!(snap.fused_sessions, 1 + 4 + 4 + 100);
        assert_eq!(snap.batch_hist.len(), BATCH_HIST_BUCKETS);
        assert_eq!(snap.batch_hist[0], 1);
        assert_eq!(snap.batch_hist[3], 2);
        assert_eq!(snap.batch_hist[BATCH_HIST_BUCKETS - 1], 1);
        assert_eq!(snap.batch_hist.iter().sum::<u64>(), snap.fused_steps);
    }

    /// Regression (mutual-stall livelock): a preemption victim that is
    /// itself parked in `stalled` holds pool bytes but no worker, so a
    /// preemption mark would never be honored (marks are checked only at
    /// `yield_back` chunk boundaries). `cannot_grow` must preempt it
    /// directly instead of marking it.
    #[test]
    fn stalled_victim_is_preempted_directly_not_marked() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let pool = Arc::new(BlockPool::new(2 * per));
        let sched = Scheduler::new(Arc::clone(&pool));
        let (tx, _rx) = mpsc::channel();
        sched.submit(mk_session(1, &cfg, &man, &pool), tx.clone());
        sched.submit(mk_session(2, &cfg, &man, &pool), tx.clone());
        let older = sched.next().unwrap();
        let younger = sched.next().unwrap();
        assert_eq!((older.session.id, younger.session.id), (1, 2));
        // Park the younger session in `stalled` by hand — the state it
        // reaches when its own growth failed while a preemption was in
        // flight (cannot_grow's pending-preempts branch).
        {
            let mut inner = sched.inner.lock();
            inner.held.remove(&younger.session.id);
            inner.starving.insert(younger.session.id);
            inner.stalled.push_back(younger);
        }
        sched.cannot_grow(older);
        let snap = sched.snapshot();
        assert_eq!(snap.preemptions, 1, "stalled victim preempted directly");
        assert_eq!(snap.running, 1, "victim left the admitted set");
        {
            let inner = sched.inner.lock();
            assert!(inner.preempt_marks.is_empty(), "no unhonorable mark left behind");
            assert!(inner.stalled.is_empty(), "freed bytes unstalled the caller");
            assert_eq!(inner.waiting.front().map(|e| e.session.id), Some(2));
        }
        // the starved caller retries first and makes progress
        let retry = sched.next().expect("caller unstalled");
        assert_eq!(retry.session.id, 1);
        sched.yield_back(retry);
        assert_eq!(sched.snapshot().running, 2, "victim re-admitted after the yield");
    }

    /// Admission reclaims resident-but-unreferenced shared prefixes
    /// before refusing (and cannot_grow reclaims them before preempting
    /// a live session); entries with attached refs are never touched.
    #[test]
    fn admission_reclaims_unreferenced_prefixes() {
        use crate::kvcache::{PrefixGeom, PrefixIndex, PrefixPayload};
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let pool = Arc::new(BlockPool::new(2 * per));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
        let (tx, _rx) = mpsc::channel();
        sched.submit(mk_session(1, &cfg, &man, &pool), tx.clone());
        assert_eq!(sched.snapshot().running, 1);
        // a resident prefix with zero refs occupies part of the pool
        let geom = PrefixGeom { kind: "fp32", layers: 2, hkv: 1, dh: 16, prec_tag: 0 };
        let n = 8;
        let payload = PrefixPayload::Fp32 {
            full_len: n,
            k: vec![0.5; 2 * n * 16],
            v: vec![-0.5; 2 * n * 16],
        };
        let tokens: Vec<i32> = (0..n as i32).collect();
        let att = idx.publish(&tokens, geom, payload).expect("residency fits");
        drop(att); // refs -> 0, entry stays resident
        let resident = idx.stats().resident_bytes;
        assert!(resident > 0 && pool.used() == per + resident);
        // the second admission only fits if the reclaimer runs
        sched.submit(mk_session(2, &cfg, &man, &pool), tx.clone());
        let snap = sched.snapshot();
        assert_eq!(snap.running, 2, "reclaim freed the resident prefix");
        assert_eq!(snap.prefix_reclaims, 1);
        assert_eq!(snap.prefix_resident_entries, 0);
        assert!(snap.pool_peak <= snap.pool_capacity);
    }

    /// Prefix sharing must not affect decode-batch formation: a session
    /// attached to a shared prefix has the same `BatchKey` as an
    /// unshared same-family session and they fuse into one batch.
    #[test]
    fn prefix_sharing_leaves_batch_key_unchanged() {
        use crate::coordinator::session::build_backend;
        use crate::kvcache::{PrefixIndex, PrefixPayload};
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
        // publish a prefix with the quant geometry so session 1 attaches
        // at construction (payload content is irrelevant to batching)
        let probe = build_backend(&cfg, &man).unwrap();
        let geom = probe.prefix_geom();
        drop(probe);
        let n = 8;
        let sc = 2 * n; // layers * n slots, one scale group each
        let payload = PrefixPayload::Quant {
            full_len: n,
            k_codes: vec![0; 2 * n * 16],
            k_scales: vec![0.0; sc],
            v_codes: vec![0; 2 * n * 16],
            v_scales: vec![0.0; sc],
            tags: vec![geom.prec_tag; 2 * n],
        };
        let prompt: Vec<i32> = (0..16).collect();
        let _keep = idx.publish(&prompt[..n], geom, payload).expect("publish");
        let shared = Session::with_parts(
            1,
            prompt.clone(),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .unwrap();
        assert!(shared.has_prefix_attachment(), "construction-time hit");
        let unshared = mk_session(2, &cfg, &man, &pool);
        assert_eq!(shared.compat_key(), unshared.compat_key(), "sharing is key-invariant");
        let (tx, _rx) = mpsc::channel();
        sched.submit(shared, tx.clone());
        sched.submit(unshared, tx.clone());
        let batch = sched.next_batch(4).expect("batch");
        let ids: Vec<u64> = batch.iter().map(|e| e.session.id).collect();
        assert_eq!(ids, vec![1, 2], "shared + unshared fuse into one batch");
        assert_eq!(sched.snapshot().prefix_hits, 1);
    }

    /// Preemption marks set while a worker holds the victim are honored
    /// at yield time.
    #[test]
    fn held_victim_vacates_at_yield() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let pool = Arc::new(BlockPool::new(2 * per));
        let sched = Scheduler::new(Arc::clone(&pool));
        let (tx, _rx) = mpsc::channel();
        sched.submit(mk_session(1, &cfg, &man, &pool), tx.clone());
        sched.submit(mk_session(2, &cfg, &man, &pool), tx.clone());
        let older = sched.next().unwrap();
        let younger = sched.next().unwrap(); // both now held by "workers"
        assert_eq!(younger.session.id, 2);
        sched.cannot_grow(older); // marks id 2 for preemption
        assert_eq!(sched.snapshot().preemptions, 0, "victim still held");
        sched.yield_back(younger); // honors the mark
        let snap = sched.snapshot();
        assert_eq!(snap.preemptions, 1);
        assert_eq!(snap.queue_depth, 1);
        assert_eq!(snap.running, 1);
        // starved caller runs, yields, and the victim is re-admitted
        let retry = sched.next().unwrap();
        assert_eq!(retry.session.id, 1);
        sched.yield_back(retry);
        let snap = sched.snapshot();
        assert_eq!(snap.running, 2);
        assert_eq!(snap.queue_depth, 0);
        assert!(snap.pool_peak <= snap.pool_capacity);
    }

    /// Goodput policy: next() serves the tightest-TTFT-slack runnable
    /// session instead of the FIFO front; untargeted sessions come
    /// next, deadline-hopeless ones last.
    #[test]
    fn goodput_policy_pulls_tightest_slack_first() {
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        sched.set_policy(SchedPolicy::Goodput);
        assert_eq!(sched.policy(), SchedPolicy::Goodput);
        sched.drive_clock(50);
        let classed = |id: u64, ttft: u64| {
            let cfg = ServeConfig {
                slo_class: Some("t".into()),
                slo: SloTarget::new(ttft, 0),
                ..tiny_cfg()
            };
            mk_session(id, &cfg, &man, &pool)
        };
        let (tx, _rx) = mpsc::channel();
        sched.submit(classed(1, 500), tx.clone()); // deadline 550
        sched.submit(classed(2, 100), tx.clone()); // deadline 150: urgent
        sched.submit(mk_session(3, &tiny_cfg(), &man, &pool), tx.clone()); // best-effort
        sched.submit(classed(4, 10), tx.clone()); // deadline 60
        sched.drive_clock(100); // session 4's deadline is now lost
        let mut order = Vec::new();
        let mut held = Vec::new();
        for _ in 0..4 {
            let e = sched.next().expect("runnable");
            order.push(e.session.id);
            held.push(e);
        }
        assert_eq!(order, vec![2, 1, 3, 4], "slack order, hopeless last");
        assert!(sched.snapshot().sched_policy_goodput);
    }

    /// Terminating classed sessions fold into the goodput / violation
    /// counters and the per-class book; best-effort sessions never
    /// count, and the class counts sum to the global pair.
    #[test]
    fn slo_outcomes_fold_into_goodput_books() {
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        sched.drive_clock(0);
        let cfg = ServeConfig {
            slo_class: Some("chat".into()),
            slo: SloTarget::new(100, 0),
            ..tiny_cfg()
        };
        let (tx, _rx) = mpsc::channel();
        sched.submit(mk_session(1, &cfg, &man, &pool), tx.clone());
        sched.submit(mk_session(2, &cfg, &man, &pool), tx.clone());
        sched.submit(mk_session(3, &tiny_cfg(), &man, &pool), tx.clone());
        // id 1 gets its first token at tick 60 (met), id 2 at tick 500
        // (violated), id 3 is best-effort and never scored
        let mut a = sched.next().unwrap();
        assert_eq!(a.session.id, 1);
        a.session.slo.first_token_tick = Some(60);
        sched.complete(&mut a.session);
        sched.drive_clock(500);
        let mut b = sched.next().unwrap();
        assert_eq!(b.session.id, 2);
        b.session.slo.first_token_tick = Some(500);
        sched.complete(&mut b.session);
        let mut c = sched.next().unwrap();
        assert_eq!(c.session.id, 3);
        sched.complete(&mut c.session);
        let snap = sched.snapshot();
        assert_eq!(snap.goodput, 1);
        assert_eq!(snap.slo_violations, 1);
        assert_eq!(snap.completions, 3, "goodput counts a subset of completions");
        assert_eq!(snap.slo_classes.len(), 1, "best-effort never enters the book");
        let cls = &snap.slo_classes[0];
        assert_eq!(cls.name, "chat");
        assert_eq!(cls.goodput + cls.violations, snap.goodput + snap.slo_violations);
        assert_eq!(cls.ttft_p50, 60, "sorted samples [60, 500]");
        assert_eq!(cls.ttft_p99, 500);
    }

    /// Regression (preemption storm): an oversubscribed arrival wave
    /// whose sessions keep demanding growth drives repeated preemption
    /// with the starving gate active. The storm must drain — every
    /// request completes, no re-admission livelock — and no session is
    /// preempted an unbounded number of times.
    #[test]
    fn preemption_storm_drains_without_livelock() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let probe = mk_session(0, &cfg, &man, &Arc::new(BlockPool::new(u64::MAX / 2)));
        let per = probe.admission_bytes();
        let pool = Arc::new(BlockPool::new(2 * per));
        let sched = Scheduler::new(Arc::clone(&pool));
        sched.set_policy(SchedPolicy::Goodput);
        sched.drive_clock(1);
        let (tx, rx) = mpsc::channel();
        for id in 1..=6u64 {
            sched.submit(mk_session(id, &cfg, &man, &pool), tx.clone());
        }
        let mut pulls: BTreeMap<u64, u32> = BTreeMap::new();
        let mut done = 0;
        let mut iters = 0u32;
        while done < 6 {
            iters += 1;
            assert!(iters < 1_000, "re-admission livelock: {done} done after {iters} pulls");
            let mut e = sched.next().expect("runnable session");
            let n = {
                let c = pulls.entry(e.session.id).or_insert(0);
                *c += 1;
                *c
            };
            assert!(
                e.session.preemptions <= 8,
                "unbounded preemption churn for session {}",
                e.session.id
            );
            if n == 1 {
                // first chunk finishes the prompt work
                e.session.test_fake_prefill();
                sched.yield_back(e);
            } else if n == 2 && sched.snapshot().running > 1 {
                // growth demand under pressure: someone gets preempted
                sched.cannot_grow(e);
            } else if n >= 3 {
                e.session.finished_at = Some(std::time::Instant::now());
                let _ = e.done_tx.send(RequestResult::from_session(&e.session));
                sched.complete(&mut e.session);
                done += 1;
            } else {
                sched.yield_back(e);
            }
        }
        drop(tx);
        assert_eq!(rx.iter().count(), 6, "every request completes");
        let snap = sched.snapshot();
        assert_eq!(snap.completions, 6);
        assert_eq!(snap.rejections, 0, "no request failed out of the storm");
        assert!(snap.preemptions >= 1, "the storm actually preempted");
        assert!(snap.pool_peak <= snap.pool_capacity);
    }

    /// Cost-ordered resume requeue (satellite of ISSUE 9): vacated
    /// sessions form a contiguous front region of the waiting line
    /// ordered by ascending modeled resume cost, a resume older than
    /// [`RESUME_AGE_BOUND_TICKS`] is never jumped by a cheaper one, and
    /// fresh arrivals always queue behind the whole region.
    #[test]
    fn resume_requeue_orders_by_cost_with_age_bound() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        // zero-capacity pool: nothing ever admits, so the waiting line
        // keeps exactly the order the requeue chose
        let pool = Arc::new(BlockPool::new(0));
        let sched = Scheduler::new(Arc::clone(&pool));
        sched.drive_clock(1);
        let (tx, _rx) = mpsc::channel();
        // fresh arrival: ends the resume region, must stay last throughout
        sched.submit(mk_session(10, &cfg, &man, &pool), tx.clone());
        // expensive resume, vacated at tick 1 (it will age below)
        let mut a = mk_session(1, &cfg, &man, &pool);
        a.resume_cost_ns = Some(500_000);
        a.preempted_at_tick = 1;
        sched.resubmit(a, tx.clone());
        sched.drive_clock(1 + RESUME_AGE_BOUND_TICKS);
        // a cheap resume arriving after the bound may not jump aged A
        let mut b = mk_session(2, &cfg, &man, &pool);
        b.resume_cost_ns = Some(100_000);
        b.preempted_at_tick = sched.now_ticks();
        sched.resubmit(b, tx.clone());
        // a mid-cost resume sorts behind the cheaper fresh-aged B
        let mut c = mk_session(3, &cfg, &man, &pool);
        c.resume_cost_ns = Some(300_000);
        c.preempted_at_tick = sched.now_ticks();
        sched.resubmit(c, tx.clone());
        // the cheapest resume jumps B and C but still not aged A
        let mut d = mk_session(4, &cfg, &man, &pool);
        d.resume_cost_ns = Some(10_000);
        d.preempted_at_tick = sched.now_ticks();
        sched.resubmit(d, tx.clone());
        let ids: Vec<u64> = {
            let inner = sched.inner.lock();
            inner.waiting.iter().map(|e| e.session.id).collect()
        };
        assert_eq!(
            ids,
            vec![1, 4, 2, 3, 10],
            "aged-first, then ascending cost, fresh arrival last"
        );
        sched.shutdown();
    }

    /// Admission clears the resume-cost stamp, so a session that cycles
    /// through admit -> vacate re-enters the region with fresh pricing
    /// (and an admitted session never reads a stale stamp).
    #[test]
    fn admission_clears_resume_cost_stamp() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        sched.drive_clock(1);
        let (tx, _rx) = mpsc::channel();
        let mut s = mk_session(1, &cfg, &man, &pool);
        s.resume_cost_ns = Some(123);
        sched.resubmit(s, tx);
        let e = sched.next().expect("admitted");
        assert_eq!(e.session.resume_cost_ns, None, "stamp cleared on grant");
        sched.shutdown();
    }

    /// Proactive idle swap-out (satellite of ISSUE 9): a prefilled
    /// runnable session untouched for the configured ticks is suspended
    /// to the swap pool by the sweep — counted as `idle_swapouts`, not a
    /// preemption — while busier sessions and already-suspended ones are
    /// left alone, and the victim resumes bit-accurately off the
    /// snapshot.
    #[test]
    fn idle_sweep_suspends_stale_runnables() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let swap = Arc::new(SwapPool::new(64 << 20));
        let sched = Scheduler::with_swap(Arc::clone(&pool), Some(Arc::clone(&swap)));
        sched.drive_clock(10);
        let (tx, _rx) = mpsc::channel();
        sched.submit(mk_session(1, &cfg, &man, &pool), tx.clone());
        sched.submit(mk_session(2, &cfg, &man, &pool), tx.clone());
        let mut a = sched.next().expect("runnable");
        let mut b = sched.next().expect("runnable");
        assert_eq!((a.session.id, b.session.id), (1, 2));
        a.session.test_fake_prefill();
        b.session.test_fake_prefill();
        let a_bytes = a.session.bytes_used();
        sched.yield_back(a); // last ran at tick 10
        assert_eq!(sched.sweep_idle(), 0, "sweep is off by default");
        sched.set_idle_swap(5);
        sched.drive_clock(14);
        sched.yield_back(b); // last ran at tick 14
        assert_eq!(sched.sweep_idle(), 0, "nothing has sat idle 5 ticks yet");
        sched.drive_clock(16);
        assert_eq!(sched.sweep_idle(), 1, "only the tick-10 session is idle");
        let snap = sched.snapshot();
        assert_eq!(snap.idle_swapouts, 1);
        assert_eq!(snap.preemptions, 0, "idle swap-out is not a preemption");
        assert_eq!(snap.swap_outs, 1);
        assert!(snap.swap_used > 0, "snapshot charged to the swap pool");
        sched.drive_clock(22);
        assert_eq!(sched.sweep_idle(), 1, "second session idle now; suspended one skipped");
        assert_eq!(sched.snapshot().idle_swapouts, 2);
        // the pool is effectively unbounded, so both victims re-admitted
        // immediately; the first one resumes with zero recompute resets
        let mut e = loop {
            let e = sched.next().expect("runnable");
            if e.session.id == 1 {
                break e;
            }
            sched.yield_back(e);
        };
        assert!(e.session.is_suspended());
        e.session.resume_from_swap().unwrap();
        assert_eq!(e.session.preemptions, 0, "never reset for recompute");
        assert_eq!(e.session.bytes_used(), a_bytes, "bit-accurate restore");
        assert_eq!(e.session.pos, man.model.prefill_len);
        sched.shutdown();
    }
}
