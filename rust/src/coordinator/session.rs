//! A decode session: one request's full state machine, advanced one decode
//! step at a time against a worker's engine — alone through
//! [`Session::step`], or as a member of a cross-session decode batch
//! through the same halves ([`Session::begin_step`] /
//! [`Session::finish_step`]) wrapped around one fused
//! [`DecodeEngine::decode_batch`] call.
//!
//! Every compression mode flows through the same generic decode path via
//! the [`KvBackend`] trait (`make_room` → [`DecodeEngine::decode`] → `absorb`);
//! the mode only decides which backend [`build_backend`] constructs.
//! Prompt prefill is a cursor state machine ([`Session::advance_prefill`]):
//! the batched worker advances a long prompt one fixed-token chunk per
//! fused step — interleaved with its batch-mates' decode — instead of
//! head-of-line-blocking the batch on one inline whole-prompt prefill.
//! Sessions also carry their [`BlockPool`] reservation: the scheduler
//! grants an admission reserve, each step pre-reserves its worst-case
//! growth and trues the reservation up after ([`Session::step`] returns
//! [`StepOutcome::NeedMemory`] when the pool cannot cover the growth, and
//! the scheduler preempts). All cache policy work happens here in Rust —
//! the engine only executes the AOT decode-step HLO.
//!
//! Preemption has two flavors:
//!
//! * **suspend-to-host** ([`Session::suspend_to`]) — the backend is
//!   snapshotted into a byte-accounted [`SwapPool`] and dropped; on
//!   re-admission the next [`Session::step`] restores it and decoding
//!   continues with the *identical* token stream and zero replayed
//!   steps (tokens, position, and sampler state never reset).
//! * **recompute** ([`Session::reset_for_preemption`]) — the PR 1 path:
//!   generation rewinds to the prompt and replays. Used when swapping
//!   is disabled or the snapshot does not fit the swap pool.

use std::sync::Arc;

use anyhow::Result;

use crate::baselines::eviction::RetentionCounters;
use crate::baselines::quant_baselines::PmKvq;
use crate::compress::tbe::{Tbe, TbeConfig};
use crate::compress::tbq::Tbq;
use crate::kvcache::{
    AttachedPrefix, BatchKey, BlockPool, ByteLease, CacheConfig, CtCache, Fp32Backend, Fp32Cache,
    KvBackend, KvSnapshot, PrefixGeom, PrefixIndex, QuantBackend, SwapLease, SwapPool,
};
use crate::metrics::Breakdown;
use crate::quant::Precision;
use crate::runtime::{CacheView, DecodeEngine, DecodeOut, ExecStats};
use crate::thought::classifier::{Classifier, ClassifierConfig};

use super::config::{CompressionMode, ServeConfig, SloTarget};
use super::sampler::Sampler;

/// Result of advancing a session by one decode step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[must_use = "an unhandled NeedMemory or Finished outcome strands the session"]
pub enum StepOutcome {
    /// The session produced a token and can keep going.
    Running,
    /// The session finished (token budget reached, or already done).
    Finished,
    /// The block pool could not cover this step's KV growth; the
    /// scheduler must reclaim memory (preempt) before retrying.
    NeedMemory,
}

/// Outcome of the pre-decode half of a (possibly batched) step:
/// everything [`Session::begin_step`] does before the engine call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[must_use = "an unhandled NeedMemory or Finished prep strands the session"]
pub enum StepPrep {
    /// The session is ready for the fused engine call with these
    /// decode-step scalars (token, position, ring-buffer fill).
    Ready { token: i32, pos: i32, buf_idx: i32 },
    /// The session finished before needing another decode step.
    Finished,
    /// The pool could not cover this step's worst-case KV growth.
    NeedMemory,
}

/// Build the cache backend a serving mode runs on.
pub fn build_backend(
    cfg: &ServeConfig,
    manifest: &crate::model::Manifest,
) -> Result<Box<dyn KvBackend>> {
    let m = manifest.model.clone();
    let kv_dim = m.n_kv_heads * m.d_head;
    // the fp32 policy arena serves FullKV, every eviction baseline, and
    // any explicit `--policy` override (which wins over the mode): the
    // registry supplies the policy instance, its effective budget, and
    // whether evictions compact ([`PolicyKind`] is the single list a
    // new policy registers in). This also fixes the old SnapKV wiring,
    // which silently substituted StreamingLLM on the live path —
    // deferred priming now captures the protected set from the first
    // observed attention row instead.
    if let Some(kind) = cfg.policy_kind() {
        let need = m.prefill_len + cfg.max_new_tokens + m.buf_slots;
        let capacity = manifest
            .pick_fp32_cap(need.min(*manifest.fp32_caps.last().unwrap_or(&need)))
            .or(manifest.fp32_caps.last().copied())
            .ok_or_else(|| anyhow::anyhow!("no fp32 artifact"))?;
        return Ok(Box::new(Fp32Backend::new(
            Fp32Cache::new(m.n_layers, capacity, kv_dim, m.buf_slots),
            kind.build(cfg.budget),
            kind.budget_for(cfg.budget),
            kind.gather(),
            capacity,
        )));
    }
    match &cfg.mode {
        CompressionMode::ThinKv { .. } | CompressionMode::Kivi(_) | CompressionMode::PmKvq => {
            let headroom = cfg.budget + m.buf_slots + 64;
            let want = match &cfg.mode {
                // quantization-only modes never evict: need room for all
                CompressionMode::Kivi(_) | CompressionMode::PmKvq => {
                    m.prefill_len + cfg.max_new_tokens + m.buf_slots
                }
                CompressionMode::ThinKv { no_tbe: true, .. } => {
                    m.prefill_len + cfg.max_new_tokens + m.buf_slots
                }
                _ => headroom,
            };
            let capacity = cfg
                .capacity
                .or_else(|| manifest.pick_quant_cap(want))
                .or(manifest.quant_caps.last().copied())
                .ok_or_else(|| anyhow::anyhow!("no quant artifact"))?;
            let cache = CtCache::new(CacheConfig {
                layers: m.n_layers,
                capacity,
                block_size: 8,
                hkv: m.n_kv_heads,
                dh: m.d_head,
                buf_slots: m.buf_slots,
            });
            let (tbq, tbe, pmkvq) = match &cfg.mode {
                CompressionMode::ThinKv { assignment, no_tbq, no_tbe } => {
                    let tbq = if *no_tbq {
                        // iso-compression ablation: uniform FP8 (highest
                        // fidelity available on the quant path)
                        Tbq::uniform(Precision::Fp8)
                    } else {
                        Tbq::new(*assignment)
                    };
                    let tbe = (!no_tbe).then(|| {
                        Tbe::new(TbeConfig {
                            retention: cfg.retention.clone(),
                            budget: cfg.budget,
                            kmeans_iters: 8,
                            seed: cfg.seed,
                        })
                    });
                    (tbq, tbe, None)
                }
                CompressionMode::Kivi(p) => (Tbq::uniform(*p), None, None),
                CompressionMode::PmKvq => {
                    (Tbq::uniform(Precision::Fp8), None, Some(PmKvq::default_schedule()))
                }
                _ => unreachable!(),
            };
            let classifier = Classifier::new(ClassifierConfig {
                layers: vec![0, 1, 2, 3],
                thresholds: crate::thought::calibration::default_thresholds(3),
                refresh: cfg.refresh,
            });
            Ok(Box::new(QuantBackend::new(cache, tbq, tbe, classifier, pmkvq)))
        }
        CompressionMode::FullKv | CompressionMode::Evict(_) => {
            unreachable!("fp32-path modes resolve through the policy arena above")
        }
    }
}

/// A suspended session's cache image plus the ledgered swap-pool lease
/// backing it (settled on resume, drop, or reset).
struct SuspendedKv {
    snap: KvSnapshot,
    lease: SwapLease,
}

/// Prompt-prefill cursor: prefill is a little state machine now that a
/// long prompt can be computed in scheduler-interleaved chunks
/// ([`Session::advance_prefill`]) instead of one inline call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PrefillCursor {
    /// No prefill work done yet (fresh session, or reset for recompute).
    NotStarted,
    /// Positions `0..next` are in the cache (a shared-attach region
    /// counts); the engine still owes `next..prefill_len`.
    InProgress { next: usize },
    /// Prefill complete: the first token was sampled from its logits.
    Done,
}

/// Per-session SLO bookkeeping (tenant class, targets, tick stamps on
/// the scheduler's clock — wall milliseconds live, deterministic
/// engine-time units under the trace-replay harness). The first-token
/// stamp is **sticky** across recompute preemption: the client-visible
/// first token happened exactly once, so a replayed session does not
/// get a fresh TTFT.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SloState {
    /// Tenant-class label ([`ServeConfig::slo_class`]); empty =
    /// unclassed / best-effort.
    pub class: String,
    /// TTFT/TPOT targets in ticks (both 0 = no target).
    pub target: SloTarget,
    /// Scheduler-clock tick the session was submitted at.
    pub submitted_at: u64,
    /// Tick the first generated token landed at.
    pub first_token_tick: Option<u64>,
    /// Tick the session completed (or failed) at.
    pub finished_tick: Option<u64>,
}

impl SloState {
    /// True when this session counts toward per-class goodput/violation
    /// accounting (a class label *and* a real target).
    pub fn classed(&self) -> bool {
        !self.class.is_empty() && !self.target.is_none()
    }

    /// TTFT slack at `now`: ticks left before the TTFT deadline blows.
    /// `None` when no TTFT target applies or the first token already
    /// landed (the deadline race is over).
    pub fn ttft_slack(&self, now: u64) -> Option<i64> {
        if self.target.ttft_ticks == 0 || self.first_token_tick.is_some() {
            return None;
        }
        Some((self.submitted_at + self.target.ttft_ticks) as i64 - now as i64)
    }

    /// Deadline-hopeless: the TTFT deadline passed with no first token —
    /// no scheduling decision can still save this request's SLO.
    pub fn hopeless(&self, now: u64) -> bool {
        matches!(self.ttft_slack(now), Some(s) if s < 0)
    }

    /// Observed TTFT in ticks (first token − submit), once known.
    pub fn ttft(&self) -> Option<u64> {
        self.first_token_tick.map(|t| t.saturating_sub(self.submitted_at))
    }

    /// Observed TPOT in milli-ticks per token over `n_tokens` generated
    /// tokens (first-token → finish over `n_tokens − 1` gaps; 0 when
    /// fewer than two tokens were generated).
    pub fn tpot_milli(&self, n_tokens: usize) -> Option<u64> {
        let first = self.first_token_tick?;
        let fin = self.finished_tick?;
        if n_tokens < 2 {
            return Some(0);
        }
        Some(fin.saturating_sub(first) * 1000 / (n_tokens as u64 - 1))
    }

    /// Did the request meet its SLO over `n_tokens` generated tokens?
    /// `None` for unclassed sessions (they never count either way).
    pub fn met(&self, n_tokens: usize) -> Option<bool> {
        if !self.classed() {
            return None;
        }
        let ttft_ok = self.target.ttft_ticks == 0
            || self.ttft().is_some_and(|t| t <= self.target.ttft_ticks);
        let tpot_ok = self.target.tpot_milli_ticks == 0
            || self.tpot_milli(n_tokens).map_or(true, |t| t <= self.target.tpot_milli_ticks);
        Some(ttft_ok && tpot_ok)
    }
}

pub struct Session {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub pos: usize,
    pub max_new_tokens: usize,
    pub mode_label: String,
    /// Display name of the retention policy managing this session's
    /// cache ([`KvBackend::policy_name`]), priced once at construction
    /// from the probe backend — available even before the lazy backend
    /// build and after a preemption drops the slabs.
    pub policy_label: &'static str,
    /// Built lazily on the first decode step and dropped on preemption,
    /// so sessions waiting for admission (and preempted ones) hold no
    /// cache slabs — process memory tracks the pool, not the submit
    /// count.
    backend: Option<Box<dyn KvBackend>>,
    sampler: Sampler,
    pub breakdown: Breakdown,
    pub created: std::time::Instant,
    pub first_token_at: Option<std::time::Instant>,
    pub finished_at: Option<std::time::Instant>,
    /// SLO class + targets + tick stamps on the scheduler's clock.
    /// Stamped by the scheduler (`submit`) and the batched worker
    /// (first-token tick); evaluated once at completion.
    pub slo: SloState,
    /// Where prompt prefill stands — chunked prefill advances this
    /// cursor one chunk at a time; the whole-prompt path runs it to
    /// `Done` in one [`Session::prefill`] call.
    prefill: PrefillCursor,
    /// Times this session was preempted with *recompute* (reset +
    /// requeued, generation replayed). Swap preemptions are counted
    /// separately in [`Session::swap_outs`] — a fully swapped run keeps
    /// this at zero.
    pub preemptions: u64,
    /// Times this session was suspended to the host swap pool.
    pub swap_outs: u64,
    /// Times this session was restored from the host swap pool.
    pub swap_ins: u64,
    /// Cumulative wall time spent restoring this session's snapshots.
    pub restore_ns: u64,
    /// Host-side cache image while preempted-with-swap (None while
    /// running or when preempted with recompute).
    suspended: Option<SuspendedKv>,
    /// Admission reserve, computed once at construction.
    admission_est: u64,
    /// Batched-decode compatibility key (cache family + compiled
    /// capacity), computed once at construction.
    compat_key: BatchKey,
    /// Worst-case `bytes_used` growth of one decode step, computed once
    /// at construction — what batch formation pre-reserves per member.
    step_headroom: u64,
    /// Prefix-sharing geometry key, computed once at construction.
    prefix_geom: PrefixGeom,
    /// The scheduler-owned prefix index, when sharing is enabled.
    prefix_index: Option<Arc<PrefixIndex>>,
    /// This session's shared-prefix attachment: admission and byte
    /// accounting charge only the delta while it is active, and the
    /// backend reads the resident payload instead of re-quantizing.
    prefix_att: Option<Arc<AttachedPrefix>>,
    cfg: ServeConfig,
    manifest: crate::model::Manifest,
    pool: Option<Arc<BlockPool>>,
    /// The ledgered pool charge backing every byte this session holds
    /// (admission grant + growth bonds + drained CoW reservations);
    /// `None` while the session holds nothing.
    lease: Option<ByteLease>,
    /// Modeled resume cost in nanoseconds of serving time
    /// (`min(swap restore, recompute replay)`), stamped by the
    /// scheduler when the session is vacated with restorable progress;
    /// `None` for fresh arrivals. Orders the waiting line's resume
    /// region; cleared on (re)admission.
    pub(crate) resume_cost_ns: Option<u64>,
    /// Scheduler tick this session was last vacated at (the resume
    /// ordering's starvation age bound reads it).
    pub(crate) preempted_at_tick: u64,
    /// Scheduler tick this session last ran (or was submitted) — the
    /// proactive idle swap-out sweep compares it against `now`.
    pub(crate) last_ran_tick: u64,
    /// Streaming sink: one frame of newly generated tokens per chunk
    /// boundary. The channel is **bounded** — a slow consumer applies
    /// backpressure to the decode worker at chunk granularity.
    pub(crate) stream_tx: Option<std::sync::mpsc::SyncSender<Vec<i32>>>,
    /// Tokens already emitted to `stream_tx`; survives recompute
    /// preemption so a bit-identical replay never re-sends a frame.
    pub(crate) streamed_tokens: usize,
}

impl Session {
    pub fn new(
        id: u64,
        prompt: Vec<i32>,
        cfg: &ServeConfig,
        manifest: &crate::model::Manifest,
    ) -> Result<Session> {
        Session::with_pool(id, prompt, cfg, manifest, None)
    }

    /// Construct a session whose KV bytes are accounted against `pool`.
    pub fn with_pool(
        id: u64,
        prompt: Vec<i32>,
        cfg: &ServeConfig,
        manifest: &crate::model::Manifest,
        pool: Option<Arc<BlockPool>>,
    ) -> Result<Session> {
        Session::with_parts(id, prompt, cfg, manifest, pool, None)
    }

    /// [`Session::with_pool`] plus cross-session prefix sharing: the
    /// prompt is matched against `prefix` at construction so admission
    /// already charges only the delta when a resident prefix covers it.
    pub fn with_parts(
        id: u64,
        prompt: Vec<i32>,
        cfg: &ServeConfig,
        manifest: &crate::model::Manifest,
        pool: Option<Arc<BlockPool>>,
        prefix: Option<Arc<PrefixIndex>>,
    ) -> Result<Session> {
        // transient probe: validates the mode/artifact combination and
        // prices the admission reserve, the per-step growth bound, and
        // the batching compatibility key, then frees its slabs
        let probe = build_backend(cfg, manifest)?;
        let admission_est = probe.admission_bytes(manifest.model.prefill_len);
        let compat_key = probe.compat_key();
        let step_headroom = probe.step_headroom_bytes();
        let prefix_geom = probe.prefix_geom();
        let policy_label = probe.policy_name();
        drop(probe);
        // the attachment holds a reference, so a matched prefix stays
        // resident from admission pricing through prefill; CoW
        // privatization must charge *this session's* pool, which under
        // a fleet-global index is not the index's own pool
        let prefix_att = prefix
            .as_ref()
            .and_then(|idx| idx.attach(&prompt, prefix_geom, manifest.model.prefill_len))
            .map(|att| match &pool {
                Some(p) => att.rebind_charge(Arc::clone(p)),
                None => att,
            });
        Ok(Session {
            id,
            prompt,
            tokens: Vec::new(),
            pos: 0,
            max_new_tokens: cfg.max_new_tokens,
            mode_label: cfg.mode.label(),
            policy_label,
            backend: None,
            sampler: Sampler::new(cfg.temperature, 32, cfg.seed ^ id),
            breakdown: Breakdown::default(),
            created: std::time::Instant::now(),
            first_token_at: None,
            finished_at: None,
            slo: SloState {
                class: cfg.slo_class.clone().unwrap_or_default(),
                target: cfg.slo,
                ..SloState::default()
            },
            prefill: PrefillCursor::NotStarted,
            preemptions: 0,
            swap_outs: 0,
            swap_ins: 0,
            restore_ns: 0,
            suspended: None,
            admission_est,
            compat_key,
            step_headroom,
            prefix_geom,
            prefix_index: prefix,
            prefix_att,
            cfg: cfg.clone(),
            manifest: manifest.clone(),
            pool,
            lease: None,
            resume_cost_ns: None,
            preempted_at_tick: 0,
            last_ran_tick: 0,
            stream_tx: None,
            streamed_tokens: 0,
        })
    }

    /// Price the batched-decode compatibility key for a config/manifest
    /// pair without constructing a session — the router's placement
    /// probe (side-effect free: no pool charge, no prefix attach).
    pub fn probe_key(cfg: &ServeConfig, manifest: &crate::model::Manifest) -> Result<BatchKey> {
        Ok(build_backend(cfg, manifest)?.compat_key())
    }

    /// Attach a streaming sink: every chunk boundary flushes the tokens
    /// generated since the last flush as one frame.
    pub fn set_stream(&mut self, tx: std::sync::mpsc::SyncSender<Vec<i32>>) {
        self.stream_tx = Some(tx);
    }

    /// Emit tokens generated since the last flush to the streaming sink
    /// (no-op without one). Blocks when the bounded channel is full —
    /// per-connection backpressure, surfaced to the decode worker at
    /// chunk granularity. A disconnected consumer drops the sink so a
    /// dead client cannot stall the batch again.
    pub fn flush_stream(&mut self) {
        let Some(tx) = self.stream_tx.as_ref() else { return };
        if self.tokens.len() <= self.streamed_tokens {
            return;
        }
        let frame = self.tokens[self.streamed_tokens..].to_vec();
        let n = frame.len();
        if tx.send(frame).is_err() {
            self.stream_tx = None;
            return;
        }
        self.streamed_tokens += n;
    }

    /// Rebind a **suspended** session to another replica's pool and the
    /// (fleet-shared) prefix index — the device-side half of live
    /// migration. Legal only while the session holds no pool bytes
    /// (post-`suspend_to`: the reservation was released to the source
    /// pool, the host snapshot's bytes stay charged to the source swap
    /// pool it rides in). Any prefix attachment is re-created so later
    /// CoW privatization charges the *destination* pool.
    pub(crate) fn rebind_for_migration(
        &mut self,
        pool: Arc<BlockPool>,
        prefix: Option<Arc<PrefixIndex>>,
    ) {
        debug_assert!(self.suspended.is_some(), "only suspended sessions migrate");
        debug_assert_eq!(self.reserved_bytes(), 0, "migrating session must hold no pool bytes");
        // the lease (if any) is empty by the assert above, but it still
        // pins the *source* pool — settle it so nothing crosses replicas
        if let Some(lease) = self.lease.take() {
            lease.settle();
        }
        if let Some(att) = self.prefix_att.take() {
            self.prefix_att = Some(att.rebind_charge(Arc::clone(&pool)));
        }
        self.pool = Some(pool);
        self.prefix_index = prefix;
    }

    fn ensure_backend(&mut self) -> Result<()> {
        if self.backend.is_none() {
            self.backend = Some(build_backend(&self.cfg, &self.manifest)?);
        }
        Ok(())
    }

    pub fn done(&self) -> bool {
        self.finished_at.is_some()
    }

    /// Live cached tokens (for memory reporting).
    pub fn live_tokens(&self) -> usize {
        self.backend.as_ref().map_or(0, |b| b.live_tokens())
    }

    pub fn avg_bits(&self) -> f64 {
        self.backend.as_ref().map_or(0.0, |b| b.avg_bits())
    }

    pub fn ct_reuse_count(&self) -> u64 {
        self.backend.as_ref().map_or(0, |b| b.ct_reuses())
    }

    pub fn tbe_stats(&self) -> Option<crate::compress::tbe::TbeStats> {
        self.backend.as_ref().and_then(|b| b.tbe_stats())
    }

    pub fn gather_stats(&self) -> (u64, u64, u64) {
        self.backend.as_ref().map_or((0, 0, 0), |b| b.gather_stats())
    }

    /// Retention counters from the live backend (evictions, never-
    /// materialized skips, retained bytes); zeros before the backend
    /// exists or on the quantized path.
    pub fn retention(&self) -> RetentionCounters {
        self.backend.as_ref().map_or_else(RetentionCounters::default, |b| b.retention())
    }

    /// Current live KV bytes under packed accounting.
    pub fn bytes_used(&self) -> u64 {
        self.backend.as_ref().map_or(0, |b| b.bytes_used())
    }

    /// Bytes the scheduler must reserve in the pool before (re)admitting
    /// this session: the upper bound on the post-prefill footprint for a
    /// fresh or recompute-preempted session, or the exact live footprint
    /// recorded at suspend time for a swapped session (byte-accurate
    /// swap-in). A session attached to a resident shared prefix charges
    /// only its **delta** — the prefix bytes are charged once, globally,
    /// by the [`PrefixIndex`].
    pub fn admission_bytes(&self) -> u64 {
        match &self.suspended {
            // suspend-time device bytes already excluded any active
            // shared prefix (bytes_used is delta-accounted)
            Some(s) => s.snap.device_bytes,
            None => {
                let shared = self
                    .prefix_att
                    .as_ref()
                    .filter(|a| a.is_active())
                    .map_or(0, |a| a.bytes());
                self.admission_est.saturating_sub(shared)
            }
        }
    }

    /// Tokens currently read from a shared (cross-session) prefix — 0
    /// for unshared sessions and after copy-on-write privatization.
    pub fn shared_prefix_tokens(&self) -> usize {
        self.backend.as_ref().map_or(0, |b| b.shared_prefix_tokens())
    }

    /// True while this session holds a prefix attachment (active or
    /// privatized).
    pub fn has_prefix_attachment(&self) -> bool {
        self.prefix_att.is_some()
    }

    /// True while this session's cache lives in the host swap pool.
    pub fn is_suspended(&self) -> bool {
        self.suspended.is_some()
    }

    /// Device bytes of the suspended snapshot (what a migration moves);
    /// `None` while running.
    pub fn suspended_bytes(&self) -> Option<u64> {
        self.suspended.as_ref().map(|s| s.snap.device_bytes)
    }

    /// Batched-decode compatibility key: sessions with equal keys run
    /// the same compiled decode executable, so the scheduler may put
    /// them in one fused decode batch.
    pub fn compat_key(&self) -> BatchKey {
        self.compat_key
    }

    /// Worst-case `bytes_used` growth of a single decode step (one
    /// token landing in the f32 ring buffer). Batch formation reserves
    /// this per extra batch member *before* the fused call so a batch
    /// can never over-commit the pool mid-step.
    pub fn step_headroom_bytes(&self) -> u64 {
        self.step_headroom
    }

    /// Bytes currently held in the pool on this session's behalf (the
    /// live lease's size).
    pub(crate) fn reserved_bytes(&self) -> u64 {
        self.lease.as_ref().map_or(0, |l| l.bytes())
    }

    /// Absorb a pool lease into this session's own (creating it if the
    /// session holds nothing yet). Both must charge the session's pool.
    fn absorb_lease(&mut self, incoming: ByteLease) {
        match &mut self.lease {
            Some(l) => l.merge(incoming),
            None => self.lease = Some(incoming),
        }
    }

    /// Credit a pool lease the scheduler already charged on this
    /// session's behalf (the batch-formation growth bond). The surplus
    /// flows back through the post-step reservation true-up.
    pub(crate) fn add_growth_bond(&mut self, bond: ByteLease) {
        debug_assert!(self.pool.is_some(), "growth bond without a pool");
        self.absorb_lease(bond);
    }

    /// Record the admission lease the scheduler charged to the pool on
    /// this session's behalf.
    pub(crate) fn grant(&mut self, lease: ByteLease) {
        debug_assert!(self.lease.is_none(), "double admission grant");
        self.lease = Some(lease);
    }

    /// Fold the pool lease a copy-on-write privatization charged
    /// directly (outside this session's lease) into it, so every byte
    /// flows through the one settle path.
    fn drain_cow(&mut self) {
        let Some(att) = &self.prefix_att else { return };
        if let Some(cow) = att.take_cow_lease() {
            self.absorb_lease(cow);
        }
    }

    /// Return every byte this session holds to the pool.
    pub(crate) fn release_pool(&mut self) {
        self.drain_cow();
        if let Some(lease) = self.lease.take() {
            lease.settle();
        }
    }

    /// Grow the reservation to `want` bytes; false if the pool is out of
    /// memory (caller must preempt someone and retry).
    fn ensure_reserved(&mut self, want: u64) -> bool {
        let Some(pool) = &self.pool else { return true };
        let held = self.reserved_bytes();
        if want > held {
            let delta = want - held;
            match &mut self.lease {
                Some(l) => {
                    if !l.grow(delta) {
                        return false;
                    }
                }
                None => match pool.lease(delta) {
                    Some(l) => self.lease = Some(l),
                    None => return false,
                },
            }
        }
        true
    }

    /// True the reservation up to the backend's actual live bytes —
    /// called after every append/evict/requant so the pool stays
    /// byte-accurate (surplus from the pre-step worst-case reserve goes
    /// back immediately). A copy-on-write that fired during the step
    /// already charged its lease in the pool; drain it into this
    /// session's lease first so the true-up never double-charges.
    fn sync_pool(&mut self) {
        self.drain_cow();
        let cur = self.bytes_used();
        if self.pool.is_none() {
            return;
        }
        let held = self.reserved_bytes();
        if cur < held {
            self.lease
                .as_mut()
                .expect("nonzero holding implies a lease")
                .shrink(held - cur);
        } else if cur > held {
            // Growth is pre-reserved, so this only fires if an admission
            // estimate undershot; true up best-effort to keep pool books
            // honest.
            debug_assert!(false, "KV growth exceeded its pre-step reserve");
            let _ = self.ensure_reserved(cur);
        }
    }

    /// Suspend this session's cache to the host-side swap pool
    /// (suspend-to-host preemption): snapshot the backend, charge the
    /// snapshot to `swap`, drop the device slabs, and return the block
    /// pool bytes. Generation state (tokens, position, sampler) is kept,
    /// so the resumed session produces the identical token stream with
    /// zero recompute steps.
    ///
    /// Returns false — and leaves the session untouched — when there is
    /// nothing to snapshot yet (no backend / not prefilled) or the
    /// snapshot does not fit `swap`; the caller then falls back to
    /// [`Session::reset_for_preemption`].
    pub fn suspend_to(&mut self, swap: &Arc<SwapPool>) -> bool {
        if self.suspended.is_some() {
            // re-admitted but preempted again before its first step: the
            // snapshot still sits in the swap pool untouched — just hand
            // the device reservation back
            self.release_pool();
            return true;
        }
        if !self.prefill_done() {
            // a mid-prefill cache has no cursor state in the snapshot
            // format; those sessions fall back to recompute
            return false;
        }
        let Some(backend) = self.backend.as_ref() else {
            return false;
        };
        // price first, copy after: a snapshot that will not fit the swap
        // pool must cost O(1), not a discarded full cache copy
        let need = backend.snapshot_bytes();
        let Some(lease) = swap.lease(need) else {
            swap.note_fallback();
            return false;
        };
        let snap = match backend.snapshot() {
            Ok(s) => s,
            Err(_) => {
                lease.settle();
                swap.note_fallback();
                return false;
            }
        };
        debug_assert_eq!(snap.bytes, need, "snapshot_bytes must price exactly");
        swap.note_swap_out(snap.bytes);
        self.swap_outs += 1;
        self.backend = None; // device slabs freed
        self.release_pool(); // device bytes back to the block pool
        self.suspended = Some(SuspendedKv { snap, lease });
        true
    }

    /// Rebuild the backend from the suspended snapshot (swap-in): called
    /// on the first decode step after re-admission. O(bytes copied), no
    /// engine work, no replayed decode steps. No-op when the session is
    /// not suspended.
    pub(crate) fn resume_from_swap(&mut self) -> Result<()> {
        let Some(SuspendedKv { snap, lease }) = self.suspended.take() else {
            return Ok(());
        };
        let bytes = snap.bytes;
        let pool = Arc::clone(lease.pool());
        let t0 = std::time::Instant::now();
        let result = self.rebuild_from(snap);
        // the swap lease is settled on both paths — a failed restore
        // must not strand host bytes (the caller then resets for
        // recompute, returning the block-pool reservation too)
        lease.settle();
        match &result {
            Ok(()) => {
                let ns = t0.elapsed().as_nanos() as u64;
                pool.note_swap_in(bytes, ns);
                self.swap_ins += 1;
                self.restore_ns += ns;
            }
            Err(_) => {
                self.backend = None; // a half-restored cache is unusable
                pool.note_fallback();
            }
        }
        result
    }

    /// Build a fresh backend and load `snap` into it (the swap-in copy).
    fn rebuild_from(&mut self, snap: KvSnapshot) -> Result<()> {
        let mut backend = build_backend(&self.cfg, &self.manifest)?;
        backend.restore(snap)?;
        // re-link a shared-prefix attachment so the restored cache keeps
        // its read-only marker and delta accounting
        if let Some(att) = &self.prefix_att {
            backend.reattach_prefix(Arc::clone(att));
        }
        self.backend = Some(backend);
        Ok(())
    }

    /// Drop a suspended snapshot (if any) and return its swap bytes —
    /// the session is leaving the system without resuming.
    fn drop_swap(&mut self) {
        if let Some(SuspendedKv { snap, lease }) = self.suspended.take() {
            debug_assert_eq!(lease.bytes(), snap.bytes, "swap lease drifted from its snapshot");
            lease.settle();
        }
    }

    /// Reset this session for preemption: free the cache slabs, return
    /// the pool bytes, and rewind generation so a later re-admission
    /// recomputes from the prompt (vLLM-style recompute preemption; the
    /// backend is rebuilt lazily on the next step). The time-accounting
    /// fields keep running — ttft/total latencies include the time spent
    /// preempted. This is the fallback when suspend-to-host
    /// ([`Session::suspend_to`]) is disabled or does not fit.
    pub fn reset_for_preemption(&mut self) {
        self.drop_swap();
        self.release_pool();
        self.backend = None;
        // a privatized attachment bought nothing that survives the
        // reset — drop it so the re-prefill can share (or publish)
        // afresh; an active one is kept and re-attached at prefill
        if self.prefix_att.as_ref().is_some_and(|a| !a.is_active()) {
            self.prefix_att = None;
        }
        self.sampler = Sampler::new(self.cfg.temperature, 32, self.cfg.seed ^ self.id);
        self.tokens.clear();
        self.pos = 0;
        // a victim that never finished prefill loses no generated work,
        // so only count resets that actually force a recompute
        if self.prefill_done() {
            self.preemptions += 1;
        }
        self.prefill = PrefillCursor::NotStarted;
        self.first_token_at = None;
        // slo.first_token_tick is deliberately NOT cleared: the
        // client-visible first token happened once; the replay does not
        // restart the TTFT clock (the SLO verdict stays honest).
    }

    /// True once prompt prefill has completed (the first token was
    /// sampled from the prefill logits).
    pub fn prefill_done(&self) -> bool {
        matches!(self.prefill, PrefillCursor::Done)
    }

    /// Prompt tokens the engine still owes this session: what a prefill
    /// chunk costs the scheduler's per-step token budget. 0 once done;
    /// before the first chunk, the padded prefill length minus any
    /// construction-time shared-prefix attachment (the attached region
    /// needs no engine compute at all).
    pub fn prefill_remaining(&self) -> usize {
        let p_len = self.manifest.model.prefill_len;
        match self.prefill {
            PrefillCursor::Done => 0,
            PrefillCursor::InProgress { next } => p_len - next,
            PrefillCursor::NotStarted => {
                let shared = self
                    .prefix_att
                    .as_ref()
                    .filter(|a| a.is_active())
                    .map_or(0, |a| a.attach_len().min(p_len));
                p_len - shared
            }
        }
    }

    /// Run prompt prefill to completion. With prefix sharing enabled
    /// this is where the lifecycle forks: a matched prompt **attaches**
    /// the resident payload (shared-attach + private-tail, no
    /// re-quantization of the prefix), an unmatched one prefills fully
    /// and **publishes** its block-aligned prefix for later sessions.
    pub fn prefill(&mut self, engine: &dyn DecodeEngine) -> Result<()> {
        while !self.advance_prefill(engine, usize::MAX)? {}
        Ok(())
    }

    /// Advance prompt prefill by one chunk of at most `chunk` tokens
    /// (`usize::MAX` = the whole remaining prompt, the single-session
    /// path). This is the chunked-prefill state machine the batched
    /// worker drives once per fused step, so a long-prompt arrival
    /// delays its batch-mates by one chunk instead of a full prefill:
    ///
    /// * first chunk — resolve the shared-prefix fork once (attach the
    ///   resident payload, second-chance lookup included) and start the
    ///   cursor at the attach boundary;
    /// * every chunk — one [`DecodeEngine::prefill_chunk`] call, written
    ///   through [`KvBackend::write_prefill_chunk`] at absolute prompt
    ///   positions (timed into `breakdown.prefill_exec_ns`);
    /// * final chunk — publish the block-aligned prefix (unshared
    ///   sessions), bootstrap the first token from the prefill logits,
    ///   and true the pool reservation up.
    ///
    /// Any chunking produces a cache and token stream bit-identical to
    /// the whole-prompt path (engine chunking is bit-invariant, cache
    /// writes are per-position). Returns true once prefill is complete.
    pub fn advance_prefill(&mut self, engine: &dyn DecodeEngine, chunk: usize) -> Result<bool> {
        if self.prefill_done() {
            return Ok(true);
        }
        self.ensure_backend()?;
        let p_len = engine.model().prefill_len;
        let start = match self.prefill {
            PrefillCursor::Done => unreachable!("handled above"),
            PrefillCursor::InProgress { next } => next,
            PrefillCursor::NotStarted => {
                if self.prefix_att.is_none() {
                    // second-chance lookup: a sharer submitted before us
                    // may have published between admission and now
                    if let Some(idx) = &self.prefix_index {
                        self.prefix_att = idx
                            .attach_quiet(&self.prompt, self.prefix_geom, p_len)
                            .map(|att| match &self.pool {
                                Some(p) => att.rebind_charge(Arc::clone(p)),
                                None => att,
                            });
                    }
                }
                let backend = self.backend.as_mut().expect("backend built above");
                match &self.prefix_att {
                    Some(att) => backend.begin_prefill_shared(Arc::clone(att), p_len)?,
                    None => 0,
                }
            }
        };
        // a zero-length final chunk is legal (the attach covered every
        // prompt position): it only fetches the bootstrap logits
        let len = chunk.max(1).min(p_len - start);
        let t0 = std::time::Instant::now();
        let es0 = engine.exec_stats();
        let out = {
            let backend = self.backend.as_ref().expect("backend built above");
            engine.prefill_chunk(&self.prompt, start, len, &backend.view())?
        };
        note_exec_delta(&mut self.breakdown, es0, engine.exec_stats());
        self.breakdown.prefill_exec_ns += t0.elapsed().as_nanos() as u64;
        self.breakdown.prefill_chunks += 1;
        let backend = self.backend.as_mut().expect("backend built above");
        if len > 0 {
            backend.write_prefill_chunk(&out.k, &out.v, start, start + len);
        }
        let end = start + len;
        if end < p_len {
            self.prefill = PrefillCursor::InProgress { next: end };
            return Ok(false);
        }
        // final chunk: publish, exactly as the whole-prompt path did
        if self.prefix_att.is_none() {
            if let Some(idx) = &self.prefix_index {
                let n = idx.shareable_len(self.prompt.len(), p_len);
                if n > 0 {
                    if let Some(payload) = backend.export_prefix(n) {
                        if let Some(att) =
                            idx.publish(&self.prompt[..n], self.prefix_geom, payload)
                        {
                            // the publisher shares its own prefix too:
                            // the residency charge moves to the index
                            // and this session pays its delta (CoW, if
                            // it comes, charges the session's pool)
                            let att = match &self.pool {
                                Some(p) => att.rebind_charge(Arc::clone(p)),
                                None => att,
                            };
                            backend.reattach_prefix(Arc::clone(&att));
                            self.prefix_att = Some(att);
                        }
                    }
                }
            }
        }
        // bootstrap the first generated token from prefill logits
        let t0 = std::time::Instant::now();
        let next = self.sampler.sample(&out.logits);
        self.breakdown.sample_ns += t0.elapsed().as_nanos() as u64;
        self.tokens.push(next);
        self.pos = p_len;
        self.first_token_at = Some(std::time::Instant::now());
        self.prefill = PrefillCursor::Done;
        // the admission reserve carried the whole prefill; surplus over
        // the actual footprint flows back only now that it is complete
        self.sync_pool();
        Ok(true)
    }

    /// Everything a decode step does *before* the engine call: restore
    /// a suspended snapshot, run prefill, reserve this step's worst-case
    /// KV growth, and flush the ring buffer (`make_room`). Returns the
    /// decode-step scalars the (fused) engine call needs. Split from
    /// [`Session::step`] so a batch of sessions can prepare
    /// individually, then advance with **one**
    /// [`DecodeEngine::decode_batch`] call per step.
    pub fn begin_step(&mut self, engine: &dyn DecodeEngine) -> Result<StepPrep> {
        if self.done() {
            return Ok(StepPrep::Finished);
        }
        if self.suspended.is_some() {
            // swapped-out session re-admitted: restore the cache image
            // instead of recomputing (the admission reserve already
            // covers the restored footprint byte-accurately)
            if let Err(e) = self.resume_from_swap() {
                // a snapshot that fails to restore must not fail the
                // request: release the swap + pool reservations (done
                // inside resume_from_swap / reset) and fall back to the
                // recompute path, exactly as if swapping were disabled
                eprintln!(
                    "session {}: swap-in restore failed ({e:#}); recomputing from prompt",
                    self.id
                );
                self.reset_for_preemption();
            }
            self.sync_pool();
        }
        if !self.prefill_done() {
            // whole-prompt completion (the admission reserve covers the
            // prefill footprint): the single-session path lands here,
            // and it is the safety net for a batched member whose
            // prefill lane did not finish — the batched worker normally
            // advances chunks itself and only calls begin_step once the
            // cursor is Done
            self.prefill(engine)?;
        }
        if self.tokens.len() >= self.max_new_tokens {
            self.finished_at = Some(std::time::Instant::now());
            return Ok(StepPrep::Finished);
        }
        // reserve this step's worst-case KV growth before doing any work
        let headroom = self
            .backend
            .as_ref()
            .expect("prefill built the backend")
            .step_headroom_bytes();
        let want = self.bytes_used() + headroom;
        if !self.ensure_reserved(want) {
            return Ok(StepPrep::NeedMemory);
        }
        let token = *self.tokens.last().expect("prefill bootstraps a token");
        let pos = self.pos;
        let backend = self.backend.as_mut().expect("prefill built the backend");
        backend.make_room(pos, &mut self.breakdown)?;
        Ok(StepPrep::Ready {
            token,
            pos: pos as i32,
            buf_idx: backend.buf_fill() as i32,
        })
    }

    /// Engine-facing borrowed view of this session's cache — valid
    /// between [`Session::begin_step`] returning `Ready` and the engine
    /// call that consumes it.
    pub fn cache_view(&self) -> CacheView<'_> {
        self.backend.as_ref().expect("begin_step built the backend").view()
    }

    /// Everything a decode step does *after* the engine call: absorb
    /// the step outputs into the cache, sample the next token, and true
    /// the pool reservation up. Never returns
    /// [`StepOutcome::NeedMemory`] — growth was reserved in
    /// [`Session::begin_step`].
    pub fn finish_step(
        &mut self,
        out: &DecodeOut,
        engine: &dyn DecodeEngine,
    ) -> Result<StepOutcome> {
        let pos = self.pos;
        let backend = self.backend.as_mut().expect("begin_step built the backend");
        backend.absorb(out, pos, engine.model(), &mut self.breakdown)?;
        let t0 = std::time::Instant::now();
        let next = self.sampler.sample(&out.logits);
        self.breakdown.sample_ns += t0.elapsed().as_nanos() as u64;
        self.tokens.push(next);
        self.pos += 1;
        self.breakdown.steps += 1;
        self.sync_pool();
        if self.tokens.len() >= self.max_new_tokens {
            self.finished_at = Some(std::time::Instant::now());
            return Ok(StepOutcome::Finished);
        }
        Ok(StepOutcome::Running)
    }

    /// Advance one decode step — the single generic path every
    /// compression mode runs ([`Session::begin_step`] → one engine call
    /// → [`Session::finish_step`]; the batched worker path runs the same
    /// halves around one fused call for the whole batch).
    pub fn step(&mut self, engine: &dyn DecodeEngine) -> Result<StepOutcome> {
        match self.begin_step(engine)? {
            StepPrep::Finished => Ok(StepOutcome::Finished),
            StepPrep::NeedMemory => Ok(StepOutcome::NeedMemory),
            StepPrep::Ready { token, pos, buf_idx } => {
                let te = std::time::Instant::now();
                let es0 = engine.exec_stats();
                let out = engine.decode(token, pos, buf_idx, &self.cache_view())?;
                note_exec_delta(&mut self.breakdown, es0, engine.exec_stats());
                self.breakdown.decode_exec_ns += te.elapsed().as_nanos() as u64;
                self.finish_step(&out, engine)
            }
        }
    }

    /// Test-only: fabricate a completed prefill (synthetic K/V, no
    /// engine) so suspend/resume paths can be exercised in artifact-free
    /// unit tests.
    #[cfg(test)]
    pub(crate) fn test_fake_prefill(&mut self) {
        self.ensure_backend().expect("backend builds");
        let m = self.manifest.model.clone();
        let kvd = m.n_kv_heads * m.d_head;
        let pf = crate::runtime::PrefillOut {
            logits: vec![0.0; m.vocab],
            k: vec![0.01; m.n_layers * m.prefill_len * kvd],
            v: vec![0.02; m.n_layers * m.prefill_len * kvd],
            obs: vec![0.0; m.n_layers * m.prefill_len],
        };
        self.backend
            .as_mut()
            .expect("backend built above")
            .write_prefill(&pf, m.prefill_len);
        self.tokens.push(1);
        self.pos = m.prefill_len;
        self.first_token_at = Some(std::time::Instant::now());
        self.prefill = PrefillCursor::Done;
        self.sync_pool();
    }
}

/// Fold the engine's PJRT-execute ledger delta around one engine call
/// into this session's breakdown. The engine is worker-thread-local
/// (`!Sync`), so calls are serialized and the before/after diff is
/// exact for the bracketed call. Saturating: an engine swapped
/// mid-session must not underflow the counters.
fn note_exec_delta(bd: &mut Breakdown, before: ExecStats, after: ExecStats) {
    bd.pjrt_decode_executes += after.decode_executes.saturating_sub(before.decode_executes);
    bd.pjrt_prefill_executes += after.prefill_executes.saturating_sub(before.prefill_executes);
    bd.pjrt_fallback_executes +=
        after.fallback_executes.saturating_sub(before.fallback_executes);
    bd.prefill_memo_hits += after.prefill_memo_hits.saturating_sub(before.prefill_memo_hits);
    bd.prefill_memo_evictions +=
        after.prefill_memo_evictions.saturating_sub(before.prefill_memo_evictions);
}

impl Drop for Session {
    /// A session dropped mid-flight (scheduler shutdown, submitter gone)
    /// must not strand its pool reservation or a suspended swap image.
    fn drop(&mut self) {
        self.release_pool();
        self.drop_swap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::{tiny_cfg, tiny_manifest, FakeEngine};
    use crate::kvcache::SnapshotPayload;

    #[test]
    fn slo_state_slack_and_verdicts() {
        let mut s = SloState {
            class: "chat".into(),
            target: SloTarget::new(100, 2_000),
            submitted_at: 50,
            ..SloState::default()
        };
        assert!(s.classed());
        assert_eq!(s.ttft_slack(60), Some(90));
        assert!(!s.hopeless(150), "on the deadline is still meetable");
        assert!(s.hopeless(151));
        s.first_token_tick = Some(120);
        assert_eq!(s.ttft_slack(500), None, "race over once the token lands");
        assert!(!s.hopeless(500));
        s.finished_tick = Some(130);
        assert_eq!(s.ttft(), Some(70));
        // 5 tokens over 10 ticks = 2500 milli-ticks/token
        assert_eq!(s.tpot_milli(5), Some(2_500));
        assert_eq!(s.met(5), Some(false), "TPOT 2500 > target 2000");
        s.target = SloTarget::new(100, 0);
        assert_eq!(s.met(5), Some(true), "TTFT 70 <= 100, no TPOT target");
        assert_eq!(SloState::default().met(5), None, "unclassed never counts");
    }

    /// Failure injection for the swap-in error path: a snapshot that
    /// fails to restore must release both the swap-pool reservation and
    /// (through the recompute fallback) leave the block-pool books
    /// balanced — the request recomputes instead of failing.
    #[test]
    fn failed_swap_restore_falls_back_to_recompute() {
        let cfg = tiny_cfg();
        let man = tiny_manifest();
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let mut s =
            Session::with_pool(1, vec![1, 2, 3], &cfg, &man, Some(Arc::clone(&pool))).unwrap();
        // admit by hand, as the scheduler would
        let need = s.admission_bytes();
        s.grant(pool.lease(need).expect("admission fits"));
        s.test_fake_prefill();
        let swap = Arc::new(SwapPool::new(64 << 20));
        assert!(s.suspend_to(&swap));
        assert!(s.is_suspended());
        assert!(swap.used() > 0);
        assert_eq!(pool.used(), 0, "device bytes released at suspend");
        // corrupt the host image so restore_state must fail
        {
            let susp = s.suspended.as_mut().expect("suspended");
            let SnapshotPayload::Quant(q) = &mut susp.snap.payload else {
                panic!("quant snapshot expected");
            };
            q.ct.layers[0].k_codes.truncate(1);
        }
        // re-admission reserve, as the scheduler would
        let readmit = s.admission_bytes();
        s.grant(pool.lease(readmit).expect("re-admission fits"));
        let engine = FakeEngine::new(man.model.clone());
        let prep = s.begin_step(&engine).expect("fallback, not failure");
        assert!(matches!(prep, StepPrep::Ready { .. }));
        assert_eq!(s.preemptions, 1, "restore failure counted as a recompute");
        assert_eq!(s.swap_ins, 0, "no successful swap-in");
        assert!(!s.is_suspended());
        assert_eq!(swap.used(), 0, "swap bytes released on the error path");
        assert_eq!(swap.stats().fallbacks, 1);
        assert!(swap.stats().bytes_in == 0);
        // books return to baseline when the session leaves
        drop(s);
        assert_eq!(pool.used(), 0, "block-pool reservation fully released");
    }

    /// Session-level sharing round trip with the causal fake engine:
    /// the publisher exports its prefix, a second session attaches it,
    /// is priced delta-only, and both produce the exact streams of the
    /// unshared path.
    #[test]
    fn sessions_share_prefix_and_streams_match_unshared() {
        let cfg = ServeConfig { max_new_tokens: 6, ..tiny_cfg() };
        let man = tiny_manifest();
        let engine = FakeEngine::new(man.model.clone());
        let system: Vec<i32> = (0..16).collect();
        let mut prompts = Vec::new();
        for tail in 0..3 {
            let mut p = system.clone();
            p.extend([40 + tail, 41 + tail, 42 + tail]);
            prompts.push(p);
        }

        // unshared reference streams
        let mut reference = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let mut s = Session::new(i as u64 + 1, p.clone(), &cfg, &man).unwrap();
            loop {
                match s.step(&engine).unwrap() {
                    StepOutcome::Finished => break,
                    StepOutcome::Running => {}
                    StepOutcome::NeedMemory => panic!("no pool bound"),
                }
            }
            reference.push(s.tokens.clone());
        }

        // shared path: one pool + index, same ids (sampler seeds match)
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let mut sessions: Vec<Session> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Session::with_parts(
                    i as u64 + 1,
                    p.clone(),
                    &cfg,
                    &man,
                    Some(Arc::clone(&pool)),
                    Some(Arc::clone(&idx)),
                )
                .unwrap()
            })
            .collect();
        for s in sessions.iter_mut() {
            let need = s.admission_bytes();
            s.grant(pool.lease(need).expect("admission fits"));
        }
        // serialize: session 1 publishes, 2 and 3 attach at prefill
        for s in sessions.iter_mut() {
            loop {
                match s.step(&engine).unwrap() {
                    StepOutcome::Finished => break,
                    StepOutcome::Running => {}
                    StepOutcome::NeedMemory => panic!("pool unbounded"),
                }
            }
        }
        for (s, r) in sessions.iter().zip(&reference) {
            assert_eq!(&s.tokens, r, "shared stream must be bit-identical");
            assert!(s.has_prefix_attachment());
            assert_eq!(s.shared_prefix_tokens(), 16, "system prompt attached");
        }
        let stats = idx.stats();
        assert_eq!(stats.inserts, 1, "first session published the prefix");
        assert_eq!(stats.hits, 2, "later sessions attached");
        assert_eq!(stats.resident_entries, 1);
        // delta accounting: everyone's bill excludes the shared prefix
        let geom = sessions[0].prefix_geom;
        let shared_bytes = geom.bytes_for(16);
        assert!(shared_bytes > 0);
        for s in &sessions {
            assert!(s.admission_bytes() < s.admission_est);
        }
        // books: sessions + residency, nothing else — and the lease
        // ledger explains every byte
        let session_bytes: u64 = sessions.iter().map(|s| s.reserved_bytes()).sum();
        assert_eq!(pool.used(), session_bytes + shared_bytes);
        pool.assert_conserved();
        drop(sessions);
        assert_eq!(pool.used(), shared_bytes, "only the resident prefix remains");
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), shared_bytes);
        assert_eq!(pool.used(), 0);
        pool.assert_conserved();
    }
}
