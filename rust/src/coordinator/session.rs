//! A decode session: one request's full state machine, advanced one decode
//! step at a time against a worker's PJRT engine.
//!
//! ThinKV sessions own a [`CtCache`] plus the classifier/TBE/TBQ trio;
//! baseline sessions own an [`Fp32Cache`] plus their [`EvictionPolicy`].
//! All cache policy work happens here in Rust — the engine only executes
//! the AOT decode-step HLO.

use anyhow::{bail, Result};

use crate::baselines::eviction::{EvictionPolicy, PosAttn};
use crate::baselines::quant_baselines::PmKvq;
use crate::compress::tbe::{Tbe, TbeConfig};
use crate::compress::tbq::Tbq;
use crate::kvcache::{CacheConfig, CtCache, Fp32Cache, Thought};
use crate::metrics::Breakdown;
use crate::quant::Precision;
use crate::runtime::{DecodeOut, Engine};
use crate::sim::harness::EvictKind;
use crate::thought::classifier::{Classifier, ClassifierConfig};
use crate::thought::sparsity_per_layer;

use super::config::{CompressionMode, ServeConfig};
use super::sampler::Sampler;

const SPARSITY_REL_THRESHOLD: f32 = 0.01; // 1% of row max (paper fn. 2)

enum CacheState {
    Quant {
        cache: CtCache,
        tbq: Tbq,
        tbe: Option<Tbe>,
        classifier: Classifier,
        cur_thought: Thought,
        cur_segment: usize,
        pmkvq: Option<PmKvq>,
    },
    Fp32 {
        cache: Fp32Cache,
        policy: Box<dyn EvictionPolicy>,
        budget: usize,
        gather: bool,
        capacity: usize,
    },
}

pub struct Session {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub pos: usize,
    pub max_new_tokens: usize,
    pub mode_label: String,
    state: CacheState,
    sampler: Sampler,
    pub breakdown: Breakdown,
    pub created: std::time::Instant,
    pub first_token_at: Option<std::time::Instant>,
    pub finished_at: Option<std::time::Instant>,
    prefilled: bool,
}

impl Session {
    pub fn new(
        id: u64,
        prompt: Vec<i32>,
        cfg: &ServeConfig,
        manifest: &crate::model::Manifest,
    ) -> Result<Session> {
        let m = manifest.model.clone();
        let kv_dim = m.n_kv_heads * m.d_head;
        let state = match &cfg.mode {
            CompressionMode::FullKv | CompressionMode::Evict(_) => {
                let need = m.prefill_len + cfg.max_new_tokens + m.buf_slots;
                let capacity = manifest
                    .pick_fp32_cap(need.min(*manifest.fp32_caps.last().unwrap_or(&need)))
                    .or(manifest.fp32_caps.last().copied())
                    .ok_or_else(|| anyhow::anyhow!("no fp32 artifact"))?;
                let (policy, gather, budget): (Box<dyn EvictionPolicy>, bool, usize) =
                    match &cfg.mode {
                        CompressionMode::FullKv => {
                            (Box::new(crate::baselines::eviction::FullKv), false, usize::MAX)
                        }
                        CompressionMode::Evict(kind) => {
                            let p: Box<dyn EvictionPolicy> = match kind {
                                EvictKind::H2O => Box::new(crate::baselines::eviction::H2O::new()),
                                EvictKind::Rkv | EvictKind::RkvOverlapped => {
                                    Box::new(crate::baselines::eviction::Rkv::new())
                                }
                                EvictKind::LazyEviction => {
                                    Box::new(crate::baselines::eviction::LazyEviction::new())
                                }
                                EvictKind::RaaS => {
                                    Box::new(crate::baselines::eviction::RaaS::new())
                                }
                                EvictKind::SnapKv => Box::new(
                                    crate::baselines::eviction::StreamingLlm::new(4),
                                ), // prefill-obs wired post-prefill
                                EvictKind::StreamingLlm => {
                                    Box::new(crate::baselines::eviction::StreamingLlm::new(4))
                                }
                            };
                            (p, kind == &EvictKind::Rkv || kind == &EvictKind::RkvOverlapped, cfg.budget)
                        }
                        _ => unreachable!(),
                    };
                CacheState::Fp32 {
                    cache: Fp32Cache::new(m.n_layers, capacity, kv_dim, m.buf_slots),
                    policy,
                    budget,
                    gather,
                    capacity,
                }
            }
            CompressionMode::ThinKv { .. }
            | CompressionMode::Kivi(_)
            | CompressionMode::PmKvq => {
                let headroom = cfg.budget + m.buf_slots + 64;
                let want = match &cfg.mode {
                    // quantization-only modes never evict: need room for all
                    CompressionMode::Kivi(_) | CompressionMode::PmKvq => {
                        m.prefill_len + cfg.max_new_tokens + m.buf_slots
                    }
                    CompressionMode::ThinKv { no_tbe: true, .. } => {
                        m.prefill_len + cfg.max_new_tokens + m.buf_slots
                    }
                    _ => headroom,
                };
                let capacity = cfg
                    .capacity
                    .or_else(|| manifest.pick_quant_cap(want))
                    .or(manifest.quant_caps.last().copied())
                    .ok_or_else(|| anyhow::anyhow!("no quant artifact"))?;
                let cache = CtCache::new(CacheConfig {
                    layers: m.n_layers,
                    capacity,
                    block_size: 8,
                    hkv: m.n_kv_heads,
                    dh: m.d_head,
                    buf_slots: m.buf_slots,
                });
                let (tbq, tbe, pmkvq) = match &cfg.mode {
                    CompressionMode::ThinKv { assignment, no_tbq, no_tbe } => {
                        let tbq = if *no_tbq {
                            // iso-compression ablation: uniform FP8 (highest
                            // fidelity available on the quant path)
                            Tbq::uniform(Precision::Fp8)
                        } else {
                            Tbq::new(*assignment)
                        };
                        let tbe = (!no_tbe).then(|| {
                            Tbe::new(TbeConfig {
                                retention: cfg.retention.clone(),
                                budget: cfg.budget,
                                kmeans_iters: 8,
                                seed: cfg.seed,
                            })
                        });
                        (tbq, tbe, None)
                    }
                    CompressionMode::Kivi(p) => (Tbq::uniform(*p), None, None),
                    CompressionMode::PmKvq => {
                        (Tbq::uniform(Precision::Fp8), None, Some(PmKvq::default_schedule()))
                    }
                    _ => unreachable!(),
                };
                CacheState::Quant {
                    cache,
                    tbq,
                    tbe,
                    classifier: Classifier::new(ClassifierConfig {
                        layers: vec![0, 1, 2, 3],
                        thresholds: crate::thought::calibration::default_thresholds(3),
                        refresh: cfg.refresh,
                    }),
                    cur_thought: Thought::Reasoning,
                    cur_segment: 0,
                    pmkvq,
                }
            }
        };
        Ok(Session {
            id,
            prompt,
            tokens: Vec::new(),
            pos: 0,
            max_new_tokens: cfg.max_new_tokens,
            mode_label: cfg.mode.label(),
            state,
            sampler: Sampler::new(cfg.temperature, 32, cfg.seed ^ id),
            breakdown: Breakdown::default(),
            created: std::time::Instant::now(),
            first_token_at: None,
            finished_at: None,
            prefilled: false,
        })
    }

    pub fn done(&self) -> bool {
        self.finished_at.is_some()
    }

    /// Live cached tokens (for memory reporting).
    pub fn live_tokens(&self) -> usize {
        match &self.state {
            CacheState::Quant { cache, .. } => cache.live_tokens() + cache.buf_fill(),
            CacheState::Fp32 { cache, .. } => cache.live_tokens() + cache.buf_fill(),
        }
    }

    pub fn avg_bits(&self) -> f64 {
        match &self.state {
            CacheState::Quant { cache, .. } => cache.avg_bits_written(),
            CacheState::Fp32 { .. } => 16.0,
        }
    }

    pub fn ct_reuse_count(&self) -> u64 {
        match &self.state {
            CacheState::Quant { cache, .. } => {
                cache.tables.iter().map(|t| t.reuse_count).sum()
            }
            _ => 0,
        }
    }

    pub fn tbe_stats(&self) -> Option<crate::compress::tbe::TbeStats> {
        match &self.state {
            CacheState::Quant { tbe: Some(t), .. } => Some(t.stats.clone()),
            _ => None,
        }
    }

    pub fn gather_stats(&self) -> (u64, u64, u64) {
        match &self.state {
            CacheState::Fp32 { cache, .. } => {
                (cache.gather_calls, cache.gather_bytes, cache.gather_nanos)
            }
            _ => (0, 0, 0),
        }
    }

    /// Run prompt prefill (once).
    pub fn prefill(&mut self, engine: &Engine) -> Result<()> {
        if self.prefilled {
            return Ok(());
        }
        let m = engine.model().clone();
        let out = engine.prefill(&self.prompt)?;
        let p = m.prefill_len;
        match &mut self.state {
            CacheState::Quant { cache, tbq, .. } => {
                // prefill tokens are R thoughts (paper §6.1)
                let prec = tbq.psi(Thought::Reasoning);
                cache.write_prefill(&out.k, &out.v, p, prec);
            }
            CacheState::Fp32 { cache, .. } => {
                cache.write_prefill(&out.k, &out.v, p);
            }
        }
        // bootstrap the first generated token from prefill logits
        let t0 = std::time::Instant::now();
        let next = self.sampler.sample(&out.logits);
        self.breakdown.sample_ns += t0.elapsed().as_nanos() as u64;
        self.tokens.push(next);
        self.pos = p;
        self.first_token_at = Some(std::time::Instant::now());
        self.prefilled = true;
        Ok(())
    }

    /// Advance one decode step. Returns true while the session is running.
    pub fn step(&mut self, engine: &Engine) -> Result<bool> {
        if self.done() {
            return Ok(false);
        }
        if !self.prefilled {
            self.prefill(engine)?;
        }
        if self.tokens.len() >= self.max_new_tokens {
            self.finished_at = Some(std::time::Instant::now());
            return Ok(false);
        }
        let token = *self.tokens.last().expect("prefill bootstraps a token");
        let m = engine.model().clone();
        let out = match &mut self.state {
            CacheState::Quant { .. } => self.step_quant(engine, token)?,
            CacheState::Fp32 { .. } => self.step_fp32(engine, token)?,
        };
        let t0 = std::time::Instant::now();
        let next = self.sampler.sample(&out.logits);
        self.breakdown.sample_ns += t0.elapsed().as_nanos() as u64;
        self.tokens.push(next);
        self.pos += 1;
        self.breakdown.steps += 1;
        let _ = m;
        if self.tokens.len() >= self.max_new_tokens {
            self.finished_at = Some(std::time::Instant::now());
            return Ok(false);
        }
        Ok(true)
    }

    fn step_quant(&mut self, engine: &Engine, token: i32) -> Result<DecodeOut> {
        let m = engine.model().clone();
        let CacheState::Quant {
            cache,
            tbq,
            tbe,
            classifier,
            cur_thought,
            cur_segment,
            pmkvq,
        } = &mut self.state
        else {
            unreachable!()
        };
        if cache.segments.is_empty() {
            bail!("prefill did not initialize segments");
        }
        if *cur_segment == 0 && cache.segments.len() == 1 {
            // first decode token: open the initial decode segment
            *cur_segment = cache.open_segment(*cur_thought, self.pos);
        }

        // 1. flush the fp ring buffer if full (group quantization, TBQ)
        if cache.buf_fill() == cache.cfg.buf_slots {
            let tq = std::time::Instant::now();
            let psi = |t: Thought| tbq.psi(t);
            if cache.flush_buffer(&psi).is_err() {
                // TBE case 2 under allocation pressure
                if let Some(tbe) = tbe.as_mut() {
                    let te = std::time::Instant::now();
                    tbe.ensure_budget(cache);
                    self.breakdown.tbe_ns += te.elapsed().as_nanos() as u64;
                    self.breakdown.tbe_calls += 1;
                }
                if cache.flush_buffer(&psi).is_err() {
                    bail!("cache exhausted even after TBE (budget too small for capacity)");
                }
            }
            self.breakdown.quant_write_ns += tq.elapsed().as_nanos() as u64;
        }

        // 2. decode step over the quantized cache
        let te = std::time::Instant::now();
        let out = engine.decode_quant(token, self.pos as i32, cache.buf_fill() as i32, &cache.view())?;
        self.breakdown.decode_exec_ns += te.elapsed().as_nanos() as u64;

        // 3. sparsity -> classifier
        let tr = std::time::Instant::now();
        let c = cache.cfg.capacity;
        let b = cache.cfg.buf_slots;
        let span = c + b;
        let mut valid = vec![0f32; m.n_layers * span];
        for l in 0..m.n_layers {
            valid[l * span..l * span + c].copy_from_slice(&cache.mask[l * c..(l + 1) * c]);
            valid[l * span + c..(l + 1) * span]
                .copy_from_slice(&cache.buf_mask[l * b..(l + 1) * b]);
        }
        let per_layer = sparsity_per_layer(
            &out.probs,
            &valid,
            m.n_layers,
            m.n_heads,
            span,
            SPARSITY_REL_THRESHOLD,
        );
        classifier.push_step(&per_layer);
        if classifier.due() {
            let closing = *cur_thought;
            let label = classifier.refresh();
            self.breakdown.refresh_calls += 1;
            // TBE case 1 at the end of a transition window
            if closing == Thought::Transition {
                if let Some(tbe) = tbe.as_mut() {
                    let tt = std::time::Instant::now();
                    tbe.on_transition_end(cache, *cur_segment);
                    self.breakdown.tbe_ns += tt.elapsed().as_nanos() as u64;
                    self.breakdown.tbe_calls += 1;
                }
            }
            *cur_thought = label;
            *cur_segment = cache.open_segment(label, self.pos + 1);
        }
        self.breakdown.refresh_ns += tr.elapsed().as_nanos() as u64;

        // 4. push the new token into B_buf
        let tq = std::time::Instant::now();
        cache.push_token(&out.new_k, &out.new_v, self.pos, *cur_segment, *cur_thought);
        self.breakdown.quant_write_ns += tq.elapsed().as_nanos() as u64;

        // 5. TBE case 2: budget
        if let Some(tbe) = tbe.as_mut() {
            tbe.tick();
            if cache.live_tokens() + cache.buf_fill() > tbe.cfg.budget {
                let tt = std::time::Instant::now();
                let evicted = tbe.ensure_budget(cache);
                self.breakdown.tbe_ns += tt.elapsed().as_nanos() as u64;
                if evicted > 0 {
                    self.breakdown.tbe_calls += 1;
                }
            }
        }

        // 6. PM-KVQ progressive requantization
        if let Some(pm) = pmkvq {
            if self.pos % 128 == 0 {
                let tp = std::time::Instant::now();
                pm.apply(cache, self.pos);
                self.breakdown.policy_ns += tp.elapsed().as_nanos() as u64;
                self.breakdown.policy_calls += 1;
            }
        }
        Ok(out)
    }

    fn step_fp32(&mut self, engine: &Engine, token: i32) -> Result<DecodeOut> {
        let m = engine.model().clone();
        let CacheState::Fp32 { cache, policy, budget, gather, capacity } = &mut self.state
        else {
            unreachable!()
        };
        // flush buffer if full
        if cache.buf_fill() == cache.buf_slots {
            while cache.flush_buffer().is_err() {
                let tp = std::time::Instant::now();
                let live = cache.live_positions();
                let target = live.len().saturating_sub(cache.buf_slots);
                let evict = policy.select_evictions(&live, target);
                if evict.is_empty() {
                    bail!("fp32 cache full and policy refuses to evict");
                }
                cache.evict_positions(&evict);
                self.breakdown.policy_ns += tp.elapsed().as_nanos() as u64;
                self.breakdown.policy_calls += 1;
                if *gather {
                    let tg = std::time::Instant::now();
                    cache.compact_gather();
                    self.breakdown.gather_ns += tg.elapsed().as_nanos() as u64;
                    self.breakdown.gather_calls += 1;
                }
            }
        }

        let te = std::time::Instant::now();
        let out = engine.decode_fp32(
            *capacity,
            token,
            self.pos as i32,
            cache.buf_fill() as i32,
            &cache.k,
            &cache.v,
            &cache.mask,
            &cache.buf_k,
            &cache.buf_v,
            &cache.buf_mask,
        )?;
        self.breakdown.decode_exec_ns += te.elapsed().as_nanos() as u64;

        // feed attention stats to the policy (mean over layers+heads)
        let tp = std::time::Instant::now();
        let span = *capacity + cache.buf_slots;
        let mut pos_attn = Vec::new();
        for slot in 0..*capacity {
            let p = cache.slot_pos[slot];
            if p < 0 {
                continue;
            }
            let mut acc = 0f32;
            for l in 0..m.n_layers {
                for h in 0..m.n_heads {
                    acc += out.probs[(l * m.n_heads + h) * span + slot];
                }
            }
            pos_attn.push((p as usize, acc / (m.n_layers * m.n_heads) as f32));
        }
        policy.observe(&PosAttn { step: self.pos, attn: pos_attn });
        self.breakdown.policy_ns += tp.elapsed().as_nanos() as u64;

        cache.push_token(&out, self.pos);

        // budget enforcement
        if *budget != usize::MAX {
            let live = cache.live_positions();
            if live.len() + cache.buf_fill() > *budget {
                let tp = std::time::Instant::now();
                let target = budget.saturating_sub(cache.buf_fill());
                let evict = policy.select_evictions(&live, target);
                if !evict.is_empty() {
                    cache.evict_positions(&evict);
                    self.breakdown.policy_calls += 1;
                    if *gather {
                        let tg = std::time::Instant::now();
                        cache.compact_gather();
                        self.breakdown.gather_ns += tg.elapsed().as_nanos() as u64;
                        self.breakdown.gather_calls += 1;
                    }
                }
                self.breakdown.policy_ns += tp.elapsed().as_nanos() as u64;
            }
        }
        Ok(out)
    }
}
