//! The multi-replica serving tier: a [`Router`] in front of N
//! [`Replica`]s, each a full memory-aware [`Scheduler`] with its own
//! [`BlockPool`] / [`SwapPool`], plus **live session migration** —
//! `Router::rebalance` suspends a victim on a hot replica through the
//! existing `KvSnapshot` path and resumes it mid-decode on a cold one,
//! bit-exactly, with tokens / sampler / SLO clock intact. ThinKV makes
//! this cheap: a compressed session snapshot is a few hundred KB, so
//! moving a session costs less than recomputing even a short prefix.
//!
//! The router also owns the fleet-global [`PrefixIndex`]: a shared
//! system prompt is resident **once per fleet** (charged to replica 0's
//! pool), not once per replica; per-session CoW privatizations charge
//! the owning session's replica pool (see `AttachedPrefix::rebind_charge`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use crate::kvcache::{BatchKey, BlockPool, PrefixIndex, SwapPool};
use crate::metrics::SchedSnapshot;

use super::engine_loop::RequestResult;
use super::scheduler::Scheduler;
use super::session::Session;

/// One serving replica: a scheduler bound to its own device block pool
/// and (optionally) host swap pool. Worker threads are owned by the
/// [`super::Coordinator`]; deterministic harnesses drive the scheduler
/// directly with [`super::advance_batch`].
pub struct Replica {
    id: usize,
    scheduler: Arc<Scheduler>,
}

impl Replica {
    /// Build a replica over fresh pools. `prefix` is the fleet-shared
    /// index (same `Arc` on every replica, or `None`).
    pub fn new(
        id: usize,
        pool: Arc<BlockPool>,
        swap: Option<Arc<SwapPool>>,
        prefix: Option<Arc<PrefixIndex>>,
    ) -> Replica {
        Replica { id, scheduler: Arc::new(Scheduler::with_prefix(pool, swap, prefix)) }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.scheduler
    }
}

/// Fleet front end: places new sessions by least-loaded-lane scoring,
/// owns the fleet-global prefix index, and live-migrates sessions off
/// hot replicas ([`Router::rebalance`]).
pub struct Router {
    replicas: Vec<Replica>,
    prefix: Option<Arc<PrefixIndex>>,
    migrations: AtomicU64,
    migration_bytes: AtomicU64,
    migration_ns: AtomicU64,
}

/// A replica must lead the coldest one by at least this many queued
/// sessions before `rebalance` moves anything — hysteresis so a fleet
/// in steady state does not thrash sessions back and forth.
const REBALANCE_GAP: usize = 2;

impl Router {
    /// Build an `n`-replica fleet. Every replica gets its own pools
    /// (`pool_bytes` / `swap_bytes` are **per replica**); the fleet
    /// prefix index accounts residency against replica 0's pool, so a
    /// 1-replica router is byte-identical to the legacy single
    /// scheduler. `prefix_block` is the trie granularity in tokens.
    pub fn new(
        n: usize,
        pool_bytes: u64,
        swap_bytes: Option<u64>,
        prefix_share: bool,
        prefix_block: usize,
    ) -> Router {
        let n = n.max(1);
        let pools: Vec<Arc<BlockPool>> =
            (0..n).map(|_| Arc::new(BlockPool::new(pool_bytes))).collect();
        let prefix = prefix_share.then(|| PrefixIndex::new(Arc::clone(&pools[0]), prefix_block));
        let replicas = pools
            .into_iter()
            .enumerate()
            .map(|(id, pool)| {
                let swap = swap_bytes.map(|b| Arc::new(SwapPool::new(b)));
                Replica::new(id, pool, swap, prefix.clone())
            })
            .collect();
        Router {
            replicas,
            prefix,
            migrations: AtomicU64::new(0),
            migration_bytes: AtomicU64::new(0),
            migration_ns: AtomicU64::new(0),
        }
    }

    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    /// The fleet-shared prefix index (resident payloads charged once,
    /// to replica 0's pool).
    pub fn prefix_index(&self) -> Option<&Arc<PrefixIndex>> {
        self.prefix.as_ref()
    }

    /// Live migrations completed so far.
    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::SeqCst)
    }

    /// Least-loaded-lane placement: the replica where this session's
    /// `BatchKey` lane is shortest (a lone fp32 session lands where it
    /// cannot cap a quant-heavy queue's batch width), total queued load
    /// breaking ties, replica id breaking those — so placement is
    /// deterministic and replica 0 wins an empty-fleet tie, keeping the
    /// 1-replica path byte-identical to the legacy scheduler.
    pub fn place(&self, key: &BatchKey) -> usize {
        self.replicas
            .iter()
            .map(|r| {
                let lane = r
                    .scheduler
                    .lane_occupancy()
                    .iter()
                    .find(|(k, _)| k == key)
                    .map_or(0, |(_, n)| *n);
                (lane, r.scheduler.load(), r.id)
            })
            .min()
            .map(|(_, _, id)| id)
            .expect("router has at least one replica")
    }

    /// Place and submit a session; returns the chosen replica id. The
    /// session must have been built against that replica's pool — use
    /// [`Router::place`] first, or go through `Coordinator::submit`
    /// which does both.
    pub fn submit_to(
        &self,
        replica: usize,
        session: Session,
        done_tx: mpsc::Sender<RequestResult>,
    ) {
        self.replicas[replica].scheduler.submit(session, done_tx);
    }

    /// One rebalance pass: while the most loaded replica leads the
    /// least loaded by at least [`REBALANCE_GAP`] queued sessions,
    /// live-migrate one session hot → cold (suspend on the source via
    /// its swap pool, rebind to the destination pool + fleet prefix,
    /// resume there with zero recompute steps). Bounded at one
    /// migration per replica per pass. Returns migrations performed.
    pub fn rebalance(&self) -> usize {
        if self.replicas.len() < 2 {
            return 0;
        }
        let mut moved = 0;
        for _ in 0..self.replicas.len() {
            let loads: Vec<usize> =
                self.replicas.iter().map(|r| r.scheduler.load()).collect();
            let hot = (0..loads.len()).max_by_key(|&i| loads[i]).expect("nonempty");
            let cold = (0..loads.len()).min_by_key(|&i| loads[i]).expect("nonempty");
            if hot == cold || loads[hot] < loads[cold] + REBALANCE_GAP {
                break;
            }
            if !self.migrate_one(hot, cold) {
                break;
            }
            moved += 1;
        }
        moved
    }

    /// Migrate one session from `hot` to `cold`. Returns false when no
    /// session on `hot` is safely migratable (or `hot` has no swap pool
    /// to stage the snapshot through).
    fn migrate_one(&self, hot: usize, cold: usize) -> bool {
        let src = &self.replicas[hot].scheduler;
        let dst = &self.replicas[cold].scheduler;
        let Some(swap) = src.swap_pool().cloned() else { return false };
        let Some(mut entry) = src.take_for_migration() else { return false };
        let t0 = std::time::Instant::now();
        // priced before the move so the destination's cost-ordered
        // resume sees the same restore-vs-recompute tradeoff a local
        // preemption victim would
        let live_bytes = entry.session.bytes_used().max(entry.session.admission_bytes());
        let replay_steps = entry.session.pos.max(1);
        if !entry.session.suspend_to(&swap) {
            // snapshot did not fit the source swap pool: hand the
            // untouched victim straight back — migration is strictly
            // opportunistic and never degrades a session to recompute
            src.return_from_migration(entry);
            return false;
        }
        let bytes = entry.session.suspended_bytes().unwrap_or(0);
        // carry the deterministic clock across: the destination's tick
        // source must be at least the source's or the migrated
        // session's SLO stamps would travel back in time
        if let Some(t) = src.logical_clock() {
            dst.drive_clock(t);
        }
        entry.session.rebind_for_migration(Arc::clone(dst.pool()), self.prefix.clone());
        dst.price_resume(&mut entry.session, live_bytes, replay_steps);
        dst.resubmit(entry.session, entry.done_tx);
        src.migration_release();
        self.migrations.fetch_add(1, Ordering::SeqCst);
        self.migration_bytes.fetch_add(bytes, Ordering::SeqCst);
        self.migration_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::SeqCst);
        true
    }

    /// Per-replica snapshots, replica order.
    pub fn replica_snapshots(&self) -> Vec<SchedSnapshot> {
        self.replicas.iter().map(|r| r.scheduler.snapshot()).collect()
    }

    /// Fleet-merged snapshot: counters and pool gauges summed across
    /// replicas (prefix books kept from replica 0 — the index is
    /// fleet-shared, so every replica reports the same values), stamped
    /// with the router's migration counters.
    pub fn snapshot(&self) -> SchedSnapshot {
        let mut snaps = self.replica_snapshots().into_iter();
        let mut merged = snaps.next().expect("router has at least one replica");
        for s in snaps {
            merged.merge_replica(&s);
        }
        merged.migrations = self.migrations.load(Ordering::SeqCst);
        merged.migration_bytes = self.migration_bytes.load(Ordering::SeqCst);
        merged.migration_ns = self.migration_ns.load(Ordering::SeqCst);
        merged
    }

    /// Total sessions submitted and not yet finished, fleet-wide.
    pub fn inflight(&self) -> u64 {
        self.replicas.iter().map(|r| r.scheduler.inflight()).sum()
    }

    /// Stop every replica's scheduler (workers drain and exit).
    pub fn shutdown(&self) {
        for r in &self.replicas {
            r.scheduler.shutdown();
        }
    }
}
