//! Baseline KV-compression policies the paper compares against (§6.1):
//! eviction — H2O, RaaS, R-KV, LazyEviction, SnapKV, StreamingLLM;
//! quantization — KIVI, PM-KVQ (built on the TBQ machinery with uniform /
//! progressive tags).
//!
//! All eviction baselines implement [`EvictionPolicy`]: they observe each
//! decode step's attention row over CoT *positions* (model-agnostic — the
//! same policies run against the real PJRT model and the LRM trace
//! simulator) and, when the cache exceeds budget, nominate positions to
//! evict. Unlike ThinKV's CT cache, evictions here leave holes that
//! require gather compaction (R-KV) or are constrained to be contiguous
//! (H2O's circular buffer).

pub mod eviction;
pub mod quant_baselines;

pub use eviction::{
    filter_guarded, CrystalKv, EvictionPolicy, FullKv, LazyEviction, PolicyKind, PosAttn, RaaS,
    RetentionCounters, RetentionEvent, RetentionTrace, Rkv, SkipKv, SnapKv, StreamingLlm, H2O,
};
pub use quant_baselines::{Kivi, PmKvq};
