//! Eviction baselines (paper §1.1, §6.1).
//!
//! Every policy sees, per decode step, the attention mass each cached CoT
//! position received (mean over layers and heads) and keeps whatever
//! statistics the original system keeps. `select_evictions` is called when
//! the live set must shrink to `target` positions.

use std::collections::BTreeMap;

/// Attention received per CoT position at one decode step.
#[derive(Debug, Clone, Default)]
pub struct PosAttn {
    pub step: usize,
    /// (position, attention mass) — positions currently visible.
    pub attn: Vec<(usize, f32)>,
}

impl PosAttn {
    pub fn get(&self, pos: usize) -> f32 {
        self.attn
            .iter()
            .find(|(p, _)| *p == pos)
            .map(|(_, a)| *a)
            .unwrap_or(0.0)
    }
}

pub trait EvictionPolicy: Send {
    fn name(&self) -> &'static str;

    /// Observe one decode step's attention row.
    fn observe(&mut self, attn: &PosAttn);

    /// Choose positions (from `live`) to evict so ~`target` remain.
    /// `live` is ascending. Must return distinct members of `live`.
    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize>;

    /// Whether evictions leave non-contiguous holes needing gather
    /// compaction (R-KV and friends) — drives the Figure-7 cost model.
    fn needs_gather(&self) -> bool {
        true
    }

    /// Clone into a new boxed policy carrying the same accumulated
    /// statistics — suspend-to-host snapshots
    /// ([`crate::kvcache::swap::Fp32Snapshot`]) duplicate the policy so
    /// eviction decisions are identical after a resume.
    fn box_clone(&self) -> Box<dyn EvictionPolicy>;
}

impl Clone for Box<dyn EvictionPolicy> {
    fn clone(&self) -> Box<dyn EvictionPolicy> {
        self.box_clone()
    }
}

// ---------------------------------------------------------------------------
// FullKV
// ---------------------------------------------------------------------------

/// No compression: the FullKV reference.
#[derive(Debug, Clone, Default)]
pub struct FullKv;

impl EvictionPolicy for FullKv {
    fn name(&self) -> &'static str {
        "FullKV"
    }

    fn observe(&mut self, _attn: &PosAttn) {}

    fn select_evictions(&mut self, _live: &[usize], _target: usize) -> Vec<usize> {
        Vec::new()
    }

    fn needs_gather(&self) -> bool {
        false
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// H2O (Zhang et al., 2023)
// ---------------------------------------------------------------------------

/// Heavy-Hitter Oracle: keep the top-scoring "heavy hitters" (cumulative
/// attention) plus a recency window; ring-buffer semantics in the original
/// mean evictions are taken from the *oldest non-heavy* region.
#[derive(Debug, Clone)]
pub struct H2O {
    cum: BTreeMap<usize, f64>,
    last_step: usize,
    /// Fraction of the budget reserved for heavy hitters (rest = recent).
    pub heavy_frac: f64,
}

impl H2O {
    pub fn new() -> H2O {
        H2O { cum: BTreeMap::new(), last_step: 0, heavy_frac: 0.5 }
    }
}

impl Default for H2O {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for H2O {
    fn name(&self) -> &'static str {
        "H2O"
    }

    fn observe(&mut self, attn: &PosAttn) {
        self.last_step = attn.step;
        for (p, a) in &attn.attn {
            *self.cum.entry(*p).or_insert(0.0) += *a as f64;
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let heavy_n = ((target as f64) * self.heavy_frac) as usize;
        let recent_n = target - heavy_n;
        // recency-protected tail
        let recent: std::collections::BTreeSet<usize> =
            live.iter().rev().take(recent_n).copied().collect();
        // heavy hitters among the rest
        let mut rest: Vec<usize> = live.iter().filter(|p| !recent.contains(p)).copied().collect();
        rest.sort_by(|a, b| {
            let sa = self.cum.get(a).copied().unwrap_or(0.0);
            let sb = self.cum.get(b).copied().unwrap_or(0.0);
            sb.partial_cmp(&sa).unwrap()
        });
        rest.into_iter().skip(heavy_n).collect()
    }

    fn needs_gather(&self) -> bool {
        // the original uses a ring buffer; no gather kernels on the hot path
        false
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// R-KV (Cai et al., 2025)
// ---------------------------------------------------------------------------

/// Redundancy-aware KV: importance (cumulative attention, recency-decayed)
/// combined with redundancy (similarity to already-kept positions in
/// *attention-pattern* space). Evicts the lowest combined score; leaves
/// non-contiguous holes, so the original needs gather compaction — the
/// §5.1 cost this repo reproduces.
#[derive(Debug, Clone)]
pub struct Rkv {
    cum: BTreeMap<usize, f64>,
    recent: BTreeMap<usize, f64>, // exponentially decayed
    pub lambda: f64,              // importance vs redundancy mix
    decay: f64,
}

impl Rkv {
    pub fn new() -> Rkv {
        Rkv { cum: BTreeMap::new(), recent: BTreeMap::new(), lambda: 0.7, decay: 0.95 }
    }
}

impl Default for Rkv {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for Rkv {
    fn name(&self) -> &'static str {
        "R-KV"
    }

    fn observe(&mut self, attn: &PosAttn) {
        for v in self.recent.values_mut() {
            *v *= self.decay;
        }
        for (p, a) in &attn.attn {
            *self.cum.entry(*p).or_insert(0.0) += *a as f64;
            *self.recent.entry(*p).or_insert(0.0) += *a as f64;
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        // score = λ·importance + (1-λ)·recent-uniqueness; redundancy proxy:
        // positions adjacent to higher-scored neighbours are redundant.
        let imp: Vec<f64> = live
            .iter()
            .map(|p| self.cum.get(p).copied().unwrap_or(0.0))
            .collect();
        let rec: Vec<f64> = live
            .iter()
            .map(|p| self.recent.get(p).copied().unwrap_or(0.0))
            .collect();
        let maxi = imp.iter().cloned().fold(1e-12, f64::max);
        let maxr = rec.iter().cloned().fold(1e-12, f64::max);
        let mut scored: Vec<(f64, usize)> = live
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let redundancy = if i > 0 && imp[i - 1] >= imp[i] { 0.3 } else { 0.0 };
                let s = self.lambda * imp[i] / maxi + (1.0 - self.lambda) * rec[i] / maxr
                    - redundancy * (imp[i] / maxi);
                (s, p)
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        scored
            .into_iter()
            .take(live.len() - target)
            .map(|(_, p)| p)
            .collect()
    }

    fn needs_gather(&self) -> bool {
        true
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// LazyEviction (Zhang et al., 2025a)
// ---------------------------------------------------------------------------

/// Lagged eviction with attention-pattern observation: tokens whose
/// attention *recurred* recently are protected for a lag window even if
/// their cumulative score is low.
#[derive(Debug, Clone)]
pub struct LazyEviction {
    cum: BTreeMap<usize, f64>,
    last_attended: BTreeMap<usize, usize>,
    /// Positions that re-emerged (were dormant > lag, then attended again).
    recurrent: BTreeMap<usize, usize>,
    step: usize,
    pub lag: usize,
    pub attend_threshold: f32,
}

impl LazyEviction {
    pub fn new() -> LazyEviction {
        LazyEviction {
            cum: BTreeMap::new(),
            last_attended: BTreeMap::new(),
            recurrent: BTreeMap::new(),
            step: 0,
            lag: 64,
            attend_threshold: 0.0,
        }
    }
}

impl Default for LazyEviction {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for LazyEviction {
    fn name(&self) -> &'static str {
        "LazyEviction"
    }

    fn observe(&mut self, attn: &PosAttn) {
        self.step = attn.step;
        let rel = (self.attend_threshold as f64)
            .max(1.4 / attn.attn.len().max(1) as f64) as f32;
        for (p, a) in &attn.attn {
            *self.cum.entry(*p).or_insert(0.0) += *a as f64;
            if *a > rel {
                if let Some(&prev) = self.last_attended.get(p) {
                    if attn.step.saturating_sub(prev) > self.lag {
                        // dormant then re-attended: a recurrence event —
                        // LazyEviction's signal that eviction must lag
                        self.recurrent.insert(*p, attn.step);
                    }
                }
                self.last_attended.insert(*p, attn.step);
            }
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        // protected: tokens with a *recurrence* event within the lag window
        let mut candidates: Vec<(f64, usize)> = live
            .iter()
            .filter(|p| {
                self.recurrent
                    .get(p)
                    .map(|&s| self.step.saturating_sub(s) > self.lag)
                    .unwrap_or(true)
            })
            .map(|&p| (self.cum.get(&p).copied().unwrap_or(0.0), p))
            .collect();
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out: Vec<usize> = candidates.into_iter().take(need).map(|(_, p)| p).collect();
        if out.len() < need {
            // lag protection exceeded the budget: fall back to lowest score
            let chosen: std::collections::BTreeSet<usize> = out.iter().copied().collect();
            let mut rest: Vec<(f64, usize)> = live
                .iter()
                .filter(|p| !chosen.contains(p))
                .map(|&p| (self.cum.get(&p).copied().unwrap_or(0.0), p))
                .collect();
            rest.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            out.extend(rest.into_iter().take(need - out.len()).map(|(_, p)| p));
        }
        out
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// RaaS (Hu et al., 2025)
// ---------------------------------------------------------------------------

/// Reasoning-aware attention sparsity: "milestone" tokens get timestamps
/// refreshed whenever they re-emerge; eviction removes the stalest
/// timestamps first.
#[derive(Debug, Clone)]
pub struct RaaS {
    timestamp: BTreeMap<usize, usize>,
    step: usize,
    pub milestone_threshold: f32,
}

impl RaaS {
    pub fn new() -> RaaS {
        RaaS { timestamp: BTreeMap::new(), step: 0, milestone_threshold: 0.0 }
    }
}

impl Default for RaaS {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for RaaS {
    fn name(&self) -> &'static str {
        "RaaS"
    }

    fn observe(&mut self, attn: &PosAttn) {
        self.step = attn.step;
        // milestone threshold is relative to the mean row mass: with n live
        // positions, "re-emergent" means clearly above uniform attention.
        let rel = (self.milestone_threshold as f64)
            .max(1.4 / attn.attn.len().max(1) as f64) as f32;
        for (p, a) in &attn.attn {
            let e = self.timestamp.entry(*p).or_insert(attn.step);
            if *a > rel {
                *e = attn.step; // re-emergent importance refreshes the clock
            }
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let mut ts: Vec<(usize, usize)> = live
            .iter()
            .map(|&p| (self.timestamp.get(&p).copied().unwrap_or(0), p))
            .collect();
        ts.sort();
        ts.into_iter()
            .take(live.len() - target)
            .map(|(_, p)| p)
            .collect()
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// SnapKV (Li et al., 2024) — prefill compression + recency decode window
// ---------------------------------------------------------------------------

/// SnapKV selects prompt positions by pooled observation-window attention
/// at prefill; during decode it keeps a sliding recent window (it was
/// designed for long inputs, which is why it underperforms on long outputs
/// — Figure 8).
#[derive(Debug, Clone)]
pub struct SnapKv {
    /// Positions chosen at prefill (protected).
    pub prefill_keep: Vec<usize>,
}

impl SnapKv {
    /// `obs[pos]` = prefill observation scores; keep top `keep_n`.
    pub fn from_prefill_obs(obs: &[f32], keep_n: usize) -> SnapKv {
        let keep = crate::util::stats::top_k(obs, keep_n);
        SnapKv { prefill_keep: keep }
    }
}

impl EvictionPolicy for SnapKv {
    fn name(&self) -> &'static str {
        "SnapKV"
    }

    fn observe(&mut self, _attn: &PosAttn) {}

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        let protected: std::collections::BTreeSet<usize> =
            self.prefill_keep.iter().copied().collect();
        // evict oldest unprotected first
        let mut out = Vec::new();
        for &p in live {
            if out.len() == need {
                break;
            }
            if !protected.contains(&p) {
                out.push(p);
            }
        }
        // if everything old is protected, evict oldest protected
        let mut i = 0;
        while out.len() < need && i < live.len() {
            if !out.contains(&live[i]) {
                out.push(live[i]);
            }
            i += 1;
        }
        out
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// StreamingLLM (Xiao et al., 2023) — attention sinks + sliding window
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct StreamingLlm {
    pub sinks: usize,
}

impl StreamingLlm {
    pub fn new(sinks: usize) -> StreamingLlm {
        StreamingLlm { sinks }
    }
}

impl EvictionPolicy for StreamingLlm {
    fn name(&self) -> &'static str {
        "StreamingLLM"
    }

    fn observe(&mut self, _attn: &PosAttn) {}

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        live.iter()
            .filter(|&&p| p >= self.sinks) // sinks are immortal
            .take(need)
            .copied()
            .collect()
    }

    fn needs_gather(&self) -> bool {
        false // contiguous window: ring-buffer friendly
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn steps(policy: &mut dyn EvictionPolicy, rows: &[Vec<(usize, f32)>]) {
        for (i, r) in rows.iter().enumerate() {
            policy.observe(&PosAttn { step: i, attn: r.clone() });
        }
    }

    #[test]
    fn fullkv_never_evicts() {
        let mut p = FullKv;
        assert!(p.select_evictions(&[0, 1, 2, 3], 1).is_empty());
        assert!(!p.needs_gather());
    }

    #[test]
    fn h2o_keeps_heavy_hitters_and_recent() {
        let mut p = H2O::new();
        // position 2 is a heavy hitter
        let rows: Vec<Vec<(usize, f32)>> = (0..10)
            .map(|_| vec![(0, 0.01), (1, 0.01), (2, 0.9), (3, 0.01), (4, 0.02)])
            .collect();
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4], 2);
        assert!(!evicted.contains(&2), "heavy hitter evicted: {evicted:?}");
        assert!(!evicted.contains(&4), "most recent evicted: {evicted:?}");
        assert_eq!(evicted.len(), 3);
    }

    #[test]
    fn rkv_evicts_low_importance() {
        let mut p = Rkv::new();
        let rows: Vec<Vec<(usize, f32)>> = (0..20)
            .map(|_| vec![(0, 0.4), (1, 0.005), (2, 0.4), (3, 0.005), (4, 0.19)])
            .collect();
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4], 3);
        assert_eq!(evicted.len(), 2);
        assert!(evicted.contains(&1) && evicted.contains(&3), "{evicted:?}");
        assert!(p.needs_gather());
    }

    #[test]
    fn lazy_eviction_protects_recurrent_tokens() {
        let mut p = LazyEviction::new();
        p.lag = 5;
        // position 0: attended early, dormant for > lag, then re-attended at
        // step 9 — a recurrence event that must delay its eviction.
        let mut rows: Vec<Vec<(usize, f32)>> =
            vec![vec![(0, 0.4), (1, 0.2), (2, 0.2), (3, 0.2)]];
        rows.extend((1..9).map(|_| vec![(0, 0.001), (1, 0.3), (2, 0.3), (3, 0.3)]));
        rows.push(vec![(0, 0.5), (1, 0.1), (2, 0.2), (3, 0.2)]);
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2, 3], 3);
        assert!(!evicted.contains(&0), "recurrent token evicted: {evicted:?}");
    }

    #[test]
    fn raas_drops_stalest_timestamp() {
        let mut p = RaaS::new();
        let rows: Vec<Vec<(usize, f32)>> = (0..10)
            .map(|i| {
                vec![
                    (0, if i < 2 { 0.5 } else { 0.001 }), // stale after step 1
                    (1, 0.5),
                    (2, 0.5),
                ]
            })
            .collect();
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2], 2);
        assert_eq!(evicted, vec![0]);
    }

    #[test]
    fn snapkv_protects_prefill_selection() {
        let obs = vec![0.1f32, 0.9, 0.05, 0.8, 0.02];
        let mut p = SnapKv::from_prefill_obs(&obs, 2);
        assert_eq!(p.prefill_keep, vec![1, 3]);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4], 3);
        assert_eq!(evicted, vec![0, 2]);
    }

    #[test]
    fn streaming_llm_keeps_sinks() {
        let mut p = StreamingLlm::new(2);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4, 5], 4);
        assert_eq!(evicted, vec![2, 3]);
        assert!(!p.needs_gather());
    }

    #[test]
    fn box_clone_preserves_accumulated_state() {
        let mut p = Rkv::new();
        let rows: Vec<Vec<(usize, f32)>> = (0..20)
            .map(|_| vec![(0, 0.4), (1, 0.005), (2, 0.4), (3, 0.005), (4, 0.19)])
            .collect();
        steps(&mut p, &rows);
        let mut clone = p.box_clone();
        assert_eq!(clone.name(), "R-KV");
        // identical state => identical eviction decisions
        let a = p.select_evictions(&[0, 1, 2, 3, 4], 3);
        let b = clone.select_evictions(&[0, 1, 2, 3, 4], 3);
        assert_eq!(a, b);
    }

    #[test]
    fn policies_return_distinct_members() {
        let live: Vec<usize> = (0..50).collect();
        let mut rows = Vec::new();
        for s in 0..30 {
            rows.push(
                (0..50)
                    .map(|p| (p, if (p + s) % 7 == 0 { 0.2 } else { 0.01 }))
                    .collect::<Vec<_>>(),
            );
        }
        let mut policies: Vec<Box<dyn EvictionPolicy>> = vec![
            Box::new(H2O::new()),
            Box::new(Rkv::new()),
            Box::new(LazyEviction::new()),
            Box::new(RaaS::new()),
            Box::new(StreamingLlm::new(4)),
        ];
        for p in policies.iter_mut() {
            steps(p.as_mut(), &rows);
            let ev = p.select_evictions(&live, 20);
            assert_eq!(ev.len(), 30, "{} wrong count", p.name());
            let set: std::collections::BTreeSet<_> = ev.iter().collect();
            assert_eq!(set.len(), 30, "{} duplicates", p.name());
            assert!(ev.iter().all(|e| live.contains(e)), "{} invalid", p.name());
        }
    }
}
