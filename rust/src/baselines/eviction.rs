//! Eviction baselines (paper §1.1, §6.1).
//!
//! Every policy sees, per decode step, the attention mass each cached CoT
//! position received (mean over layers and heads) and keeps whatever
//! statistics the original system keeps. `select_evictions` is called when
//! the live set must shrink to `target` positions.

use std::collections::BTreeMap;

/// Attention received per CoT position at one decode step.
#[derive(Debug, Clone, Default)]
pub struct PosAttn {
    pub step: usize,
    /// (position, attention mass) — positions currently visible.
    pub attn: Vec<(usize, f32)>,
}

impl PosAttn {
    pub fn get(&self, pos: usize) -> f32 {
        self.attn
            .iter()
            .find(|(p, _)| *p == pos)
            .map(|(_, a)| *a)
            .unwrap_or(0.0)
    }
}

pub trait EvictionPolicy: Send {
    fn name(&self) -> &'static str;

    /// Observe one decode step's attention row.
    fn observe(&mut self, attn: &PosAttn);

    /// Choose positions (from `live`) to evict so ~`target` remain.
    /// `live` is ascending. Must return distinct members of `live`.
    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize>;

    /// Whether evictions leave non-contiguous holes needing gather
    /// compaction (R-KV and friends) — drives the Figure-7 cost model.
    fn needs_gather(&self) -> bool {
        true
    }

    /// Decide whether the K/V entry the current step is about to produce
    /// at `pos` should **never be materialized** (SkipKV's selective
    /// KV-generation skipping — a never-materialize axis, not an
    /// eviction axis). The live backend consults this after feeding the
    /// step's attention row to [`EvictionPolicy::observe`]; a `true`
    /// skips the cache append entirely, so the position consumes neither
    /// pool bytes nor a cache row. Default: never skip.
    fn skip_kv(&mut self, pos: usize) -> bool {
        let _ = pos;
        false
    }

    /// Clone into a new boxed policy carrying the same accumulated
    /// statistics — suspend-to-host snapshots
    /// ([`crate::kvcache::swap::Fp32Snapshot`]) duplicate the policy so
    /// eviction decisions are identical after a resume.
    fn box_clone(&self) -> Box<dyn EvictionPolicy>;
}

impl Clone for Box<dyn EvictionPolicy> {
    fn clone(&self) -> Box<dyn EvictionPolicy> {
        self.box_clone()
    }
}

// ---------------------------------------------------------------------------
// FullKV
// ---------------------------------------------------------------------------

/// No compression: the FullKV reference.
#[derive(Debug, Clone, Default)]
pub struct FullKv;

impl EvictionPolicy for FullKv {
    fn name(&self) -> &'static str {
        "FullKV"
    }

    fn observe(&mut self, _attn: &PosAttn) {}

    fn select_evictions(&mut self, _live: &[usize], _target: usize) -> Vec<usize> {
        Vec::new()
    }

    fn needs_gather(&self) -> bool {
        false
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// H2O (Zhang et al., 2023)
// ---------------------------------------------------------------------------

/// Heavy-Hitter Oracle: keep the top-scoring "heavy hitters" (cumulative
/// attention) plus a recency window; ring-buffer semantics in the original
/// mean evictions are taken from the *oldest non-heavy* region.
#[derive(Debug, Clone)]
pub struct H2O {
    cum: BTreeMap<usize, f64>,
    last_step: usize,
    /// Fraction of the budget reserved for heavy hitters (rest = recent).
    pub heavy_frac: f64,
}

impl H2O {
    pub fn new() -> H2O {
        H2O { cum: BTreeMap::new(), last_step: 0, heavy_frac: 0.5 }
    }
}

impl Default for H2O {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for H2O {
    fn name(&self) -> &'static str {
        "H2O"
    }

    fn observe(&mut self, attn: &PosAttn) {
        self.last_step = attn.step;
        for (p, a) in &attn.attn {
            *self.cum.entry(*p).or_insert(0.0) += f64::from(*a);
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let heavy_n = ((target as f64) * self.heavy_frac) as usize;
        let recent_n = target - heavy_n;
        // recency-protected tail
        let recent: std::collections::BTreeSet<usize> =
            live.iter().rev().take(recent_n).copied().collect();
        // heavy hitters among the rest
        let mut rest: Vec<usize> = live.iter().filter(|p| !recent.contains(p)).copied().collect();
        rest.sort_by(|a, b| {
            let sa = self.cum.get(a).copied().unwrap_or(0.0);
            let sb = self.cum.get(b).copied().unwrap_or(0.0);
            sb.partial_cmp(&sa).unwrap()
        });
        rest.into_iter().skip(heavy_n).collect()
    }

    fn needs_gather(&self) -> bool {
        // the original uses a ring buffer; no gather kernels on the hot path
        false
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// R-KV (Cai et al., 2025)
// ---------------------------------------------------------------------------

/// Redundancy-aware KV: importance (cumulative attention, recency-decayed)
/// combined with redundancy (similarity to already-kept positions in
/// *attention-pattern* space). Evicts the lowest combined score; leaves
/// non-contiguous holes, so the original needs gather compaction — the
/// §5.1 cost this repo reproduces.
#[derive(Debug, Clone)]
pub struct Rkv {
    cum: BTreeMap<usize, f64>,
    recent: BTreeMap<usize, f64>, // exponentially decayed
    pub lambda: f64,              // importance vs redundancy mix
    decay: f64,
}

impl Rkv {
    pub fn new() -> Rkv {
        Rkv { cum: BTreeMap::new(), recent: BTreeMap::new(), lambda: 0.7, decay: 0.95 }
    }
}

impl Default for Rkv {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for Rkv {
    fn name(&self) -> &'static str {
        "R-KV"
    }

    fn observe(&mut self, attn: &PosAttn) {
        for v in self.recent.values_mut() {
            *v *= self.decay;
        }
        for (p, a) in &attn.attn {
            *self.cum.entry(*p).or_insert(0.0) += f64::from(*a);
            *self.recent.entry(*p).or_insert(0.0) += f64::from(*a);
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        // score = λ·importance + (1-λ)·recent-uniqueness; redundancy proxy:
        // positions adjacent to higher-scored neighbours are redundant.
        let imp: Vec<f64> = live
            .iter()
            .map(|p| self.cum.get(p).copied().unwrap_or(0.0))
            .collect();
        let rec: Vec<f64> = live
            .iter()
            .map(|p| self.recent.get(p).copied().unwrap_or(0.0))
            .collect();
        let maxi = imp.iter().cloned().fold(1e-12, f64::max);
        let maxr = rec.iter().cloned().fold(1e-12, f64::max);
        let mut scored: Vec<(f64, usize)> = live
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let redundancy = if i > 0 && imp[i - 1] >= imp[i] { 0.3 } else { 0.0 };
                let s = self.lambda * imp[i] / maxi + (1.0 - self.lambda) * rec[i] / maxr
                    - redundancy * (imp[i] / maxi);
                (s, p)
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        scored
            .into_iter()
            .take(live.len() - target)
            .map(|(_, p)| p)
            .collect()
    }

    fn needs_gather(&self) -> bool {
        true
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// LazyEviction (Zhang et al., 2025a)
// ---------------------------------------------------------------------------

/// Lagged eviction with attention-pattern observation: tokens whose
/// attention *recurred* recently are protected for a lag window even if
/// their cumulative score is low.
#[derive(Debug, Clone)]
pub struct LazyEviction {
    cum: BTreeMap<usize, f64>,
    last_attended: BTreeMap<usize, usize>,
    /// Positions that re-emerged (were dormant > lag, then attended again).
    recurrent: BTreeMap<usize, usize>,
    step: usize,
    pub lag: usize,
    pub attend_threshold: f32,
}

impl LazyEviction {
    pub fn new() -> LazyEviction {
        LazyEviction {
            cum: BTreeMap::new(),
            last_attended: BTreeMap::new(),
            recurrent: BTreeMap::new(),
            step: 0,
            lag: 64,
            attend_threshold: 0.0,
        }
    }
}

impl Default for LazyEviction {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for LazyEviction {
    fn name(&self) -> &'static str {
        "LazyEviction"
    }

    fn observe(&mut self, attn: &PosAttn) {
        self.step = attn.step;
        let rel = f64::from(self.attend_threshold)
            .max(1.4 / attn.attn.len().max(1) as f64) as f32;
        for (p, a) in &attn.attn {
            *self.cum.entry(*p).or_insert(0.0) += f64::from(*a);
            if *a > rel {
                if let Some(&prev) = self.last_attended.get(p) {
                    if attn.step.saturating_sub(prev) > self.lag {
                        // dormant then re-attended: a recurrence event —
                        // LazyEviction's signal that eviction must lag
                        self.recurrent.insert(*p, attn.step);
                    }
                }
                self.last_attended.insert(*p, attn.step);
            }
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        // protected: tokens with a *recurrence* event within the lag window
        let mut candidates: Vec<(f64, usize)> = live
            .iter()
            .filter(|p| {
                self.recurrent
                    .get(p)
                    .map(|&s| self.step.saturating_sub(s) > self.lag)
                    .unwrap_or(true)
            })
            .map(|&p| (self.cum.get(&p).copied().unwrap_or(0.0), p))
            .collect();
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out: Vec<usize> = candidates.into_iter().take(need).map(|(_, p)| p).collect();
        if out.len() < need {
            // lag protection exceeded the budget: fall back to lowest score
            let chosen: std::collections::BTreeSet<usize> = out.iter().copied().collect();
            let mut rest: Vec<(f64, usize)> = live
                .iter()
                .filter(|p| !chosen.contains(p))
                .map(|&p| (self.cum.get(&p).copied().unwrap_or(0.0), p))
                .collect();
            rest.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            out.extend(rest.into_iter().take(need - out.len()).map(|(_, p)| p));
        }
        out
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// RaaS (Hu et al., 2025)
// ---------------------------------------------------------------------------

/// Reasoning-aware attention sparsity: "milestone" tokens get timestamps
/// refreshed whenever they re-emerge; eviction removes the stalest
/// timestamps first.
#[derive(Debug, Clone)]
pub struct RaaS {
    timestamp: BTreeMap<usize, usize>,
    step: usize,
    pub milestone_threshold: f32,
}

impl RaaS {
    pub fn new() -> RaaS {
        RaaS { timestamp: BTreeMap::new(), step: 0, milestone_threshold: 0.0 }
    }
}

impl Default for RaaS {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for RaaS {
    fn name(&self) -> &'static str {
        "RaaS"
    }

    fn observe(&mut self, attn: &PosAttn) {
        self.step = attn.step;
        // milestone threshold is relative to the mean row mass: with n live
        // positions, "re-emergent" means clearly above uniform attention.
        let rel = (self.milestone_threshold as f64)
            .max(1.4 / attn.attn.len().max(1) as f64) as f32;
        for (p, a) in &attn.attn {
            let e = self.timestamp.entry(*p).or_insert(attn.step);
            if *a > rel {
                *e = attn.step; // re-emergent importance refreshes the clock
            }
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let mut ts: Vec<(usize, usize)> = live
            .iter()
            .map(|&p| (self.timestamp.get(&p).copied().unwrap_or(0), p))
            .collect();
        ts.sort();
        ts.into_iter()
            .take(live.len() - target)
            .map(|(_, p)| p)
            .collect()
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// SnapKV (Li et al., 2024) — prefill compression + recency decode window
// ---------------------------------------------------------------------------

/// SnapKV selects prompt positions by pooled observation-window attention
/// at prefill; during decode it keeps a sliding recent window (it was
/// designed for long inputs, which is why it underperforms on long outputs
/// — Figure 8).
#[derive(Debug, Clone)]
pub struct SnapKv {
    /// Positions chosen at prefill (protected).
    pub prefill_keep: Vec<usize>,
    /// Deferred-priming target: while `prefill_keep` is empty, the
    /// *first* observed attention row primes the protected set with its
    /// top `keep_n` positions. The live serving path prefills in chunks
    /// and has no whole-prompt observation scores, so priming happens on
    /// the first decode step instead — deterministic, and replayable
    /// because observed rows are part of the retention trace. 0 = never
    /// prime (an explicit prefill set was supplied).
    pub keep_n: usize,
}

impl SnapKv {
    /// `obs[pos]` = prefill observation scores; keep top `keep_n`.
    pub fn from_prefill_obs(obs: &[f32], keep_n: usize) -> SnapKv {
        let keep = crate::util::stats::top_k(obs, keep_n);
        SnapKv { prefill_keep: keep, keep_n: 0 }
    }

    /// Deferred priming (live path): the protected set is captured from
    /// the first observed attention row instead of prefill scores.
    pub fn deferred(keep_n: usize) -> SnapKv {
        SnapKv { prefill_keep: Vec::new(), keep_n }
    }
}

impl EvictionPolicy for SnapKv {
    fn name(&self) -> &'static str {
        "SnapKV"
    }

    fn observe(&mut self, attn: &PosAttn) {
        if self.prefill_keep.is_empty() && self.keep_n > 0 && !attn.attn.is_empty() {
            // first row primes the protected set (position tie-break
            // keeps the choice deterministic)
            let mut scored = attn.attn.clone();
            scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
            let mut keep: Vec<usize> =
                scored.into_iter().take(self.keep_n).map(|(p, _)| p).collect();
            keep.sort_unstable();
            self.prefill_keep = keep;
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        let protected: std::collections::BTreeSet<usize> =
            self.prefill_keep.iter().copied().collect();
        // evict oldest unprotected first
        let mut out = Vec::new();
        for &p in live {
            if out.len() == need {
                break;
            }
            if !protected.contains(&p) {
                out.push(p);
            }
        }
        // if everything old is protected, evict oldest protected
        let mut i = 0;
        while out.len() < need && i < live.len() {
            if !out.contains(&live[i]) {
                out.push(live[i]);
            }
            i += 1;
        }
        out
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// StreamingLLM (Xiao et al., 2023) — attention sinks + sliding window
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct StreamingLlm {
    pub sinks: usize,
}

impl StreamingLlm {
    pub fn new(sinks: usize) -> StreamingLlm {
        StreamingLlm { sinks }
    }
}

impl EvictionPolicy for StreamingLlm {
    fn name(&self) -> &'static str {
        "StreamingLLM"
    }

    fn observe(&mut self, _attn: &PosAttn) {}

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        live.iter()
            .filter(|&&p| p >= self.sinks) // sinks are immortal
            .take(need)
            .copied()
            .collect()
    }

    fn needs_gather(&self) -> bool {
        false // contiguous window: ring-buffer friendly
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// Crystal-KV — answer-first retention (PAPERS.md)
// ---------------------------------------------------------------------------

/// Crystal-KV: reasoning models spend most tokens *thinking*, but the
/// final answer is synthesized from a small answer-adjacent suffix plus a
/// few high-attention anchors. The policy protects the attention sinks
/// and a trailing answer window outright, ranks the older history by
/// cumulative attention, and evicts the lowest-mass positions first.
#[derive(Debug, Clone)]
pub struct CrystalKv {
    cum: BTreeMap<usize, f64>,
    /// Trailing answer-window size (protected while older history can
    /// still cover the eviction need).
    pub answer_window: usize,
    /// Leading attention sinks — immortal, like StreamingLLM's.
    pub sinks: usize,
}

impl CrystalKv {
    pub fn new() -> CrystalKv {
        CrystalKv { cum: BTreeMap::new(), answer_window: 16, sinks: 4 }
    }
}

impl Default for CrystalKv {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for CrystalKv {
    fn name(&self) -> &'static str {
        "Crystal-KV"
    }

    fn observe(&mut self, attn: &PosAttn) {
        for (p, a) in &attn.attn {
            *self.cum.entry(*p).or_insert(0.0) += f64::from(*a);
        }
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        let tail: std::collections::BTreeSet<usize> =
            live.iter().rev().take(self.answer_window).copied().collect();
        let mut candidates: Vec<(f64, usize)> = live
            .iter()
            .filter(|&&p| p >= self.sinks && !tail.contains(&p))
            .map(|&p| (self.cum.get(&p).copied().unwrap_or(0.0), p))
            .collect();
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut out: Vec<usize> = candidates.into_iter().take(need).map(|(_, p)| p).collect();
        if out.len() < need {
            // the answer window must yield (oldest first) before the
            // budget is violated; the sinks stay immortal
            let chosen: std::collections::BTreeSet<usize> = out.iter().copied().collect();
            out.extend(
                live.iter()
                    .filter(|&&p| p >= self.sinks && !chosen.contains(&p))
                    .take(need - out.len()),
            );
        }
        out
    }

    fn needs_gather(&self) -> bool {
        true // importance eviction leaves holes, like R-KV
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// SkipKV — selective skipping of KV *generation* (PAPERS.md)
// ---------------------------------------------------------------------------

/// SkipKV: the never-materialize axis. When the attention row of the
/// step that produced a token is highly concentrated (one cached
/// position dominates), the freshly decoded token is redundant with what
/// the model already attended to, and its K/V entry is never written —
/// the live backend consults [`EvictionPolicy::skip_kv`] before the
/// append, so a skipped position consumes neither pool bytes nor a
/// cache row. Eviction falls back to a sliding window over the
/// materialized positions (sinks immortal).
#[derive(Debug, Clone)]
pub struct SkipKv {
    /// Max attention mass in the last observed row — the concentration
    /// signal the skip decision reads.
    last_max: f32,
    /// Rows whose max exceeds this mark the new token skippable.
    pub threshold: f32,
    pub sinks: usize,
}

impl SkipKv {
    pub fn new() -> SkipKv {
        SkipKv { last_max: 0.0, threshold: 0.35, sinks: 4 }
    }
}

impl Default for SkipKv {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for SkipKv {
    fn name(&self) -> &'static str {
        "SkipKV"
    }

    fn observe(&mut self, attn: &PosAttn) {
        self.last_max = attn.attn.iter().map(|(_, a)| *a).fold(0.0, f32::max);
    }

    fn skip_kv(&mut self, pos: usize) -> bool {
        pos > self.sinks && self.last_max > self.threshold
    }

    fn select_evictions(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        if live.len() <= target {
            return Vec::new();
        }
        let need = live.len() - target;
        live.iter()
            .filter(|&&p| p >= self.sinks) // sinks are immortal
            .take(need)
            .copied()
            .collect()
    }

    fn needs_gather(&self) -> bool {
        false // window eviction plus skips: no holes to compact
    }

    fn box_clone(&self) -> Box<dyn EvictionPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// PolicyKind registry — the pluggable live policy arena
// ---------------------------------------------------------------------------

/// Registry of live-arena retention policies: one variant per
/// [`EvictionPolicy`] implementation the serving path can run over the
/// f32 paged cache, selectable end-to-end via `ServeConfig::policy` /
/// `--policy` / the server wire protocol. Adding a policy = adding a
/// variant here plus its [`PolicyKind::build`] arm; the conformance
/// battery (`tests/policy_arena.rs`) and the bench-smoke divergence
/// sweep iterate [`PolicyKind::ALL`] and pick it up automatically.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PolicyKind {
    #[default]
    FullKv,
    H2O,
    Rkv,
    RaaS,
    SnapKv,
    StreamingLlm,
    LazyEviction,
    CrystalKv,
    SkipKv,
}

impl PolicyKind {
    /// Every registered policy, in display order.
    pub const ALL: [PolicyKind; 9] = [
        PolicyKind::FullKv,
        PolicyKind::H2O,
        PolicyKind::Rkv,
        PolicyKind::RaaS,
        PolicyKind::SnapKv,
        PolicyKind::StreamingLlm,
        PolicyKind::LazyEviction,
        PolicyKind::CrystalKv,
        PolicyKind::SkipKv,
    ];

    /// Display name — always equal to the built policy's
    /// [`EvictionPolicy::name`].
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::FullKv => "FullKV",
            PolicyKind::H2O => "H2O",
            PolicyKind::Rkv => "R-KV",
            PolicyKind::RaaS => "RaaS",
            PolicyKind::SnapKv => "SnapKV",
            PolicyKind::StreamingLlm => "StreamingLLM",
            PolicyKind::LazyEviction => "LazyEviction",
            PolicyKind::CrystalKv => "Crystal-KV",
            PolicyKind::SkipKv => "SkipKV",
        }
    }

    /// Parse a `--policy` flag / wire-protocol value.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fullkv" | "full" => PolicyKind::FullKv,
            "h2o" => PolicyKind::H2O,
            "rkv" | "r-kv" => PolicyKind::Rkv,
            "raas" => PolicyKind::RaaS,
            "snapkv" => PolicyKind::SnapKv,
            "streaming" | "streamingllm" => PolicyKind::StreamingLlm,
            "lazyeviction" | "lazy" => PolicyKind::LazyEviction,
            "crystalkv" | "crystal-kv" | "crystal" => PolicyKind::CrystalKv,
            "skipkv" | "skip-kv" | "skip" => PolicyKind::SkipKv,
            _ => return None,
        })
    }

    /// Build a fresh policy instance for a serving budget of `budget`
    /// tokens (SnapKV sizes its deferred prefill-keep set from it). The
    /// sim-oracle replay rebuilds the twin with the traced budget, so
    /// live and replayed instances always start from identical state.
    pub fn build(self, budget: usize) -> Box<dyn EvictionPolicy> {
        match self {
            PolicyKind::FullKv => Box::new(FullKv),
            PolicyKind::H2O => Box::new(H2O::new()),
            PolicyKind::Rkv => Box::new(Rkv::new()),
            PolicyKind::RaaS => Box::new(RaaS::new()),
            PolicyKind::SnapKv => Box::new(SnapKv::deferred((budget / 2).max(1))),
            PolicyKind::StreamingLlm => Box::new(StreamingLlm::new(4)),
            PolicyKind::LazyEviction => Box::new(LazyEviction::new()),
            PolicyKind::CrystalKv => Box::new(CrystalKv::new()),
            PolicyKind::SkipKv => Box::new(SkipKv::new()),
        }
    }

    /// Effective token budget for this policy: FullKV never evicts, so
    /// its live backend runs unbounded.
    pub fn budget_for(self, budget: usize) -> usize {
        match self {
            PolicyKind::FullKv => usize::MAX,
            _ => budget,
        }
    }

    /// Whether the *live* arena compacts after this policy's evictions.
    /// Only the policies whose original systems pay the gather cost
    /// (Figure 7) compact; the rest tolerate holes / stay contiguous.
    pub fn gather(self) -> bool {
        matches!(self, PolicyKind::Rkv | PolicyKind::CrystalKv)
    }
}

// ---------------------------------------------------------------------------
// Retention audit surface: counters, trace, guarded-region filter
// ---------------------------------------------------------------------------

/// Per-policy retention counters a live backend accumulates and the
/// scheduler/stats surface reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetentionCounters {
    /// Positions evicted from the cache by the policy.
    pub evicted: u64,
    /// Positions whose K/V was never materialized
    /// ([`EvictionPolicy::skip_kv`]).
    pub skipped: u64,
    /// Live cache bytes retained at sample time.
    pub retained_bytes: u64,
}

/// One recorded policy decision in a [`RetentionTrace`] — the exact
/// inputs the live backend handed the policy and the output it got back,
/// so a sim twin can replay the identical call sequence.
#[derive(Debug, Clone, PartialEq)]
pub enum RetentionEvent {
    /// One decode step's attention row fed to
    /// [`EvictionPolicy::observe`].
    Observe { step: usize, attn: Vec<(usize, f32)> },
    /// The step's token was materialized (skip declined).
    Keep { pos: usize },
    /// The step's token was never materialized
    /// ([`EvictionPolicy::skip_kv`] returned true).
    Skip { pos: usize },
    /// One [`EvictionPolicy::select_evictions`] call: the live set and
    /// target it saw, and the positions it proposed (pre
    /// guarded-region filtering, so the replay mirrors the raw call).
    Evict { live: Vec<usize>, target: usize, evicted: Vec<usize> },
}

/// Compact audit log of every retention decision a live backend made:
/// (pos, kept/evicted/skipped, step) plus the attention history that
/// drove it. `sim::oracle::replay_divergence` replays the same history
/// through a freshly built sim twin and diffs the two decision streams.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RetentionTrace {
    /// Which registered policy produced the decisions.
    pub kind: PolicyKind,
    /// Token budget the live backend ran with (the twin is rebuilt with
    /// the same budget).
    pub budget: usize,
    pub events: Vec<RetentionEvent>,
}

impl RetentionTrace {
    pub fn new(kind: PolicyKind, budget: usize) -> RetentionTrace {
        RetentionTrace { kind, budget, events: Vec::new() }
    }
}

/// Split an eviction proposal around a read-only guarded region
/// `[0, guard)` — the shared-prefix rows a sibling session still reads.
/// Returns the allowed positions plus how many the guard blocked. This
/// is the one guarded-region filter every call-site (fp32 eviction, and
/// the quant backends' pre-privatization checks) routes through, so the
/// read-only invariant lives in exactly one place.
pub fn filter_guarded(evict: Vec<usize>, guard: usize) -> (Vec<usize>, usize) {
    if guard == 0 {
        return (evict, 0);
    }
    let before = evict.len();
    let allowed: Vec<usize> = evict.into_iter().filter(|&p| p >= guard).collect();
    let blocked = before - allowed.len();
    (allowed, blocked)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn steps(policy: &mut dyn EvictionPolicy, rows: &[Vec<(usize, f32)>]) {
        for (i, r) in rows.iter().enumerate() {
            policy.observe(&PosAttn { step: i, attn: r.clone() });
        }
    }

    #[test]
    fn fullkv_never_evicts() {
        let mut p = FullKv;
        assert!(p.select_evictions(&[0, 1, 2, 3], 1).is_empty());
        assert!(!p.needs_gather());
    }

    #[test]
    fn h2o_keeps_heavy_hitters_and_recent() {
        let mut p = H2O::new();
        // position 2 is a heavy hitter
        let rows: Vec<Vec<(usize, f32)>> = (0..10)
            .map(|_| vec![(0, 0.01), (1, 0.01), (2, 0.9), (3, 0.01), (4, 0.02)])
            .collect();
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4], 2);
        assert!(!evicted.contains(&2), "heavy hitter evicted: {evicted:?}");
        assert!(!evicted.contains(&4), "most recent evicted: {evicted:?}");
        assert_eq!(evicted.len(), 3);
    }

    #[test]
    fn rkv_evicts_low_importance() {
        let mut p = Rkv::new();
        let rows: Vec<Vec<(usize, f32)>> = (0..20)
            .map(|_| vec![(0, 0.4), (1, 0.005), (2, 0.4), (3, 0.005), (4, 0.19)])
            .collect();
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4], 3);
        assert_eq!(evicted.len(), 2);
        assert!(evicted.contains(&1) && evicted.contains(&3), "{evicted:?}");
        assert!(p.needs_gather());
    }

    #[test]
    fn lazy_eviction_protects_recurrent_tokens() {
        let mut p = LazyEviction::new();
        p.lag = 5;
        // position 0: attended early, dormant for > lag, then re-attended at
        // step 9 — a recurrence event that must delay its eviction.
        let mut rows: Vec<Vec<(usize, f32)>> =
            vec![vec![(0, 0.4), (1, 0.2), (2, 0.2), (3, 0.2)]];
        rows.extend((1..9).map(|_| vec![(0, 0.001), (1, 0.3), (2, 0.3), (3, 0.3)]));
        rows.push(vec![(0, 0.5), (1, 0.1), (2, 0.2), (3, 0.2)]);
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2, 3], 3);
        assert!(!evicted.contains(&0), "recurrent token evicted: {evicted:?}");
    }

    #[test]
    fn raas_drops_stalest_timestamp() {
        let mut p = RaaS::new();
        let rows: Vec<Vec<(usize, f32)>> = (0..10)
            .map(|i| {
                vec![
                    (0, if i < 2 { 0.5 } else { 0.001 }), // stale after step 1
                    (1, 0.5),
                    (2, 0.5),
                ]
            })
            .collect();
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2], 2);
        assert_eq!(evicted, vec![0]);
    }

    #[test]
    fn snapkv_protects_prefill_selection() {
        let obs = vec![0.1f32, 0.9, 0.05, 0.8, 0.02];
        let mut p = SnapKv::from_prefill_obs(&obs, 2);
        assert_eq!(p.prefill_keep, vec![1, 3]);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4], 3);
        assert_eq!(evicted, vec![0, 2]);
    }

    #[test]
    fn streaming_llm_keeps_sinks() {
        let mut p = StreamingLlm::new(2);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4, 5], 4);
        assert_eq!(evicted, vec![2, 3]);
        assert!(!p.needs_gather());
    }

    #[test]
    fn box_clone_preserves_accumulated_state() {
        let mut p = Rkv::new();
        let rows: Vec<Vec<(usize, f32)>> = (0..20)
            .map(|_| vec![(0, 0.4), (1, 0.005), (2, 0.4), (3, 0.005), (4, 0.19)])
            .collect();
        steps(&mut p, &rows);
        let mut clone = p.box_clone();
        assert_eq!(clone.name(), "R-KV");
        // identical state => identical eviction decisions
        let a = p.select_evictions(&[0, 1, 2, 3, 4], 3);
        let b = clone.select_evictions(&[0, 1, 2, 3, 4], 3);
        assert_eq!(a, b);
    }

    #[test]
    fn policies_return_distinct_members() {
        let live: Vec<usize> = (0..50).collect();
        let mut rows = Vec::new();
        for s in 0..30 {
            rows.push(
                (0..50)
                    .map(|p| (p, if (p + s) % 7 == 0 { 0.2 } else { 0.01 }))
                    .collect::<Vec<_>>(),
            );
        }
        let mut policies: Vec<Box<dyn EvictionPolicy>> = vec![
            Box::new(H2O::new()),
            Box::new(Rkv::new()),
            Box::new(LazyEviction::new()),
            Box::new(RaaS::new()),
            Box::new(StreamingLlm::new(4)),
        ];
        for p in policies.iter_mut() {
            steps(p.as_mut(), &rows);
            let ev = p.select_evictions(&live, 20);
            assert_eq!(ev.len(), 30, "{} wrong count", p.name());
            let set: std::collections::BTreeSet<_> = ev.iter().collect();
            assert_eq!(set.len(), 30, "{} duplicates", p.name());
            assert!(ev.iter().all(|e| live.contains(e)), "{} invalid", p.name());
        }
    }

    #[test]
    fn snapkv_deferred_primes_from_first_row() {
        let mut p = SnapKv::deferred(2);
        assert!(p.prefill_keep.is_empty());
        steps(&mut p, &[vec![(0, 0.1), (1, 0.9), (2, 0.05), (3, 0.8)]]);
        assert_eq!(p.prefill_keep, vec![1, 3]);
        // later rows must not re-prime
        steps(&mut p, &[vec![(0, 0.9), (1, 0.1), (2, 0.9), (3, 0.1)]]);
        assert_eq!(p.prefill_keep, vec![1, 3]);
        let evicted = p.select_evictions(&[0, 1, 2, 3], 2);
        assert_eq!(evicted, vec![0, 2]);
    }

    #[test]
    fn crystal_kv_protects_sinks_and_answer_window() {
        let mut p = CrystalKv::new();
        p.answer_window = 2;
        p.sinks = 1;
        // position 3 carries the attention mass; 4 and 5 are nonetheless
        // protected as the trailing answer window, 0 as a sink
        let rows: Vec<Vec<(usize, f32)>> = (0..8)
            .map(|_| vec![(1, 0.01), (2, 0.02), (3, 0.9), (4, 0.03), (5, 0.04)])
            .collect();
        steps(&mut p, &rows);
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4, 5], 4);
        assert_eq!(evicted, vec![1, 2], "{evicted:?}");
        assert!(p.needs_gather());
    }

    #[test]
    fn crystal_kv_yields_answer_window_before_violating_budget() {
        let mut p = CrystalKv::new();
        p.answer_window = 4;
        p.sinks = 1;
        // live fits entirely in sinks + answer window, but budget says
        // evict 2: the window yields oldest-first, sinks never do
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4], 3);
        assert_eq!(evicted, vec![1, 2]);
    }

    #[test]
    fn skip_kv_skips_on_concentrated_attention() {
        let mut p = SkipKv::new();
        steps(&mut p, &[vec![(0, 0.9), (1, 0.05)]]);
        assert!(p.skip_kv(10), "concentrated row must skip");
        assert!(!p.skip_kv(2), "sink positions never skip");
        steps(&mut p, &[vec![(0, 0.2), (1, 0.2), (2, 0.2)]]);
        assert!(!p.skip_kv(10), "diffuse row must materialize");
        // window eviction keeps the sinks
        let evicted = p.select_evictions(&[0, 1, 2, 3, 4, 5, 6, 7], 6);
        assert_eq!(evicted, vec![4, 5]);
        assert!(!p.needs_gather());
    }

    #[test]
    fn policy_kind_registry_is_consistent() {
        for kind in PolicyKind::ALL {
            let built = kind.build(64);
            assert_eq!(built.name(), kind.name(), "{kind:?} name mismatch");
            assert_eq!(
                PolicyKind::parse(kind.name()),
                Some(kind),
                "{kind:?} display name must round-trip through parse"
            );
            assert_eq!(PolicyKind::parse(&kind.name().to_ascii_uppercase()), Some(kind));
        }
        assert_eq!(PolicyKind::parse("rkv"), Some(PolicyKind::Rkv));
        assert_eq!(PolicyKind::parse("crystal"), Some(PolicyKind::CrystalKv));
        assert_eq!(PolicyKind::parse("skip"), Some(PolicyKind::SkipKv));
        assert_eq!(PolicyKind::parse("nope"), None);
        assert_eq!(PolicyKind::FullKv.budget_for(128), usize::MAX);
        assert_eq!(PolicyKind::H2O.budget_for(128), 128);
        assert!(PolicyKind::Rkv.gather() && PolicyKind::CrystalKv.gather());
        assert!(!PolicyKind::SkipKv.gather() && !PolicyKind::SnapKv.gather());
    }

    #[test]
    fn filter_guarded_splits_around_region() {
        assert_eq!(filter_guarded(vec![1, 5, 9], 0), (vec![1, 5, 9], 0));
        assert_eq!(filter_guarded(vec![1, 5, 9], 6), (vec![9], 2));
        assert_eq!(filter_guarded(vec![1, 2], 6), (vec![], 2));
        assert_eq!(filter_guarded(Vec::new(), 6), (vec![], 0));
    }

    #[test]
    fn retention_trace_records_events() {
        let mut t = RetentionTrace::new(PolicyKind::SkipKv, 32);
        t.events.push(RetentionEvent::Observe { step: 0, attn: vec![(0, 1.0)] });
        t.events.push(RetentionEvent::Skip { pos: 7 });
        t.events.push(RetentionEvent::Keep { pos: 8 });
        t.events.push(RetentionEvent::Evict {
            live: vec![0, 1, 2],
            target: 2,
            evicted: vec![1],
        });
        assert_eq!(t.kind, PolicyKind::SkipKv);
        assert_eq!(t.budget, 32);
        assert_eq!(t.events.len(), 4);
        let c = RetentionCounters { evicted: 1, skipped: 1, retained_bytes: 256 };
        assert_eq!(c, c);
    }
}
