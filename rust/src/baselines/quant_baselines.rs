//! Quantization baselines (paper §6.1, Table 1): KIVI (uniform low-bit)
//! and PM-KVQ (progressive mixed precision that requantizes older tokens
//! downward as decoding proceeds).
//!
//! Both reuse the TBQ cache machinery with non-thought-aware tag policies.

use crate::kvcache::CtCache;
use crate::quant::{dequant_groups, quant_groups, Precision};

/// KIVI: uniform quantization of all tokens (2-bit or 4-bit variants).
#[derive(Debug, Clone, Copy)]
pub struct Kivi {
    pub precision: Precision,
}

impl Kivi {
    pub fn k2() -> Kivi {
        Kivi { precision: Precision::Ternary }
    }

    pub fn k4() -> Kivi {
        Kivi { precision: Precision::Nvfp4 }
    }

    pub fn psi(&self) -> impl Fn(crate::kvcache::Thought) -> Precision + '_ {
        move |_| self.precision
    }
}

/// PM-KVQ: tokens start at high precision and are **requantized** to lower
/// precision as they age (progressive schedule by age in decode steps).
/// Requantization goes through dequantize -> quantize, accumulating error —
/// exactly the effect the paper measures against.
#[derive(Debug, Clone)]
pub struct PmKvq {
    /// (age_threshold_steps, precision) descending by precision.
    pub schedule: Vec<(usize, Precision)>,
}

impl PmKvq {
    pub fn default_schedule() -> PmKvq {
        PmKvq {
            schedule: vec![
                (0, Precision::Fp8),      // fresh tokens
                (512, Precision::Nvfp4),  // >512 steps old
                (2048, Precision::Ternary), // ancient
            ],
        }
    }

    pub fn precision_for_age(&self, age: usize) -> Precision {
        let mut p = self.schedule[0].1;
        for &(thr, prec) in &self.schedule {
            if age >= thr {
                p = prec;
            }
        }
        p
    }

    /// Average nominal bits at a given CoT length (for Table-1 style
    /// bit-width reporting).
    pub fn avg_bits_at(&self, len: usize) -> f64 {
        if len == 0 {
            return self.schedule[0].1.bits();
        }
        let total: f64 = (0..len)
            .map(|pos| self.precision_for_age(len - 1 - pos).bits())
            .sum();
        total / len as f64
    }

    /// Smallest token age at which the schedule demotes below the
    /// freshest precision — before this age [`PmKvq::apply`] is a no-op,
    /// and a shared-prefix backend can defer its copy-on-write.
    pub fn first_demotion_age(&self) -> usize {
        let base = self.schedule[0].1;
        self.schedule
            .iter()
            .filter(|(_, p)| p.bits() < base.bits())
            .map(|&(thr, _)| thr)
            .min()
            .unwrap_or(usize::MAX)
    }

    /// Requantize every live slot whose age-mandated precision dropped.
    /// Returns the number of slots requantized. Slots in a read-only
    /// shared-prefix region are skipped — the owning backend privatizes
    /// (copy-on-write) before requantization may touch them.
    pub fn apply(&self, cache: &mut CtCache, current_pos: usize) -> usize {
        let c = cache.cfg.capacity;
        let kvd = cache.cfg.kv_dim();
        let g_per = cache.cfg.hkv * cache.cfg.groups();
        let shared = cache.shared_len();
        let mut changed = 0;
        for l in 0..cache.cfg.layers {
            for slot in cache.tables[l].live_slot_ids() {
                if slot < shared {
                    continue;
                }
                let pos = cache.tables[l].slot_pos[slot];
                if pos < 0 {
                    continue;
                }
                let age = current_pos.saturating_sub(pos as usize);
                let want = self.precision_for_age(age);
                let have = Precision::from_tag(cache.tags[l * c + slot]);
                if want.bits() < have.bits() {
                    let code_base = (l * c + slot) * kvd;
                    let scale_base = (l * c + slot) * g_per;
                    let mut kf = vec![0f32; kvd];
                    let mut vf = vec![0f32; kvd];
                    dequant_groups(
                        &cache.k_codes[code_base..code_base + kvd],
                        &cache.k_scales[scale_base..scale_base + g_per],
                        have,
                        &mut kf,
                    );
                    dequant_groups(
                        &cache.v_codes[code_base..code_base + kvd],
                        &cache.v_scales[scale_base..scale_base + g_per],
                        have,
                        &mut vf,
                    );
                    quant_groups(
                        &kf,
                        want,
                        &mut cache.k_codes[code_base..code_base + kvd],
                        &mut cache.k_scales[scale_base..scale_base + g_per],
                    );
                    quant_groups(
                        &vf,
                        want,
                        &mut cache.v_codes[code_base..code_base + kvd],
                        &mut cache.v_scales[scale_base..scale_base + g_per],
                    );
                    cache.tags[l * c + slot] = want.tag();
                    changed += 1;
                }
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{CacheConfig, Thought};
    use crate::util::rng::Rng;

    #[test]
    fn kivi_uniform() {
        let k = Kivi::k2();
        for t in Thought::ALL {
            assert_eq!((k.psi())(t), Precision::Ternary);
        }
        assert_eq!(Kivi::k4().precision, Precision::Nvfp4);
    }

    #[test]
    fn pmkvq_schedule_monotone_in_age() {
        let p = PmKvq::default_schedule();
        assert_eq!(p.precision_for_age(0), Precision::Fp8);
        assert_eq!(p.precision_for_age(600), Precision::Nvfp4);
        assert_eq!(p.precision_for_age(5000), Precision::Ternary);
        assert!(p.avg_bits_at(100) > p.avg_bits_at(4000));
    }

    #[test]
    fn pmkvq_requantizes_old_slots() {
        let cfg = CacheConfig {
            layers: 1,
            capacity: 64,
            block_size: 8,
            hkv: 1,
            dh: 16,
            buf_slots: 16,
        };
        let mut cache = CtCache::new(cfg.clone());
        let mut rng = Rng::new(1);
        let seg = cache.open_segment(Thought::Reasoning, 0);
        for i in 0..16 {
            let mut k = vec![0f32; cfg.kv_dim()];
            let mut v = vec![0f32; cfg.kv_dim()];
            rng.fill_normal_f32(&mut k, 0.0, 1.0);
            rng.fill_normal_f32(&mut v, 0.0, 1.0);
            cache.push_token(&k, &v, i, seg, Thought::Reasoning);
        }
        cache.flush_buffer(&|_| Precision::Fp8).unwrap();
        let pm = PmKvq {
            schedule: vec![(0, Precision::Fp8), (10, Precision::Ternary)],
        };
        let changed = pm.apply(&mut cache, 16);
        // tokens 0..6 are >=10 steps old at pos 16
        assert_eq!(changed, 7);
        let ternary = cache.tags[..64]
            .iter()
            .filter(|&&t| t == Precision::Ternary.tag())
            .count();
        assert_eq!(ternary, 64 - 16 + 7); // empty slots default 0 = ternary tag
        // idempotent
        assert_eq!(pm.apply(&mut cache, 16), 0);
    }
}
