//! Deterministic exhaustive interleaving explorer — the engine behind
//! `make loom` (`rust/tests/loom_models.rs`).
//!
//! The container image carries no external crates beyond the seed's
//! (`anyhow`, `xla`), so the classic `loom` permutation tester cannot
//! be a dependency. This module provides the piece of it the three
//! modeled lock dances need: **exhaustive schedule exploration** over
//! cooperative state-machine threads.
//!
//! Each model is written as:
//!
//! * a `Clone` state `S` — the shared variables of the dance (queue
//!   lengths, pool bytes, pending counters), plus per-thread program
//!   counters implicit in the action index;
//! * one [`Thread`] per concurrent actor: an ordered list of **atomic
//!   actions** `fn(&mut S) -> Step`. Each action is one
//!   critical section (or one lock-free step) of the real code —
//!   the granularity at which the real threads can interleave;
//! * an **invariant** closure checked after *every* action of *every*
//!   schedule.
//!
//! [`explore`] runs a depth-first search over all interleavings: at
//! each step it forks the state and tries every thread whose next
//! action is enabled. An action returning [`Step::Blocked`] models a
//! condition wait / failed try-lock and **must leave the state
//! untouched** (the explorer clones the state before each candidate, so
//! a mutating Blocked is detected and rejected). A state where every
//! remaining thread is blocked is a **deadlock** and panics with the
//! stuck threads' names.
//!
//! This is bounded model checking, not production code: state spaces
//! for the three dances are tiny (hundreds to low thousands of
//! interleavings), and [`explore`] hard-caps the search so a model
//! with an accidental cycle fails fast instead of hanging CI.

/// Outcome of attempting one atomic action against the current state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// The action ran; the thread's program counter advances.
    Ran,
    /// The action is disabled in this state (lock held elsewhere,
    /// condition not yet true). The state **must not** have been
    /// mutated; the explorer will retry it on later schedules.
    Blocked,
}

/// One modeled thread: a name (for deadlock diagnostics) and its
/// straight-line program of atomic actions.
pub struct Thread<S> {
    pub name: &'static str,
    pub actions: Vec<fn(&mut S) -> Step>,
}

impl<S> Thread<S> {
    pub fn new(name: &'static str, actions: Vec<fn(&mut S) -> Step>) -> Thread<S> {
        Thread { name, actions }
    }
}

/// Hard cap on explored interleavings: generous for the modeled dances
/// (largest is ~10⁴) while bounding a buggy model's runtime.
const MAX_INTERLEAVINGS: u64 = 1_000_000;

/// Exhaustively explore every interleaving of `threads` from `init`,
/// asserting `invariant` after each action. Returns the number of
/// complete schedules (terminal states) explored.
///
/// Panics on: an invariant violation (whatever the closure panics
/// with), a state-mutating [`Step::Blocked`], a deadlock (all
/// unfinished threads blocked), or a search exceeding
/// [`MAX_INTERLEAVINGS`].
pub fn explore<S: Clone + PartialEq + std::fmt::Debug>(
    init: &S,
    threads: &[Thread<S>],
    invariant: &dyn Fn(&S),
) -> u64 {
    invariant(init);
    let pcs = vec![0usize; threads.len()];
    let mut terminals = 0u64;
    let mut visited = 0u64;
    dfs(init, threads, &pcs, invariant, &mut terminals, &mut visited);
    terminals
}

fn dfs<S: Clone + PartialEq + std::fmt::Debug>(
    state: &S,
    threads: &[Thread<S>],
    pcs: &[usize],
    invariant: &dyn Fn(&S),
    terminals: &mut u64,
    visited: &mut u64,
) {
    *visited += 1;
    assert!(
        *visited <= MAX_INTERLEAVINGS,
        "interleaving explosion: >{MAX_INTERLEAVINGS} states — simplify the model"
    );
    let mut ran_any = false;
    let mut blocked: Vec<&'static str> = Vec::new();
    for (t, thread) in threads.iter().enumerate() {
        let pc = pcs[t];
        if pc >= thread.actions.len() {
            continue; // finished
        }
        let mut next = state.clone();
        match (thread.actions[pc])(&mut next) {
            Step::Ran => {
                ran_any = true;
                invariant(&next);
                let mut next_pcs = pcs.to_vec();
                next_pcs[t] += 1;
                dfs(&next, threads, &next_pcs, invariant, terminals, visited);
            }
            Step::Blocked => {
                assert!(
                    next == *state,
                    "thread `{}` action {} returned Blocked but mutated state:\n \
                     before: {:?}\n after:  {:?}",
                    thread.name,
                    pc,
                    state,
                    next
                );
                blocked.push(thread.name);
            }
        }
    }
    if !ran_any {
        assert!(
            blocked.is_empty(),
            "deadlock: thread(s) {blocked:?} blocked with no runnable peer in state {state:?}"
        );
        // every thread finished: one complete schedule
        *terminals += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, PartialEq, Default)]
    struct Counter {
        lock: bool,
        value: u32,
        staged: [Option<u32>; 2],
    }

    /// Two threads doing read-modify-write under a lock: every
    /// interleaving must end at value == 2 (no lost update).
    fn incrementer(idx: usize) -> Vec<fn(&mut Counter) -> Step> {
        // monomorphize per index via small fn items (the explorer takes
        // plain fn pointers, so the index is baked in statically)
        fn lock_read<const I: usize>(s: &mut Counter) -> Step {
            if s.lock {
                return Step::Blocked;
            }
            s.lock = true;
            s.staged[I] = Some(s.value);
            Step::Ran
        }
        fn write_unlock<const I: usize>(s: &mut Counter) -> Step {
            s.value = s.staged[I].unwrap() + 1;
            s.lock = false;
            Step::Ran
        }
        match idx {
            0 => vec![lock_read::<0>, write_unlock::<0>],
            _ => vec![lock_read::<1>, write_unlock::<1>],
        }
    }

    #[test]
    fn locked_increments_never_lose_updates() {
        let threads = vec![
            Thread::new("inc0", incrementer(0)),
            Thread::new("inc1", incrementer(1)),
        ];
        let n = explore(&Counter::default(), &threads, &|_s| {});
        // both serializations complete; intermediate blocked states
        // collapse into them
        assert!(n >= 2, "expected both orders, got {n}");
        // final-value check rides in the invariant of a second pass:
        let n2 = explore(&Counter::default(), &threads, &|s| {
            if !s.lock && s.staged.iter().all(|x| x.is_some()) {
                assert_eq!(s.value, 2, "lost update");
            }
        });
        assert_eq!(n, n2);
    }

    /// Seeded bug: the same dance *without* the lock must be caught by
    /// the same invariant — proves the explorer actually explores the
    /// racy interleavings.
    #[test]
    fn unlocked_increments_lose_updates_and_are_caught() {
        fn read<const I: usize>(s: &mut Counter) -> Step {
            s.staged[I] = Some(s.value);
            Step::Ran
        }
        fn write<const I: usize>(s: &mut Counter) -> Step {
            s.value = s.staged[I].unwrap() + 1;
            Step::Ran
        }
        let threads = vec![
            Thread::new("racy0", vec![read::<0>, write::<0>]),
            Thread::new("racy1", vec![read::<1>, write::<1>]),
        ];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            explore(&Counter::default(), &threads, &|s| {
                if s.staged.iter().all(|x| x.is_some()) {
                    assert!(
                        s.value != 1 || s.staged.iter().flatten().any(|&v| v == 1),
                        "lost update reached"
                    );
                }
            })
        }));
        assert!(err.is_err(), "explorer must reach the lost-update interleaving");
    }

    #[test]
    fn deadlock_is_detected() {
        #[derive(Debug, Clone, PartialEq, Default)]
        struct TwoLocks {
            a: bool,
            b: bool,
        }
        fn take_a(s: &mut TwoLocks) -> Step {
            if s.a {
                return Step::Blocked;
            }
            s.a = true;
            Step::Ran
        }
        fn take_b(s: &mut TwoLocks) -> Step {
            if s.b {
                return Step::Blocked;
            }
            s.b = true;
            Step::Ran
        }
        let threads = vec![
            Thread::new("ab", vec![take_a, take_b]),
            Thread::new("ba", vec![take_b, take_a]),
        ];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            explore(&TwoLocks::default(), &threads, &|_s| {})
        }));
        let msg = format!("{:?}", err.expect_err("ab/ba must deadlock in some schedule"));
        assert!(msg.contains("deadlock"), "got: {msg}");
    }

    #[test]
    fn mutating_blocked_action_is_rejected() {
        #[derive(Debug, Clone, PartialEq, Default)]
        struct S {
            x: u32,
        }
        fn bad(s: &mut S) -> Step {
            s.x += 1; // illegal: Blocked must not mutate
            Step::Blocked
        }
        let threads = vec![Thread::new("bad", vec![bad])];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            explore(&S::default(), &threads, &|_s| {})
        }));
        let msg = format!("{:?}", err.expect_err("mutating Blocked must be rejected"));
        assert!(msg.contains("mutated state"), "got: {msg}");
    }
}
