//! # ThinKV — Thought-Adaptive KV Cache Compression for Efficient Reasoning Models
//!
//! A three-layer Rust + JAX + Pallas reproduction of the ThinKV paper
//! (Ramachandran et al., 2025):
//!
//! * **Layer 1 (Pallas, build time)** — fused dequantization + paged-attention
//!   kernels and group-quantization kernels, authored in
//!   `python/compile/kernels/`, lowered under `interpret=True`.
//! * **Layer 2 (JAX, build time)** — a decoder-only transformer whose decode
//!   step consumes the quantized paged KV cache; AOT-lowered to HLO text in
//!   `artifacts/` by `python/compile/aot.py`.
//! * **Layer 3 (Rust, run time)** — this crate: the serving coordinator
//!   (memory-aware scheduler with byte-accurate `BlockPool` admission,
//!   preempt-youngest reclamation, suspend-to-host swap preemption, and
//!   cross-session batched decode — one fused engine call advances a
//!   whole batch of compatible sessions per step), the unified `KvBackend`
//!   cache abstraction over the Continuous-Thinking quantized cache and
//!   the f32 baseline cache, thought decomposition (KDE calibration +
//!   sparsity classifier), TBQ/TBE compression policies, all
//!   eviction/quantization baselines, the GPU cost model, and the LRM
//!   trace simulator.
//!
//! Crate map (run-time layer):
//! * [`kvcache`] — CT block tables, [`kvcache::CtCache`] /
//!   [`kvcache::Fp32Cache`], the [`kvcache::KvBackend`] trait unifying
//!   them, the global [`kvcache::BlockPool`] byte pool, and the
//!   suspend-to-host swap subsystem ([`kvcache::swap`]:
//!   [`kvcache::KvSnapshot`] + [`kvcache::SwapPool`]).
//! * [`coordinator`] — [`coordinator::Scheduler`] (admission/preemption),
//!   [`coordinator::Session`] (one request's generic decode loop), the
//!   engine worker loop, and serving config.
//! * [`server`] — line-delimited-JSON TCP front end + client.
//! * [`metrics`] — latencies, the Table-5 breakdown, and the scheduler /
//!   pool snapshot ([`metrics::SchedSnapshot`]).
//! * [`runtime`] — PJRT engine over the AOT HLO artifacts.
//! * [`compress`] / [`thought`] / [`baselines`] — ThinKV policies and the
//!   paper's comparison systems.
//! * [`sim`] / [`bench`] — trace simulator, GPU cost model, bench tables.
//! * [`syncx`] — ranked-lock facade (lock-hierarchy enforcement) and the
//!   deterministic interleaving explorer behind `make loom`.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once, and the Rust binary is self-contained afterwards.

pub mod util;
pub mod syncx;
pub mod quant;
pub mod kvcache;
pub mod thought;
pub mod compress;
pub mod baselines;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod coordinator;
pub mod server;
pub mod metrics;
pub mod bench;
#[doc(hidden)]
pub mod testkit;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
