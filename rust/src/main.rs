//! `thinkv` — CLI entry point for the ThinKV serving coordinator.
//!
//! Subcommands:
//!   generate   run N requests through the coordinator, print stats
//!   serve      start the TCP JSON server
//!   calibrate  run KDE thought calibration on simulated traces
//!   sim        run the trace-simulation harness for one method
//!   info       print artifact manifest info

use thinkv::baselines::PolicyKind;
use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig};
use thinkv::server::Server;
use thinkv::sim::{run_method, DatasetProfile, Method, SimConfig, TenantClass, Trace};
use thinkv::util::cli::Args;
use thinkv::util::rng::Rng;

fn main() {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "calibrate" => cmd_calibrate(&args),
        "sim" => cmd_sim(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "thinkv — thought-adaptive KV cache compression (paper reproduction)

USAGE: thinkv <cmd> [--flags]

  generate  --mode thinkv|fullkv|rkv|h2o|kivi2|... --requests 4
            --budget 1024 --max-tokens 128 --workers 2
            --pool-mb 0 --swap-mb 0 --max-decode-batch 8
            --prefill-chunk 0 --prefix-share
            --policy h2o|rkv|raas|snapkv|streaming|lazy|crystal|skip|fullkv
            --slo-class chat|math|coding --slo-aware
  serve     --addr 127.0.0.1:7799 --mode thinkv --budget 1024
            --pool-mb 0 --swap-mb 0 --max-decode-batch 8
            --prefill-chunk 0 --prefix-share
            --policy h2o|rkv|raas|snapkv|streaming|lazy|crystal|skip|fullkv
            --slo-class chat|math|coding --slo-aware
  sim       --mode thinkv --dataset aime --budget 1024 --scale 0.5
  calibrate --prompts 8 --layers 8
  info

  --pool-mb bounds the device KV block pool (0 = unbounded); with a
  bound, oversubscribed workloads queue and preempt instead of
  overflowing. --swap-mb adds a host-side swap pool: preempted
  sessions suspend their compressed cache to host memory and resume
  with zero recompute steps (0 = recompute preemption only).
  --max-decode-batch caps the cross-session decode batch: each worker
  advances up to that many compatible sessions with one fused engine
  call per step (1 = per-session decode). --prefill-chunk N splits
  prompt prefill into N-token chunks co-scheduled with decode steps —
  each batch carries at most one prefilling session, advancing one
  chunk per fused step, so a long-prompt arrival delays running
  sessions by one chunk instead of a whole prefill (0 = whole-prompt
  prefill; token streams are bit-identical). --prefix-share stores
  identical block-aligned prompt prefixes (system prompts) once: later
  sessions attach the resident read-only blocks, are admitted for only
  their delta bytes, and privatize via copy-on-write on the first
  divergent write — multiplying max concurrency for
  common-system-prompt workloads. --slo-class tags every request with a
  builtin tenant class (chat/math/coding) whose TTFT/TPOT target it is
  scored against at completion; stats then report goodput, violations,
  and per-class latency percentiles. --slo-aware switches the scheduler
  from throughput-greedy FIFO to goodput scheduling: admission and
  batch order follow TTFT-deadline slack, and preemption prefers
  deadline-hopeless victims. --policy overrides the retention policy
  on the uncompressed fp32 cache with any arena registry entry
  (including Crystal-KV answer-first retention and SkipKV selective
  never-materialize), independent of --mode; per-request output and
  stats then report the policy name with its evicted / skipped /
  retained-bytes counters. --replicas N serves from a fleet of N
  scheduler replicas behind a router (--pool-mb / --swap-mb / --workers
  are per replica): new sessions place on the least-loaded lane and the
  router live-migrates suspended snapshots off hot replicas — stats
  gain replicas / migrations / migration_bytes / lane counters.
  --idle-swap-ticks K proactively suspends sessions idle for K
  scheduler ticks to the swap pool (needs --swap-mb) so admission and
  migration find free bytes before preemption storms hit."
    );
}

fn serve_config(args: &Args) -> ServeConfig {
    let mode = CompressionMode::parse(&args.str_or("mode", "thinkv"))
        .unwrap_or_else(CompressionMode::thinkv_default);
    // --pool-mb bounds the KV block pool (0 = unbounded): oversubscribed
    // workloads then queue/preempt instead of overflowing. --swap-mb
    // gives preempted sessions a host-side swap pool so they suspend
    // and resume instead of recomputing.
    let pool_mb = args.u64_or("pool-mb", 0);
    let swap_mb = args.u64_or("swap-mb", 0);
    // --prefill-chunk N splits prompt prefill into N-token chunks
    // co-scheduled with decode steps (0 = whole-prompt prefill)
    let prefill_chunk = args.usize_or("prefill-chunk", 0);
    // --slo-class tags requests with a builtin tenant class (and its
    // TTFT/TPOT target); --slo-aware flips the scheduler to the
    // goodput policy (deadline-slack ordering instead of FIFO)
    let slo_class = args.get("slo-class").and_then(|name| {
        let c = TenantClass::by_name(name);
        if c.is_none() {
            eprintln!("unknown --slo-class {name} (want chat|math|coding); ignoring");
        }
        c
    });
    // --policy picks a live eviction-arena registry entry explicitly
    // (overrides the mode-derived policy; forces the fp32 arena path)
    let policy = args.get("policy").and_then(|name| {
        let p = PolicyKind::parse(name);
        if p.is_none() {
            eprintln!(
                "unknown --policy {name} (want fullkv|h2o|rkv|raas|snapkv|streaming|lazy|crystal|skip); ignoring"
            );
        }
        p
    });
    // --replicas N runs a fleet of N independent scheduler replicas
    // behind a router (pool/swap/workers are per replica); sessions are
    // live-migrated off hot replicas. --idle-swap-ticks K proactively
    // suspends sessions idle >= K scheduler ticks to the swap pool.
    let idle_swap = args.u64_or("idle-swap-ticks", 0);
    ServeConfig {
        mode,
        policy,
        budget: args.usize_or("budget", 1024),
        max_new_tokens: args.usize_or("max-tokens", 128),
        workers: args.usize_or("workers", 2),
        max_decode_batch: args.usize_or("max-decode-batch", 8),
        prefill_chunk_tokens: (prefill_chunk > 0).then_some(prefill_chunk),
        refresh: args.usize_or("refresh", 128),
        temperature: args.f64_or("temperature", 0.8),
        seed: args.u64_or("seed", 42),
        pool_bytes: (pool_mb > 0).then_some(pool_mb << 20),
        swap_bytes: (swap_mb > 0).then_some(swap_mb << 20),
        prefix_share: args.bool("prefix-share"),
        slo_class: slo_class.as_ref().map(|c| c.name.to_string()),
        slo: slo_class.map(|c| c.slo).unwrap_or_default(),
        slo_aware: args.bool("slo-aware"),
        replicas: args.usize_or("replicas", 1),
        idle_swap_ticks: (idle_swap > 0).then_some(idle_swap),
        ..ServeConfig::default()
    }
}

fn cmd_generate(args: &Args) -> i32 {
    let cfg = serve_config(args);
    let n = args.usize_or("requests", 4);
    let share = cfg.prefix_share;
    match cfg.policy_kind() {
        Some(kind) => println!(
            "mode={} policy={} budget={} requests={n}",
            cfg.mode.label(),
            kind.name(),
            cfg.budget
        ),
        None => println!("mode={} budget={} requests={n}", cfg.mode.label(), cfg.budget),
    }
    let coordinator = match Coordinator::start(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start: {e:#}");
            return 1;
        }
    };
    let mut rng = Rng::new(7);
    // with --prefix-share the requests model a common-system-prompt
    // workload: a fixed 32-token system prefix plus a random tail
    let system: Vec<i32> = (0..32).map(|i| ((i * 7) % 512) as i32).collect();
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|_| {
            let mut p = if share { system.clone() } else { Vec::new() };
            let tail = 64 - p.len();
            p.extend((0..tail).map(|_| rng.below(512) as i32));
            p
        })
        .collect();
    let t0 = std::time::Instant::now();
    match coordinator.run_batch(prompts) {
        Ok(results) => {
            let wall = t0.elapsed().as_secs_f64();
            let toks: usize = results.iter().map(|r| r.tokens.len()).sum();
            for r in &results {
                // ttft decomposition: prefill_ms is the engine half,
                // the rest of ttft is scheduling/queue wait
                println!(
                    "  req {}: {} tokens, ttft {:.1} ms (prefill {:.1} ms / {} chunks), tpot {:.2} ms, avg_bits {:.2}, live {}, ct_reuses {}, recompute_preempts {}, swap_ins {}, policy {} (evicted {}, skipped {}, retained {} B)",
                    r.id, r.tokens.len(), r.ttft_ms, r.breakdown.prefill_exec_ns as f64 / 1e6,
                    r.breakdown.prefill_chunks, r.tpot_ms, r.avg_bits, r.live_tokens, r.ct_reuses,
                    r.preemptions, r.swap_ins, r.policy, r.evicted, r.skipped, r.retained_bytes
                );
            }
            println!(
                "TOTAL: {toks} tokens in {wall:.2}s = {:.1} tok/s",
                toks as f64 / wall
            );
            println!("scheduler: {}", coordinator.sched_stats().summary());
            0
        }
        Err(e) => {
            eprintln!("batch failed: {e:#}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let addr = args.str_or("addr", "127.0.0.1:7799");
    let cfg = serve_config(args);
    match Server::start(&addr, cfg) {
        Ok(server) => {
            println!("serving on {} (Ctrl-C to stop)", server.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("server failed: {e:#}");
            1
        }
    }
}

fn cmd_calibrate(args: &Args) -> i32 {
    let prompts = args.usize_or("prompts", 8);
    let layers = args.usize_or("layers", 8);
    // per-prompt per-layer sparsity series from the trace simulator; the
    // "layers" are bands whose mixing mirrors Fig 3 (some layers trimodal,
    // some not)
    let mut series = Vec::new();
    let mut rng = Rng::new(args.u64_or("seed", 5));
    for p in 0..prompts {
        let trace = Trace::generate(&DatasetProfile::aime(), 100 + p as u64, 0.3);
        let mut per_layer = Vec::new();
        for l in 0..layers {
            let clean = l % 2 == 1; // odd layers exhibit the tri-modal structure
            let samples: Vec<f64> = trace.sparsity[trace.prompt_len..]
                .iter()
                .map(|&s| {
                    if clean {
                        s
                    } else {
                        (0.5 + rng.normal() * 0.05).clamp(0.0, 1.0)
                    }
                })
                .collect();
            per_layer.push(samples);
        }
        series.push(per_layer);
    }
    let result = thinkv::thought::calibrate(&series, 3, 4, 0.12);
    println!("L* = {:?}", result.layers);
    println!("theta = {:?}", result.thresholds);
    println!("votes = {:?}", result.votes);
    0
}

fn cmd_sim(args: &Args) -> i32 {
    let dataset = DatasetProfile::by_name(&args.str_or("dataset", "aime"))
        .unwrap_or_else(DatasetProfile::aime);
    let mode = args.str_or("mode", "thinkv");
    let method = match mode.as_str() {
        "thinkv" => Method::ThinKv(Default::default()),
        "fullkv" => Method::FullKv,
        "kivi2" => Method::Kivi { prec: thinkv::quant::Precision::Ternary },
        "kivi4" => Method::Kivi { prec: thinkv::quant::Precision::Nvfp4 },
        "pmkvq" => Method::PmKvq,
        other => {
            use thinkv::sim::harness::EvictKind as E;
            let kind = match other {
                "h2o" => E::H2O,
                "rkv" => E::Rkv,
                "lazy" => E::LazyEviction,
                "raas" => E::RaaS,
                "snapkv" => E::SnapKv,
                "streaming" => E::StreamingLlm,
                _ => {
                    eprintln!("unknown mode {other}");
                    return 1;
                }
            };
            Method::Evict(kind)
        }
    };
    let trace = Trace::generate(&dataset, args.u64_or("seed", 1), args.f64_or("scale", 0.5));
    let cfg = SimConfig {
        budget: args.usize_or("budget", 1024),
        seed: args.u64_or("seed", 1),
        stride: 4,
        rollouts: args.usize_or("rollouts", 64),
    };
    let r = run_method(&trace, &method, &cfg);
    println!(
        "{} on {} (gen {} tokens): pass@1 {:.3}, mem {:.2}%, avg_bits {:.2}, recall@10 {:.3}, evict-rate {:.3}, inflation {:.2}x",
        r.method,
        dataset.name,
        trace.gen_len,
        r.pass1,
        r.mem_frac * 100.0,
        r.avg_bits,
        r.recall10,
        r.evict_call_rate,
        r.len_inflation
    );
    0
}

fn cmd_info() -> i32 {
    match thinkv::model::Manifest::load(&thinkv::model::default_artifacts_dir()) {
        Ok(m) => {
            println!("model: {:?}", m.model);
            println!("quant capacities: {:?}", m.quant_caps);
            println!("fp32 capacities: {:?}", m.fp32_caps);
            println!("batch widths: {:?}", m.batch_widths);
            println!("prefill chunk lens: {:?}", m.prefill_chunk_lens);
            println!("weights: {} tensors", m.weights.len());
            0
        }
        Err(e) => {
            eprintln!("no artifacts ({e}); run `make artifacts` first");
            1
        }
    }
}
