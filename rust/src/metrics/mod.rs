//! Serving metrics: latency histograms, throughput counters, and the
//! per-operation time breakdown used for the Table-5 reproduction.

use std::time::Instant;

use crate::util::stats::{mean, percentile};

/// Latency recorder (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct Latencies {
    samples: Vec<f64>,
}

impl Latencies {
    pub fn record_ms(&mut self, ms: f64) {
        self.samples.push(ms);
    }

    pub fn record_since(&mut self, t0: Instant) {
        self.record_ms(t0.elapsed().as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean_ms(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    pub fn merge(&mut self, other: &Latencies) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Named wall-clock accumulators — the per-operation breakdown (Table 5).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub decode_exec_ns: u64,
    pub quant_write_ns: u64,
    pub tbe_ns: u64,
    pub refresh_ns: u64,
    pub policy_ns: u64, // baseline scoring/eviction
    pub gather_ns: u64,
    pub sample_ns: u64,
    pub steps: u64,
    pub tbe_calls: u64,
    pub refresh_calls: u64,
    pub policy_calls: u64,
    pub gather_calls: u64,
}

impl Breakdown {
    pub fn total_ns(&self) -> u64 {
        self.decode_exec_ns
            + self.quant_write_ns
            + self.tbe_ns
            + self.refresh_ns
            + self.policy_ns
            + self.gather_ns
            + self.sample_ns
    }

    /// (label, % of total time, calls % of steps) rows, Table-5 style.
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total_ns().max(1) as f64;
        let steps = self.steps.max(1) as f64;
        vec![
            ("Decode exec (attention+MLP)", self.decode_exec_ns as f64 / total * 100.0, 100.0),
            ("Quant write (TBQ)", self.quant_write_ns as f64 / total * 100.0, 100.0),
            ("TBE eviction", self.tbe_ns as f64 / total * 100.0, self.tbe_calls as f64 / steps * 100.0),
            ("Thought refresh", self.refresh_ns as f64 / total * 100.0, self.refresh_calls as f64 / steps * 100.0),
            ("Policy scoring", self.policy_ns as f64 / total * 100.0, self.policy_calls as f64 / steps * 100.0),
            ("Gather compaction", self.gather_ns as f64 / total * 100.0, self.gather_calls as f64 / steps * 100.0),
            ("Sampling", self.sample_ns as f64 / total * 100.0, 100.0),
        ]
    }

    pub fn merge(&mut self, o: &Breakdown) {
        self.decode_exec_ns += o.decode_exec_ns;
        self.quant_write_ns += o.quant_write_ns;
        self.tbe_ns += o.tbe_ns;
        self.refresh_ns += o.refresh_ns;
        self.policy_ns += o.policy_ns;
        self.gather_ns += o.gather_ns;
        self.sample_ns += o.sample_ns;
        self.steps += o.steps;
        self.tbe_calls += o.tbe_calls;
        self.refresh_calls += o.refresh_calls;
        self.policy_calls += o.policy_calls;
        self.gather_calls += o.gather_calls;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latencies_percentiles() {
        let mut l = Latencies::default();
        for i in 1..=100 {
            l.record_ms(i as f64);
        }
        assert_eq!(l.count(), 100);
        assert!((l.mean_ms() - 50.5).abs() < 1e-9);
        assert!((l.p50_ms() - 50.5).abs() < 1.0);
        assert!(l.p99_ms() > 98.0);
    }

    #[test]
    fn breakdown_rows_sum_to_100() {
        let b = Breakdown {
            decode_exec_ns: 70,
            quant_write_ns: 10,
            tbe_ns: 10,
            refresh_ns: 5,
            sample_ns: 5,
            steps: 100,
            tbe_calls: 5,
            refresh_calls: 1,
            ..Default::default()
        };
        let total: f64 = b.rows().iter().map(|r| r.1).sum();
        assert!((total - 100.0).abs() < 1e-6);
        let tbe_row = b.rows()[2];
        assert!((tbe_row.2 - 5.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Breakdown { steps: 10, decode_exec_ns: 100, ..Default::default() };
        let b = Breakdown { steps: 5, decode_exec_ns: 50, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.steps, 15);
        assert_eq!(a.decode_exec_ns, 150);
    }
}
