//! Serving metrics: latency histograms, throughput counters, the
//! per-operation time breakdown used for the Table-5 reproduction, and
//! the scheduler/pool snapshot surfaced by the server `stats` command —
//! including the suspend-to-host swap counters ([`SchedSnapshot`]:
//! swap-in/out counts, bytes moved, restore latency, recompute
//! fallbacks) added for the preemption fast path, the cross-session
//! batched-decode counters (fused steps, session-steps advanced,
//! decode-batch size histogram), the chunked-prefill lane counters
//! (chunk size, chunks run, interleaved steps, prefill-queue depth),
//! and the SLO-aware goodput counters (policy echo, global and
//! per-class goodput / violation counts, TTFT/TPOT percentiles —
//! [`SloClassSnap`]).

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

/// Latency recorder (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct Latencies {
    samples: Vec<f64>,
}

impl Latencies {
    pub fn record_ms(&mut self, ms: f64) {
        self.samples.push(ms);
    }

    pub fn record_since(&mut self, t0: Instant) {
        self.record_ms(t0.elapsed().as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean_ms(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    pub fn merge(&mut self, other: &Latencies) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Named wall-clock accumulators — the per-operation breakdown (Table 5).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    /// Engine wall time running prompt prefill (whole-prompt call or
    /// the sum of chunked-prefill calls) — the execution half of TTFT;
    /// `ttft - prefill_exec` is scheduling/queue wait.
    pub prefill_exec_ns: u64,
    pub decode_exec_ns: u64,
    pub quant_write_ns: u64,
    pub tbe_ns: u64,
    pub refresh_ns: u64,
    pub policy_ns: u64, // baseline scoring/eviction
    pub gather_ns: u64,
    pub sample_ns: u64,
    pub steps: u64,
    /// Prefill chunks executed (1 for a whole-prompt prefill).
    pub prefill_chunks: u64,
    pub tbe_calls: u64,
    pub refresh_calls: u64,
    pub policy_calls: u64,
    pub gather_calls: u64,
    /// Actual PJRT decode executes this session caused (diffed from
    /// [`crate::runtime::ExecStats`] around each engine call): fused
    /// batches count 1, per-member fallback counts 1 per member.
    /// Engines without a PJRT surface (test fakes) report 0.
    pub pjrt_decode_executes: u64,
    /// PJRT prefill executes (whole-prompt calls + per-chunk executes).
    pub pjrt_prefill_executes: u64,
    /// Decode executes attributable to the per-member fallback path (a
    /// subset of `pjrt_decode_executes`): nonzero means some step ran
    /// without a covering batched artifact.
    pub pjrt_fallback_executes: u64,
    /// Chunk requests served from the engine's whole-prompt memo
    /// (no execute issued).
    pub prefill_memo_hits: u64,
    /// Memo/chunk-state entries evicted by the engine's LRU cap.
    pub prefill_memo_evictions: u64,
}

impl Breakdown {
    pub fn total_ns(&self) -> u64 {
        self.prefill_exec_ns
            + self.decode_exec_ns
            + self.quant_write_ns
            + self.tbe_ns
            + self.refresh_ns
            + self.policy_ns
            + self.gather_ns
            + self.sample_ns
    }

    /// (label, % of total time, calls % of steps) rows, Table-5 style.
    /// Prefill is once-per-request work, not per-step: its call-rate
    /// column is a flat 100% when it ran (like decode/sampling), never
    /// `chunks / steps`, which would read as >100% for long prompts.
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total_ns().max(1) as f64;
        let steps = self.steps.max(1) as f64;
        let prefill_rate = if self.prefill_chunks > 0 { 100.0 } else { 0.0 };
        vec![
            ("Prefill exec", self.prefill_exec_ns as f64 / total * 100.0, prefill_rate),
            ("Decode exec (attention+MLP)", self.decode_exec_ns as f64 / total * 100.0, 100.0),
            ("Quant write (TBQ)", self.quant_write_ns as f64 / total * 100.0, 100.0),
            ("TBE eviction", self.tbe_ns as f64 / total * 100.0, self.tbe_calls as f64 / steps * 100.0),
            ("Thought refresh", self.refresh_ns as f64 / total * 100.0, self.refresh_calls as f64 / steps * 100.0),
            ("Policy scoring", self.policy_ns as f64 / total * 100.0, self.policy_calls as f64 / steps * 100.0),
            ("Gather compaction", self.gather_ns as f64 / total * 100.0, self.gather_calls as f64 / steps * 100.0),
            ("Sampling", self.sample_ns as f64 / total * 100.0, 100.0),
        ]
    }

    pub fn merge(&mut self, o: &Breakdown) {
        self.prefill_exec_ns += o.prefill_exec_ns;
        self.prefill_chunks += o.prefill_chunks;
        self.decode_exec_ns += o.decode_exec_ns;
        self.quant_write_ns += o.quant_write_ns;
        self.tbe_ns += o.tbe_ns;
        self.refresh_ns += o.refresh_ns;
        self.policy_ns += o.policy_ns;
        self.gather_ns += o.gather_ns;
        self.sample_ns += o.sample_ns;
        self.steps += o.steps;
        self.tbe_calls += o.tbe_calls;
        self.refresh_calls += o.refresh_calls;
        self.policy_calls += o.policy_calls;
        self.gather_calls += o.gather_calls;
        self.pjrt_decode_executes += o.pjrt_decode_executes;
        self.pjrt_prefill_executes += o.pjrt_prefill_executes;
        self.pjrt_fallback_executes += o.pjrt_fallback_executes;
        self.prefill_memo_hits += o.prefill_memo_hits;
        self.prefill_memo_evictions += o.prefill_memo_evictions;
    }
}

/// Per-tenant-class SLO scoreboard inside [`SchedSnapshot`]: verdict
/// counts plus nearest-rank latency percentiles, all integer-typed
/// (ticks / milli-ticks) so the snapshot stays `Eq`-comparable across
/// bit-reproducible replays.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SloClassSnap {
    /// Tenant-class label (`"chat"`, `"math"`, ...).
    pub name: String,
    /// Sessions of this class that finished meeting their SLO target.
    pub goodput: u64,
    /// Sessions of this class that finished missing it (failures
    /// included).
    pub violations: u64,
    /// TTFT p50 across finished classed sessions, in scheduler ticks.
    pub ttft_p50: u64,
    pub ttft_p99: u64,
    /// TPOT p50 in milli-ticks per output token (fixed-point, so 2500
    /// = 2.5 ticks/token).
    pub tpot_p50_milli: u64,
    pub tpot_p99_milli: u64,
}

impl SloClassSnap {
    /// JSON object for the `stats` command / bench result files.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("goodput", Json::Num(self.goodput as f64));
        j.set("violations", Json::Num(self.violations as f64));
        j.set("ttft_p50", Json::Num(self.ttft_p50 as f64));
        j.set("ttft_p99", Json::Num(self.ttft_p99 as f64));
        j.set("tpot_p50_milli", Json::Num(self.tpot_p50_milli as f64));
        j.set("tpot_p99_milli", Json::Num(self.tpot_p99_milli as f64));
        j
    }
}

/// Point-in-time view of the memory-aware scheduler and its block pool
/// (Tables 2/3 serving discipline: admissions, preemptions, KV bytes).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SchedSnapshot {
    /// Pool capacity in bytes (packed KV accounting).
    pub pool_capacity: u64,
    pub pool_used: u64,
    pub pool_peak: u64,
    pub pool_free: u64,
    /// Live byte leases charged against the pool (ledger gauge).
    pub pool_leases: u64,
    /// Bytes those live leases hold; equals `pool_used` at quiescent
    /// points (the conservation invariant [`BlockPool::audit`] checks).
    ///
    /// [`BlockPool::audit`]: crate::kvcache::BlockPool::audit
    pub pool_leased_bytes: u64,
    /// Total admissions (re-admissions after preemption included).
    pub admissions: u64,
    /// Sessions preempted (reset + requeued) to reclaim KV bytes.
    pub preemptions: u64,
    pub completions: u64,
    /// Requests terminated abnormally: KV demand exceeded the pool, or
    /// the decode loop errored.
    pub rejections: u64,
    /// Submitted but not yet admitted (waiting for KV bytes).
    pub queue_depth: usize,
    /// Currently admitted (runnable or held by a worker).
    pub running: usize,
    /// Submitted and not yet finished.
    pub inflight: u64,
    /// Fused decode steps executed (one engine call per decode batch
    /// per step — the cross-session batching fast path).
    pub fused_steps: u64,
    /// Session-steps advanced by fused calls (sum of batch sizes);
    /// `fused_sessions / fused_steps` is the mean decode-batch size.
    pub fused_sessions: u64,
    /// Decode-batch size histogram: bucket `i` counts fused steps whose
    /// batch held `i + 1` sessions (the last bucket absorbs larger
    /// batches). Empty until the scheduler records a fused step.
    pub batch_hist: Vec<u64>,
    /// Chunked-prefill configuration: tokens per prefill chunk
    /// (0 = whole-prompt prefill inside the first decode step).
    pub prefill_chunk_tokens: usize,
    /// Prefill chunks executed by workers (chunked mode only).
    pub prefill_chunks: u64,
    /// Fused steps that advanced decode members **and** a prefill chunk
    /// in the same step — the stall-free interleave this counter exists
    /// to prove is happening.
    pub prefill_interleaved_steps: u64,
    /// Gauge: queued sessions (waiting / runnable / stalled) still owing
    /// prompt prefill work. Members currently held by a worker are not
    /// visible to the snapshot and are excluded.
    pub prefill_queue_depth: usize,
    /// Host-side swap pool capacity (0 = suspend-to-host disabled).
    pub swap_capacity: u64,
    /// Swap pool bytes currently holding suspended sessions.
    pub swap_used: u64,
    pub swap_peak: u64,
    /// Preemptions that suspended the victim's cache to host.
    pub swap_outs: u64,
    /// Suspended sessions restored (resumed with zero recompute steps).
    pub swap_ins: u64,
    /// Bytes copied host-ward by swap-outs.
    pub swap_bytes_out: u64,
    /// Bytes copied device-ward by swap-ins.
    pub swap_bytes_in: u64,
    /// Cumulative snapshot-restore wall time (swap-in latency).
    pub swap_restore_ns: u64,
    /// Preemptions that fell back to recompute (snapshot did not fit,
    /// or a snapshot restore failed and the session recomputed).
    pub swap_fallbacks: u64,
    /// Cross-session prefix sharing configured on this scheduler.
    pub prefix_enabled: bool,
    /// Prompts whose prefix matched a resident shared entry (the
    /// session attached and was charged delta-only).
    pub prefix_hits: u64,
    /// Prompts that matched no resident prefix.
    pub prefix_misses: u64,
    /// Prefixes published (residency charged to the pool once).
    pub prefix_inserts: u64,
    /// Publishes refused for lack of pool bytes.
    pub prefix_publish_fails: u64,
    /// Copy-on-write privatizations (first write past a shared boundary).
    pub prefix_cow_faults: u64,
    /// CoW attempts denied by pool pressure (region stayed read-only).
    pub prefix_cow_denied: u64,
    /// Unreferenced resident prefixes reclaimed under memory pressure.
    pub prefix_reclaims: u64,
    /// Gauge: pool bytes currently held by resident shared prefixes.
    pub prefix_resident_bytes: u64,
    /// Gauge: resident shared-prefix entries.
    pub prefix_resident_entries: u64,
    /// Zero-copy prefix attaches: the session's block table aliased the
    /// resident payload instead of memcpying it into its own cache.
    pub prefix_alias_hits: u64,
    /// Bytes the alias attaches did **not** copy (the PR-4 attach
    /// memcpy this counter proves is gone from the hot path).
    pub prefix_alias_bytes: u64,
    /// Actual PJRT decode executes across all workers (fused batch = 1;
    /// fallback member = 1 each). With batched artifacts compiled and a
    /// homogeneous batch this advances by exactly 1 per fused step.
    pub pjrt_decode_executes: u64,
    /// PJRT prefill executes (whole-prompt + per-chunk).
    pub pjrt_prefill_executes: u64,
    /// Decode executes that took the counted per-member fallback.
    pub pjrt_fallback_executes: u64,
    /// Engine prefill-memo hits (chunk served without an execute).
    pub prefill_memo_hits: u64,
    /// Engine prefill-memo/chunk-state LRU evictions.
    pub prefill_memo_evictions: u64,
    /// Retention-policy label of the live eviction arena (empty = no
    /// fp32 policy arena configured). Stamped from the serve config by
    /// the coordinator; the scheduler itself only tallies counters.
    pub policy: String,
    /// Positions evicted by the live retention policy, summed over
    /// terminated sessions.
    pub policy_evictions: u64,
    /// Positions never materialized (SkipKV's never-materialize axis:
    /// no pool bytes, no cache row), summed over terminated sessions.
    pub policy_skips: u64,
    /// KV bytes still retained at session termination, summed.
    pub policy_retained_bytes: u64,
    /// True when the scheduler runs the goodput (SLO-aware) policy —
    /// deadline-slack ordering instead of FIFO.
    pub sched_policy_goodput: bool,
    /// Classed sessions that finished meeting their SLO target.
    pub goodput: u64,
    /// Classed sessions that finished missing it (failures included).
    /// `goodput + slo_violations` = classed terminations; the per-class
    /// counts in `slo_classes` sum to the same pair.
    pub slo_violations: u64,
    /// Per-tenant-class scoreboards, in first-termination order (empty
    /// until a classed session finishes).
    pub slo_classes: Vec<SloClassSnap>,
    /// Gauge: distinct `BatchKey` lanes among runnable sessions at
    /// snapshot time (0 = empty queue, 1 = homogeneous).
    pub lanes: usize,
    /// High-water mark of the widest runnable lane ever observed by
    /// batch formation.
    pub lane_peak: u64,
    /// Times batch formation rotated a wider lane ahead of a narrower
    /// front lane (bounded by the anti-starvation skip limit).
    pub lane_switches: u64,
    /// Sessions proactively suspended to host after sitting idle for
    /// `--idle-swap-ticks` scheduler ticks (0 when the flag is off).
    pub idle_swapouts: u64,
    /// Replicas merged into this snapshot (1 = a single scheduler; the
    /// router stamps the fleet width on merged views).
    pub replicas: usize,
    /// Live migrations completed: victim suspended on one replica and
    /// resumed on another with zero recompute steps.
    pub migrations: u64,
    /// Snapshot bytes moved across replicas by those migrations.
    pub migration_bytes: u64,
    /// Cumulative wall time spent inside migration suspend+resume.
    pub migration_ns: u64,
}

impl SchedSnapshot {
    /// JSON object for the server `stats` command / bench result files.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("pool_capacity", Json::Num(self.pool_capacity as f64));
        j.set("pool_used", Json::Num(self.pool_used as f64));
        j.set("pool_peak", Json::Num(self.pool_peak as f64));
        j.set("pool_free", Json::Num(self.pool_free as f64));
        j.set("pool_leases", Json::Num(self.pool_leases as f64));
        j.set("pool_leased_bytes", Json::Num(self.pool_leased_bytes as f64));
        j.set("admissions", Json::Num(self.admissions as f64));
        j.set("preemptions", Json::Num(self.preemptions as f64));
        j.set("completions", Json::Num(self.completions as f64));
        j.set("rejections", Json::Num(self.rejections as f64));
        j.set("queue_depth", Json::Num(self.queue_depth as f64));
        j.set("running", Json::Num(self.running as f64));
        j.set("inflight", Json::Num(self.inflight as f64));
        j.set("fused_steps", Json::Num(self.fused_steps as f64));
        j.set("fused_sessions", Json::Num(self.fused_sessions as f64));
        j.set(
            "batch_hist",
            Json::Arr(self.batch_hist.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        j.set("prefill_chunk_tokens", Json::Num(self.prefill_chunk_tokens as f64));
        j.set("prefill_chunks", Json::Num(self.prefill_chunks as f64));
        j.set(
            "prefill_interleaved_steps",
            Json::Num(self.prefill_interleaved_steps as f64),
        );
        j.set("prefill_queue_depth", Json::Num(self.prefill_queue_depth as f64));
        j.set("swap_capacity", Json::Num(self.swap_capacity as f64));
        j.set("swap_used", Json::Num(self.swap_used as f64));
        j.set("swap_peak", Json::Num(self.swap_peak as f64));
        j.set("swap_outs", Json::Num(self.swap_outs as f64));
        j.set("swap_ins", Json::Num(self.swap_ins as f64));
        j.set("swap_bytes_out", Json::Num(self.swap_bytes_out as f64));
        j.set("swap_bytes_in", Json::Num(self.swap_bytes_in as f64));
        j.set("swap_restore_ms", Json::Num(self.swap_restore_ns as f64 / 1e6));
        j.set("swap_fallbacks", Json::Num(self.swap_fallbacks as f64));
        j.set("prefix_enabled", Json::Num(if self.prefix_enabled { 1.0 } else { 0.0 }));
        j.set("prefix_hits", Json::Num(self.prefix_hits as f64));
        j.set("prefix_misses", Json::Num(self.prefix_misses as f64));
        j.set("prefix_inserts", Json::Num(self.prefix_inserts as f64));
        j.set("prefix_publish_fails", Json::Num(self.prefix_publish_fails as f64));
        j.set("prefix_cow_faults", Json::Num(self.prefix_cow_faults as f64));
        j.set("prefix_cow_denied", Json::Num(self.prefix_cow_denied as f64));
        j.set("prefix_reclaims", Json::Num(self.prefix_reclaims as f64));
        j.set("prefix_resident_bytes", Json::Num(self.prefix_resident_bytes as f64));
        j.set("prefix_resident_entries", Json::Num(self.prefix_resident_entries as f64));
        j.set("prefix_alias_hits", Json::Num(self.prefix_alias_hits as f64));
        j.set("prefix_alias_bytes", Json::Num(self.prefix_alias_bytes as f64));
        j.set("pjrt_decode_executes", Json::Num(self.pjrt_decode_executes as f64));
        j.set("pjrt_prefill_executes", Json::Num(self.pjrt_prefill_executes as f64));
        j.set("pjrt_fallback_executes", Json::Num(self.pjrt_fallback_executes as f64));
        j.set("prefill_memo_hits", Json::Num(self.prefill_memo_hits as f64));
        j.set("prefill_memo_evictions", Json::Num(self.prefill_memo_evictions as f64));
        j.set("policy", Json::Str(self.policy.clone()));
        j.set("policy_evictions", Json::Num(self.policy_evictions as f64));
        j.set("policy_skips", Json::Num(self.policy_skips as f64));
        j.set("policy_retained_bytes", Json::Num(self.policy_retained_bytes as f64));
        j.set(
            "sched_policy",
            Json::Str(if self.sched_policy_goodput { "goodput" } else { "throughput" }.into()),
        );
        j.set("goodput", Json::Num(self.goodput as f64));
        j.set("slo_violations", Json::Num(self.slo_violations as f64));
        j.set("slo_classes", Json::Arr(self.slo_classes.iter().map(|c| c.to_json()).collect()));
        j.set("lanes", Json::Num(self.lanes as f64));
        j.set("lane_peak", Json::Num(self.lane_peak as f64));
        j.set("lane_switches", Json::Num(self.lane_switches as f64));
        j.set("idle_swapouts", Json::Num(self.idle_swapouts as f64));
        j.set("replicas", Json::Num(self.replicas as f64));
        j.set("migrations", Json::Num(self.migrations as f64));
        j.set("migration_bytes", Json::Num(self.migration_bytes as f64));
        j.set("migration_ms", Json::Num(self.migration_ns as f64 / 1e6));
        j
    }

    /// Fleet-merged view: fold another replica's snapshot into this one.
    ///
    /// Counters and pool/swap gauges sum; the batch histogram merges
    /// element-wise; boolean config flags OR; `lane_peak` takes the max.
    /// Prefix counters are **not** summed — with a fleet-global
    /// [`crate::kvcache::PrefixIndex`] every replica reports the same
    /// shared books, so the caller keeps the first replica's values.
    /// Per-class SLO scoreboards merge by class name (counts sum,
    /// percentiles take the element-wise max — a conservative fleet
    /// tail estimate without re-deriving the underlying samples).
    pub fn merge_replica(&mut self, other: &SchedSnapshot) {
        self.pool_capacity += other.pool_capacity;
        self.pool_used += other.pool_used;
        self.pool_peak += other.pool_peak;
        self.pool_free += other.pool_free;
        self.pool_leases += other.pool_leases;
        self.pool_leased_bytes += other.pool_leased_bytes;
        self.admissions += other.admissions;
        self.preemptions += other.preemptions;
        self.completions += other.completions;
        self.rejections += other.rejections;
        self.queue_depth += other.queue_depth;
        self.running += other.running;
        self.inflight += other.inflight;
        self.fused_steps += other.fused_steps;
        self.fused_sessions += other.fused_sessions;
        if self.batch_hist.len() < other.batch_hist.len() {
            self.batch_hist.resize(other.batch_hist.len(), 0);
        }
        for (i, &n) in other.batch_hist.iter().enumerate() {
            self.batch_hist[i] += n;
        }
        self.prefill_chunk_tokens = self.prefill_chunk_tokens.max(other.prefill_chunk_tokens);
        self.prefill_chunks += other.prefill_chunks;
        self.prefill_interleaved_steps += other.prefill_interleaved_steps;
        self.prefill_queue_depth += other.prefill_queue_depth;
        self.swap_capacity += other.swap_capacity;
        self.swap_used += other.swap_used;
        self.swap_peak += other.swap_peak;
        self.swap_outs += other.swap_outs;
        self.swap_ins += other.swap_ins;
        self.swap_bytes_out += other.swap_bytes_out;
        self.swap_bytes_in += other.swap_bytes_in;
        self.swap_restore_ns += other.swap_restore_ns;
        self.swap_fallbacks += other.swap_fallbacks;
        self.prefix_enabled |= other.prefix_enabled;
        self.pjrt_decode_executes += other.pjrt_decode_executes;
        self.pjrt_prefill_executes += other.pjrt_prefill_executes;
        self.pjrt_fallback_executes += other.pjrt_fallback_executes;
        self.prefill_memo_hits += other.prefill_memo_hits;
        self.prefill_memo_evictions += other.prefill_memo_evictions;
        if self.policy.is_empty() {
            self.policy = other.policy.clone();
        }
        self.policy_evictions += other.policy_evictions;
        self.policy_skips += other.policy_skips;
        self.policy_retained_bytes += other.policy_retained_bytes;
        self.sched_policy_goodput |= other.sched_policy_goodput;
        self.goodput += other.goodput;
        self.slo_violations += other.slo_violations;
        for oc in &other.slo_classes {
            match self.slo_classes.iter_mut().find(|c| c.name == oc.name) {
                Some(c) => {
                    c.goodput += oc.goodput;
                    c.violations += oc.violations;
                    c.ttft_p50 = c.ttft_p50.max(oc.ttft_p50);
                    c.ttft_p99 = c.ttft_p99.max(oc.ttft_p99);
                    c.tpot_p50_milli = c.tpot_p50_milli.max(oc.tpot_p50_milli);
                    c.tpot_p99_milli = c.tpot_p99_milli.max(oc.tpot_p99_milli);
                }
                None => self.slo_classes.push(oc.clone()),
            }
        }
        self.lanes += other.lanes;
        self.lane_peak = self.lane_peak.max(other.lane_peak);
        self.lane_switches += other.lane_switches;
        self.idle_swapouts += other.idle_swapouts;
        self.replicas += other.replicas;
        self.migrations += other.migrations;
        self.migration_bytes += other.migration_bytes;
        self.migration_ns += other.migration_ns;
    }

    /// One-line human summary for CLI output (plus a swap line when
    /// suspend-to-host is enabled).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "pool {}/{} B used (peak {}), adm {}, preempt {}, done {}, rej {}, queued {}, running {}",
            self.pool_used,
            self.pool_capacity,
            self.pool_peak,
            self.admissions,
            self.preemptions,
            self.completions,
            self.rejections,
            self.queue_depth,
            self.running
        );
        if self.fused_steps > 0 {
            s.push_str(&format!(
                "\ndecode: {} fused steps / {} session-steps (avg batch {:.2})",
                self.fused_steps,
                self.fused_sessions,
                self.fused_sessions as f64 / self.fused_steps as f64
            ));
        }
        if self.pjrt_decode_executes + self.pjrt_prefill_executes > 0 {
            s.push_str(&format!(
                "\npjrt: {} decode executes ({} fallback) / {} prefill executes, memo {} hits / {} evictions",
                self.pjrt_decode_executes,
                self.pjrt_fallback_executes,
                self.pjrt_prefill_executes,
                self.prefill_memo_hits,
                self.prefill_memo_evictions
            ));
        }
        if self.prefill_chunk_tokens > 0 {
            s.push_str(&format!(
                "\nprefill: chunk {} tok, {} chunks run, {} interleaved steps, {} queued",
                self.prefill_chunk_tokens,
                self.prefill_chunks,
                self.prefill_interleaved_steps,
                self.prefill_queue_depth
            ));
        }
        if self.swap_capacity > 0 {
            s.push_str(&format!(
                "\nswap: {} out / {} in ({} B out, {} B in), restore {:.2} ms, fallbacks {}, host {}/{} B (peak {})",
                self.swap_outs,
                self.swap_ins,
                self.swap_bytes_out,
                self.swap_bytes_in,
                self.swap_restore_ns as f64 / 1e6,
                self.swap_fallbacks,
                self.swap_used,
                self.swap_capacity,
                self.swap_peak
            ));
        }
        if !self.policy.is_empty() {
            s.push_str(&format!(
                "\npolicy {}: {} evicted, {} skipped, {} B retained",
                self.policy, self.policy_evictions, self.policy_skips, self.policy_retained_bytes
            ));
        }
        if self.goodput + self.slo_violations > 0 || self.sched_policy_goodput {
            s.push_str(&format!(
                "\nslo ({}): goodput {}, violations {}",
                if self.sched_policy_goodput { "goodput policy" } else { "throughput policy" },
                self.goodput,
                self.slo_violations
            ));
            for c in &self.slo_classes {
                s.push_str(&format!(
                    " | {}: {}/{} met, ttft p50/p99 {}/{}, tpot p50/p99 {}/{} milli",
                    c.name,
                    c.goodput,
                    c.goodput + c.violations,
                    c.ttft_p50,
                    c.ttft_p99,
                    c.tpot_p50_milli,
                    c.tpot_p99_milli
                ));
            }
        }
        if self.lane_peak > 0 {
            s.push_str(&format!(
                "\nlanes: {} live (peak width {}), {} switches, {} idle swap-outs",
                self.lanes, self.lane_peak, self.lane_switches, self.idle_swapouts
            ));
        }
        if self.replicas > 1 || self.migrations > 0 {
            s.push_str(&format!(
                "\nfleet: {} replicas, {} migrations ({} B moved, {:.2} ms)",
                self.replicas,
                self.migrations,
                self.migration_bytes,
                self.migration_ns as f64 / 1e6
            ));
        }
        if self.prefix_enabled {
            s.push_str(&format!(
                "\nprefix: {} hits / {} misses, {} resident ({} B), cow {} (+{} denied), reclaims {}, alias {} ({} B uncopied)",
                self.prefix_hits,
                self.prefix_misses,
                self.prefix_resident_entries,
                self.prefix_resident_bytes,
                self.prefix_cow_faults,
                self.prefix_cow_denied,
                self.prefix_reclaims,
                self.prefix_alias_hits,
                self.prefix_alias_bytes
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latencies_percentiles() {
        let mut l = Latencies::default();
        for i in 1..=100 {
            l.record_ms(i as f64);
        }
        assert_eq!(l.count(), 100);
        assert!((l.mean_ms() - 50.5).abs() < 1e-9);
        assert!((l.p50_ms() - 50.5).abs() < 1.0);
        assert!(l.p99_ms() > 98.0);
    }

    #[test]
    fn breakdown_rows_sum_to_100() {
        let b = Breakdown {
            prefill_exec_ns: 20,
            decode_exec_ns: 50,
            quant_write_ns: 10,
            tbe_ns: 10,
            refresh_ns: 5,
            sample_ns: 5,
            steps: 100,
            prefill_chunks: 4,
            tbe_calls: 5,
            refresh_calls: 1,
            ..Default::default()
        };
        let total: f64 = b.rows().iter().map(|r| r.1).sum();
        assert!((total - 100.0).abs() < 1e-6);
        let prefill_row = b.rows()[0];
        assert!((prefill_row.1 - 20.0).abs() < 1e-9, "prefill_exec_ns in rows");
        let tbe_row = b.rows()[3];
        assert!((tbe_row.2 - 5.0).abs() < 1e-9);
    }

    /// Satellite regression: `prefill_exec_ns` must flow into
    /// `total_ns` (it used to be recorded nowhere, so TTFT could not be
    /// decomposed and `total_ns` undercounted).
    #[test]
    fn prefill_exec_counts_toward_total_and_merges() {
        let mut a = Breakdown { prefill_exec_ns: 40, decode_exec_ns: 60, ..Default::default() };
        assert_eq!(a.total_ns(), 100);
        let b = Breakdown { prefill_exec_ns: 5, prefill_chunks: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.prefill_exec_ns, 45);
        assert_eq!(a.prefill_chunks, 2);
        assert_eq!(a.total_ns(), 105);
    }

    #[test]
    fn sched_snapshot_json_and_summary() {
        let s = SchedSnapshot {
            pool_capacity: 100,
            pool_used: 40,
            pool_peak: 60,
            pool_free: 60,
            admissions: 3,
            preemptions: 1,
            completions: 2,
            rejections: 0,
            queue_depth: 1,
            running: 2,
            inflight: 3,
            ..SchedSnapshot::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("pool_peak").and_then(Json::as_usize), Some(60));
        assert_eq!(j.get("queue_depth").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("swap_outs").and_then(Json::as_usize), Some(0));
        assert!(s.summary().contains("preempt 1"));
        // swap disabled (capacity 0): the summary stays a single line
        assert!(!s.summary().contains("swap:"));
    }

    /// Satellite regression: the live arena's policy identity and
    /// retention counters must survive the full metrics path — snapshot
    /// → JSON text → reparse — and show up in the human summary, so a
    /// server client can tell *which* policy served it and what it cost.
    #[test]
    fn sched_snapshot_policy_fields_roundtrip_json() {
        let s = SchedSnapshot {
            policy: "Crystal-KV".into(),
            policy_evictions: 12,
            policy_skips: 5,
            policy_retained_bytes: 4096,
            ..SchedSnapshot::default()
        };
        let text = s.to_json().to_string();
        let j = crate::util::json::parse(&text).expect("snapshot JSON reparses");
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("Crystal-KV"));
        assert_eq!(j.get("policy_evictions").and_then(Json::as_usize), Some(12));
        assert_eq!(j.get("policy_skips").and_then(Json::as_usize), Some(5));
        assert_eq!(j.get("policy_retained_bytes").and_then(Json::as_usize), Some(4096));
        let summary = s.summary();
        assert!(summary.contains("policy Crystal-KV: 12 evicted, 5 skipped, 4096 B retained"));
        // no arena configured: the policy line is omitted entirely
        assert!(!SchedSnapshot::default().summary().contains("policy "));
    }

    #[test]
    fn sched_snapshot_fused_decode_fields_surface() {
        let mut hist = vec![0u64; 16];
        hist[0] = 2; // two singleton steps
        hist[3] = 5; // five 4-wide fused steps
        let s = SchedSnapshot {
            fused_steps: 7,
            fused_sessions: 22,
            batch_hist: hist,
            ..SchedSnapshot::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("fused_steps").and_then(Json::as_usize), Some(7));
        assert_eq!(j.get("fused_sessions").and_then(Json::as_usize), Some(22));
        let hist_json = j.get("batch_hist").and_then(Json::as_arr).expect("hist array");
        assert_eq!(hist_json.len(), 16);
        assert_eq!(hist_json[3].as_f64(), Some(5.0));
        let summary = s.summary();
        assert!(summary.contains("7 fused steps / 22 session-steps"));
        assert!(summary.contains("avg batch 3.14"));
        // no fused steps recorded: the decode line is omitted entirely
        assert!(!SchedSnapshot::default().summary().contains("fused"));
    }

    #[test]
    fn sched_snapshot_prefill_fields_surface() {
        let s = SchedSnapshot {
            prefill_chunk_tokens: 128,
            prefill_chunks: 9,
            prefill_interleaved_steps: 7,
            prefill_queue_depth: 2,
            ..SchedSnapshot::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("prefill_chunk_tokens").and_then(Json::as_usize), Some(128));
        assert_eq!(j.get("prefill_chunks").and_then(Json::as_usize), Some(9));
        assert_eq!(j.get("prefill_interleaved_steps").and_then(Json::as_usize), Some(7));
        assert_eq!(j.get("prefill_queue_depth").and_then(Json::as_usize), Some(2));
        let summary = s.summary();
        assert!(summary.contains("prefill: chunk 128 tok"));
        assert!(summary.contains("7 interleaved steps"));
        // chunking disabled: the prefill line is omitted entirely
        assert!(!SchedSnapshot::default().summary().contains("prefill:"));
    }

    #[test]
    fn sched_snapshot_swap_fields_surface() {
        let s = SchedSnapshot {
            swap_capacity: 1 << 30,
            swap_used: 512,
            swap_peak: 1024,
            swap_outs: 4,
            swap_ins: 3,
            swap_bytes_out: 2048,
            swap_bytes_in: 1536,
            swap_restore_ns: 2_000_000,
            swap_fallbacks: 1,
            ..SchedSnapshot::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("swap_outs").and_then(Json::as_usize), Some(4));
        assert_eq!(j.get("swap_ins").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("swap_bytes_out").and_then(Json::as_usize), Some(2048));
        assert_eq!(j.get("swap_fallbacks").and_then(Json::as_usize), Some(1));
        let summary = s.summary();
        assert!(summary.contains("swap: 4 out / 3 in"));
        assert!(summary.contains("fallbacks 1"));
    }

    #[test]
    fn sched_snapshot_prefix_fields_surface() {
        let s = SchedSnapshot {
            prefix_enabled: true,
            prefix_hits: 5,
            prefix_misses: 2,
            prefix_inserts: 1,
            prefix_cow_faults: 1,
            prefix_cow_denied: 1,
            prefix_reclaims: 3,
            prefix_resident_bytes: 4096,
            prefix_resident_entries: 1,
            ..SchedSnapshot::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("prefix_hits").and_then(Json::as_usize), Some(5));
        assert_eq!(j.get("prefix_enabled").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("prefix_resident_bytes").and_then(Json::as_usize), Some(4096));
        let summary = s.summary();
        assert!(summary.contains("prefix: 5 hits / 2 misses"));
        assert!(summary.contains("cow 1 (+1 denied)"));
        // sharing disabled: the prefix line is omitted entirely
        assert!(!SchedSnapshot::default().summary().contains("prefix:"));
    }

    #[test]
    fn sched_snapshot_pjrt_and_alias_fields_surface() {
        let s = SchedSnapshot {
            pjrt_decode_executes: 11,
            pjrt_prefill_executes: 4,
            pjrt_fallback_executes: 2,
            prefill_memo_hits: 3,
            prefill_memo_evictions: 1,
            prefix_enabled: true,
            prefix_alias_hits: 6,
            prefix_alias_bytes: 8192,
            ..SchedSnapshot::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("pjrt_decode_executes").and_then(Json::as_usize), Some(11));
        assert_eq!(j.get("pjrt_prefill_executes").and_then(Json::as_usize), Some(4));
        assert_eq!(j.get("pjrt_fallback_executes").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("prefill_memo_hits").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("prefill_memo_evictions").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("prefix_alias_hits").and_then(Json::as_usize), Some(6));
        assert_eq!(j.get("prefix_alias_bytes").and_then(Json::as_usize), Some(8192));
        let summary = s.summary();
        assert!(summary.contains("pjrt: 11 decode executes (2 fallback)"));
        assert!(summary.contains("memo 3 hits / 1 evictions"));
        assert!(summary.contains("alias 6 (8192 B uncopied)"));
        // no executes recorded (fake engines): the pjrt line is omitted
        assert!(!SchedSnapshot::default().summary().contains("pjrt:"));
    }

    /// Satellite: the SLO/goodput fields surface in JSON (round-trip
    /// through the per-class array included) and the summary, and stay
    /// omitted from the summary for an unclassed throughput run.
    #[test]
    fn sched_snapshot_slo_fields_surface() {
        let s = SchedSnapshot {
            sched_policy_goodput: true,
            goodput: 7,
            slo_violations: 3,
            slo_classes: vec![
                SloClassSnap {
                    name: "chat".into(),
                    goodput: 5,
                    violations: 3,
                    ttft_p50: 40,
                    ttft_p99: 210,
                    tpot_p50_milli: 1500,
                    tpot_p99_milli: 2500,
                },
                SloClassSnap { name: "math".into(), goodput: 2, ..SloClassSnap::default() },
            ],
            ..SchedSnapshot::default()
        };
        // per-class counts sum to the global pair by construction here;
        // the scheduler test asserts the live invariant
        let class_total: u64 = s.slo_classes.iter().map(|c| c.goodput + c.violations).sum();
        assert_eq!(class_total, s.goodput + s.slo_violations);
        let j = s.to_json();
        assert_eq!(j.get("sched_policy").and_then(Json::as_str), Some("goodput"));
        assert_eq!(j.get("goodput").and_then(Json::as_usize), Some(7));
        assert_eq!(j.get("slo_violations").and_then(Json::as_usize), Some(3));
        let classes = j.get("slo_classes").and_then(Json::as_arr).expect("classes array");
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].get("name").and_then(Json::as_str), Some("chat"));
        assert_eq!(classes[0].get("ttft_p99").and_then(Json::as_usize), Some(210));
        assert_eq!(classes[1].get("goodput").and_then(Json::as_usize), Some(2));
        let summary = s.summary();
        assert!(summary.contains("slo (goodput policy): goodput 7, violations 3"));
        assert!(summary.contains("chat: 5/8 met"));
        // throughput policy with no classed terminations: line omitted
        assert!(!SchedSnapshot::default().summary().contains("slo ("));
        assert_eq!(
            SchedSnapshot::default().to_json().get("sched_policy").and_then(Json::as_str),
            Some("throughput")
        );
    }

    #[test]
    fn breakdown_pjrt_counters_merge() {
        let mut a = Breakdown {
            pjrt_decode_executes: 3,
            pjrt_prefill_executes: 1,
            pjrt_fallback_executes: 2,
            prefill_memo_hits: 1,
            prefill_memo_evictions: 1,
            ..Default::default()
        };
        let b = Breakdown { pjrt_decode_executes: 4, prefill_memo_hits: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.pjrt_decode_executes, 7);
        assert_eq!(a.pjrt_prefill_executes, 1);
        assert_eq!(a.pjrt_fallback_executes, 2);
        assert_eq!(a.prefill_memo_hits, 3);
        assert_eq!(a.prefill_memo_evictions, 1);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Breakdown { steps: 10, decode_exec_ns: 100, ..Default::default() };
        let b = Breakdown { steps: 5, decode_exec_ns: 50, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.steps, 15);
        assert_eq!(a.decode_exec_ns, 150);
    }
}
