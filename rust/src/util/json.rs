//! Minimal JSON: parse + serialize, sufficient for configs, results files
//! and the line-delimited server protocol.
//!
//! Supports the full JSON grammar except `\uXXXX` surrogate pairs outside
//! the BMP (non-BMP escapes are replaced with U+FFFD).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (JSON has no integer type).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `j.path(&["model", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty-print with 1-space indentation (matches python json.dump indent=1).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..=depth {
                        out.push(' ');
                    }
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..=depth {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/inf
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{}", n);
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if u32::from(c) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", u32::from(c));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error message with byte offset on failure.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap_or("");
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_model_config_style() {
        let v = parse(r#"{"model": {"d_model": 128, "rope_base": 10000.0}}"#).unwrap();
        assert_eq!(v.path(&["model", "d_model"]).unwrap().as_usize(), Some(128));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn serialize_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn pretty_roundtrip() {
        let mut o = Json::obj();
        o.set("xs", Json::from_f64s(&[1.0, 2.5]));
        o.set("name", Json::Str("t".into()));
        let s = o.to_string_pretty();
        assert_eq!(parse(&s).unwrap(), o);
    }
}
