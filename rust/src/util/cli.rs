//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Used by the `thinkv` binary, examples, and bench harnesses.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of usize, e.g. `--budgets 64,256,1024`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(s) => s
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["--x", "3", "--y=4", "pos1", "--flag"]);
        assert_eq!(a.usize_or("x", 0), 3);
        assert_eq!(a.usize_or("y", 0), 4);
        assert!(a.bool("flag"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("missing", 9), 9);
        assert_eq!(a.str_or("m", "d"), "d");
        assert!(!a.bool("m"));
    }

    #[test]
    fn lists() {
        let a = parse(&["--budgets", "64,128, 256"]);
        assert_eq!(a.usize_list("budgets", &[]), vec![64, 128, 256]);
        assert_eq!(a.usize_list("other", &[1]), vec![1]);
    }
}
