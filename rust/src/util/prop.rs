//! Mini property-testing harness (proptest is not in the offline vendor
//! set). Deterministic, seeded case generation with failure reporting and
//! a simple shrink-by-halving strategy for numeric parameters.
//!
//! Usage:
//! ```ignore
//! prop::check(100, |g| {
//!     let n = g.usize(1, 64);
//!     let v = g.vec_f32(n, -10.0, 10.0);
//!     // ... assert invariant, or return Err(msg)
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to properties.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64) as usize
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(f64::from(lo), f64::from(hi)) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_normal_f32(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n)
            .map(|_| self.rng.normal_with(mean as f64, std as f64) as f32)
            .collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.usize(lo, hi)).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`; panics with the failing seed/case on
/// the first property violation so the failure is reproducible.
pub fn check<F>(cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_seeded(0xC0FFEE, cases, prop)
}

pub fn check_seeded<F>(seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let mut g = Gen {
            rng: Rng::new(case_seed),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            // one retry with a fresh generator to produce a clean repro line
            panic!(
                "property failed (seed={seed:#x}, case={case}, case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |g| {
            let a = g.usize(0, 100);
            let b = g.usize(0, 100);
            if a + b >= a {
                Ok(())
            } else {
                Err("arith".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        check(50, |g| {
            if g.usize(0, 10) < 10 {
                Ok(())
            } else {
                Err("hit ten".into())
            }
        });
    }

    #[test]
    fn deterministic_across_runs() {
        use std::cell::RefCell;
        let seen = RefCell::new(Vec::new());
        check_seeded(42, 5, |g| {
            seen.borrow_mut().push(g.usize(0, 1_000_000));
            Ok(())
        });
        let seen2 = RefCell::new(Vec::new());
        check_seeded(42, 5, |g| {
            seen2.borrow_mut().push(g.usize(0, 1_000_000));
            Ok(())
        });
        assert_eq!(seen.into_inner(), seen2.into_inner());
    }
}
