//! Streaming statistics, percentiles, and small numeric helpers shared by
//! metrics, benchmarking and the KDE calibration code.

/// Online mean/variance (Welford) + min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Running {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let new_mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = new_mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the top-k values, descending (stable for equal values).
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx.truncate(k);
    idx
}

/// Numerically-stable softmax.
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = xs.iter().map(|x| (x - m).exp()).collect();
    let z: f32 = e.iter().sum();
    e.iter().map(|x| x / z.max(1e-30)).collect()
}

/// KL divergence between two distributions (natural log, eps-smoothed).
pub fn kl_div(p: &[f64], q: &[f64]) -> f64 {
    let eps = 1e-12;
    p.iter()
        .zip(q)
        .map(|(&pi, &qi)| {
            let pi = pi.max(eps);
            let qi = qi.max(eps);
            pi * (pi / qi).ln()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut r = Running::new();
        for x in xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 16.0);
    }

    #[test]
    fn running_merge() {
        let xs: Vec<f64> = (0..100).map(|i| f64::from(i).sin()).collect();
        let mut a = Running::new();
        let mut b = Running::new();
        for (i, x) in xs.iter().enumerate() {
            if i < 37 {
                a.push(*x)
            } else {
                b.push(*x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - mean(&xs)).abs() < 1e-12);
        assert!((a.std() - stddev(&xs)).abs() < 1e-9);
    }

    #[test]
    fn percentile_basics() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn kl_zero_for_equal() {
        let p = [0.2, 0.3, 0.5];
        assert!(kl_div(&p, &p).abs() < 1e-9);
        assert!(kl_div(&p, &[0.5, 0.3, 0.2]) > 0.0);
    }

    #[test]
    fn top_k_order() {
        assert_eq!(top_k(&[0.1, 0.9, 0.5], 2), vec![1, 2]);
    }

    #[test]
    fn argmax_first_tie() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
