//! Deterministic PRNG (PCG64-DXSM-style) + sampling helpers.
//!
//! Used everywhere randomness is needed (workload generation, k-means init,
//! simulators, property tests) so that every experiment is reproducible
//! from a seed recorded in its results JSON.

/// A 128-bit-state PCG with DXSM output, seeded deterministically.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut r = Rng {
            state: u128::from(seed).wrapping_mul(0x9e3779b97f4a7c15) ^ 0x853c49e6748fea9b2c0,
            inc: (u128::from(seed) << 1) | 1,
        };
        for _ in 0..4 {
            r.next_u64();
        }
        r
    }

    /// Derive an independent stream (for per-request / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xa0761d6478bd642f))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(MUL as u64);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Geometric-ish segment length in [lo, hi] with given mean (clamped).
    pub fn seg_len(&mut self, mean: f64, lo: usize, hi: usize) -> usize {
        let x = -mean * self.f64().max(1e-12).ln();
        (x.round() as usize).clamp(lo, hi)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// n samples without replacement from 0..pop.
    pub fn choose(&mut self, pop: usize, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..pop).collect();
        self.shuffle(&mut idx);
        idx.truncate(n.min(pop));
        idx
    }

    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_with(f64::from(mean), f64::from(std)) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[0.1, 0.1, 0.8])] += 1;
        }
        assert!(counts[2] > counts[0] + counts[1]);
    }

    #[test]
    fn choose_unique() {
        let mut r = Rng::new(9);
        let picks = r.choose(50, 10);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }
}
