//! Infrastructure substrates built in-repo.
//!
//! The offline vendor set has no serde/tokio/clap/criterion/proptest/rand,
//! so the pieces of those we need are implemented here (DESIGN §1):
//! a JSON parser/encoder, a PCG64 RNG, a CLI argument parser, a scoped
//! thread pool, streaming statistics, and a mini property-testing harness.

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
