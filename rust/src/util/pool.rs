//! A small fixed-size thread pool (tokio is not in the offline vendor set).
//!
//! The coordinator uses one pool of decode workers, each owning its own
//! PJRT executables (the `xla` handles are not Sync). Work items are
//! boxed closures; `scope`-style joining is provided by `run_batch`.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("thinkv-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool alive");
    }

    /// Run `jobs` across the pool and collect results in input order.
    pub fn run_batch<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (tx, rx) = mpsc::channel();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.submit(move || {
                let out = job();
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, out) = rx.recv().expect("worker result");
            slots[i] = Some(out);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn batch_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..20usize).map(|i| Box::new(move || i * i) as _).collect();
        let out = pool.run_batch(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn nested_submissions_do_not_deadlock() {
        let pool = Arc::new(ThreadPool::new(2));
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..8usize).map(|i| Box::new(move || i + 1) as _).collect();
        let out = pool.run_batch(jobs);
        assert_eq!(out.iter().sum::<usize>(), 36);
    }
}
