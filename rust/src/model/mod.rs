//! Model configuration plumbing: dims and artifact manifest, parsed from
//! `artifacts/model_config.json` (written once by `python/compile/aot.py`).

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

/// Transformer dimensions, mirrored from python `compile.model.ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub rope_base: f64,
    pub buf_slots: usize,
    pub prefill_len: usize,
    pub obs_window: usize,
    pub group_size: usize,
}

impl ModelConfig {
    pub fn groups(&self) -> usize {
        self.d_head / self.group_size
    }

    /// KV bytes per token per layer at full (f16-equivalent, as the paper's
    /// FullKV baselines use fp16) precision: 2 (K and V) * Hkv * Dh * 2 B.
    pub fn fullkv_bytes_per_token_layer(&self) -> f64 {
        2.0 * self.n_kv_heads as f64 * self.d_head as f64 * 2.0
    }

    pub fn kv_elems_per_token_layer(&self) -> usize {
        2 * self.n_kv_heads * self.d_head
    }
}

/// The artifact manifest: which HLO files exist and at which capacities.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelConfig,
    pub quant_caps: Vec<usize>,
    pub fp32_caps: Vec<usize>,
    /// Compiled fused-decode batch widths (ascending, e.g. `[1, 2, 4, 8]`):
    /// each `(capacity, width)` pair of both families has a
    /// `decode_*_cC_bB` artifact. Empty for pre-batched artifact sets —
    /// the engine then falls back to per-member executes.
    pub batch_widths: Vec<usize>,
    /// Compiled chunked-prefill chunk lengths (ascending, e.g.
    /// `[8, 16, 32]`): each has a `prefill_chunk_pP_nN` artifact. Empty
    /// for pre-chunked artifact sets — the engine then falls back to
    /// slicing the whole-prompt prefill.
    pub prefill_chunk_lens: Vec<usize>,
    pub micro_c: usize,
    pub golden_attn_c: usize,
    pub artifacts_dir: String,
    pub weights: Vec<(String, Vec<usize>)>,
    pub seed: u64,
}

impl Manifest {
    pub fn load(artifacts_dir: &str) -> Result<Manifest> {
        let path = format!("{artifacts_dir}/model_config.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        let m = j.get("model").context("missing model")?;
        let u = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("missing model.{k}"))
        };
        let model = ModelConfig {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            d_head: u("d_head")?,
            d_ffn: u("d_ffn")?,
            rope_base: m.get("rope_base").and_then(Json::as_f64).unwrap_or(10000.0),
            buf_slots: u("buf_slots")?,
            prefill_len: u("prefill_len")?,
            obs_window: u("obs_window")?,
            group_size: u("group_size")?,
        };
        let caps = |k: &str| -> Vec<usize> {
            j.path(&["capacities", k])
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default()
        };
        let weights = j
            .get("weights")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|w| {
                        let name = w.get("name")?.as_str()?.to_string();
                        let shape = w
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect();
                        Some((name, shape))
                    })
                    .collect()
            })
            .unwrap_or_default();
        let list = |k: &str| -> Vec<usize> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default()
        };
        Ok(Manifest {
            model,
            quant_caps: caps("quant"),
            fp32_caps: caps("fp32"),
            batch_widths: list("batch_widths"),
            prefill_chunk_lens: list("prefill_chunk_lens"),
            micro_c: j.get("micro_c").and_then(Json::as_usize).unwrap_or(1024),
            golden_attn_c: j
                .get("golden_attn_c")
                .and_then(Json::as_usize)
                .unwrap_or(128),
            artifacts_dir: artifacts_dir.to_string(),
            weights,
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        })
    }

    pub fn hlo_path(&self, name: &str) -> String {
        format!("{}/{}.hlo.txt", self.artifacts_dir, name)
    }

    pub fn decode_quant_name(&self, capacity: usize) -> String {
        format!("decode_quant_c{capacity}")
    }

    pub fn decode_fp32_name(&self, capacity: usize) -> String {
        format!("decode_fp32_c{capacity}")
    }

    pub fn prefill_name(&self) -> String {
        format!("prefill_p{}", self.model.prefill_len)
    }

    /// Fused multi-request decode artifact (quant family) at compiled
    /// batch width `b`.
    pub fn decode_quant_batch_name(&self, capacity: usize, b: usize) -> String {
        format!("decode_quant_c{capacity}_b{b}")
    }

    /// Fused multi-request decode artifact (f32 family) at compiled
    /// batch width `b`.
    pub fn decode_fp32_batch_name(&self, capacity: usize, b: usize) -> String {
        format!("decode_fp32_c{capacity}_b{b}")
    }

    /// Chunked-prefill artifact computing `n` prompt positions per
    /// execute at a runtime start offset.
    pub fn prefill_chunk_name(&self, n: usize) -> String {
        format!("prefill_chunk_p{}_n{n}", self.model.prefill_len)
    }

    /// Smallest compiled fused-decode width that covers a batch of `n`
    /// members (the padding mask absorbs the slack). `None` when no
    /// batched artifacts exist or even the widest cannot cover `n` —
    /// callers then split greedily via [`Manifest::widest_batch_width`].
    pub fn pick_batch_width(&self, n: usize) -> Option<usize> {
        self.batch_widths.iter().copied().find(|&b| b >= n)
    }

    /// Widest compiled fused-decode width `<= n` (greedy split step for
    /// batches wider than the widest artifact).
    pub fn widest_batch_width(&self, n: usize) -> Option<usize> {
        self.batch_widths.iter().copied().filter(|&b| b <= n).max()
    }

    /// Smallest exported quant capacity that can hold `budget` + headroom.
    pub fn pick_quant_cap(&self, budget: usize) -> Option<usize> {
        self.quant_caps.iter().copied().find(|&c| c >= budget)
    }

    pub fn pick_fp32_cap(&self, need: usize) -> Option<usize> {
        self.fp32_caps.iter().copied().find(|&c| c >= need)
    }
}

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> String {
    let via_env = std::env::var("THINKV_ARTIFACTS").ok();
    via_env.unwrap_or_else(|| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_loads_if_built() {
        let dir = default_artifacts_dir();
        if !std::path::Path::new(&format!("{dir}/model_config.json")).exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_head % m.model.group_size, 0);
        assert_eq!(m.model.buf_slots, m.model.group_size);
        assert!(!m.quant_caps.is_empty());
        assert!(!m.weights.is_empty());
        assert_eq!(m.weights[0].0, "embed");
        // every advertised artifact exists on disk
        for c in &m.quant_caps {
            assert!(std::path::Path::new(&m.hlo_path(&m.decode_quant_name(*c))).exists());
            for b in &m.batch_widths {
                let name = m.decode_quant_batch_name(*c, *b);
                assert!(std::path::Path::new(&m.hlo_path(&name)).exists(), "{name}");
            }
        }
        for c in &m.fp32_caps {
            for b in &m.batch_widths {
                let name = m.decode_fp32_batch_name(*c, *b);
                assert!(std::path::Path::new(&m.hlo_path(&name)).exists(), "{name}");
            }
        }
        for n in &m.prefill_chunk_lens {
            let name = m.prefill_chunk_name(*n);
            assert!(std::path::Path::new(&m.hlo_path(&name)).exists(), "{name}");
        }
    }

    #[test]
    fn pick_caps() {
        let m = ModelConfig {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            d_head: 32,
            d_ffn: 256,
            rope_base: 10000.0,
            buf_slots: 16,
            prefill_len: 64,
            obs_window: 8,
            group_size: 16,
        };
        let man = Manifest {
            model: m,
            quant_caps: vec![512, 1024, 2048],
            fp32_caps: vec![1024, 4096],
            batch_widths: vec![1, 2, 4, 8],
            prefill_chunk_lens: vec![8, 16, 32],
            micro_c: 1024,
            golden_attn_c: 128,
            artifacts_dir: ".".into(),
            weights: vec![],
            seed: 0,
        };
        assert_eq!(man.pick_quant_cap(600), Some(1024));
        assert_eq!(man.pick_quant_cap(64), Some(512));
        assert_eq!(man.pick_quant_cap(4096), None);
        assert_eq!(man.pick_fp32_cap(2000), Some(4096));
        assert_eq!(man.pick_batch_width(1), Some(1));
        assert_eq!(man.pick_batch_width(3), Some(4));
        assert_eq!(man.pick_batch_width(8), Some(8));
        assert_eq!(man.pick_batch_width(9), None);
        assert_eq!(man.widest_batch_width(9), Some(8));
        assert_eq!(man.widest_batch_width(3), Some(2));
        assert_eq!(man.widest_batch_width(0), None);
        assert_eq!(man.decode_quant_batch_name(512, 4), "decode_quant_c512_b4");
        assert_eq!(man.prefill_chunk_name(16), "prefill_chunk_p64_n16");
    }
}
