//! Golden-vector cross-check: the Rust quantizer must be bit-identical to
//! the Python reference that the Pallas kernel was validated against.
//!
//! `artifacts/quant_golden.bin` (TKVG) layout — see aot.py:
//!   magic "TKVG", u32 version, ntags, n, d, g
//!   per tag in (0,1,2): x f32[n*d], codes u8[n*d], scales f32[n*d/g],
//!                       deq f32[n*d]

use anyhow::{bail, Context, Result};

use crate::quant::formats::{dequant_groups, quant_groups, Precision};

pub struct GoldenCase {
    pub tag: Precision,
    pub x: Vec<f32>,
    pub codes: Vec<u8>,
    pub scales: Vec<f32>,
    pub deq: Vec<f32>,
    pub n: usize,
    pub d: usize,
}

pub fn load_golden(path: &str) -> Result<Vec<GoldenCase>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    let mut off = 0usize;
    let magic = &bytes[..4];
    if magic != b"TKVG" {
        bail!("bad magic in {path}");
    }
    off += 4;
    let mut u32_at = |o: &mut usize| -> u32 {
        let v = u32::from_le_bytes(bytes[*o..*o + 4].try_into().unwrap());
        *o += 4;
        v
    };
    let version = u32_at(&mut off);
    if version != 1 {
        bail!("unsupported golden version {version}");
    }
    let ntags = u32_at(&mut off) as usize;
    let n = u32_at(&mut off) as usize;
    let d = u32_at(&mut off) as usize;
    let g = u32_at(&mut off) as usize;
    if g != super::GROUP_SIZE {
        bail!("golden group size {g} != {}", super::GROUP_SIZE);
    }
    let mut cases = Vec::new();
    for tag in 0..ntags as u8 {
        let read_f32 = |off: &mut usize, count: usize| -> Vec<f32> {
            let out = bytes[*off..*off + 4 * count]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            *off += 4 * count;
            out
        };
        let x = read_f32(&mut off, n * d);
        let codes = bytes[off..off + n * d].to_vec();
        off += n * d;
        let scales = read_f32(&mut off, n * d / g);
        let deq = read_f32(&mut off, n * d);
        cases.push(GoldenCase {
            tag: Precision::from_tag(tag),
            x,
            codes,
            scales,
            deq,
            n,
            d,
        });
    }
    Ok(cases)
}

/// Verify the Rust encoder/decoder against every golden case.
/// Returns the number of rows checked; errors on any mismatch.
pub fn verify_golden(path: &str) -> Result<usize> {
    let cases = load_golden(path)?;
    let mut rows = 0;
    for case in &cases {
        let (n, d) = (case.n, case.d);
        let gcount = d / super::GROUP_SIZE;
        for r in 0..n {
            let x = &case.x[r * d..(r + 1) * d];
            let mut codes = vec![0u8; d];
            let mut scales = vec![0f32; gcount];
            quant_groups(x, case.tag, &mut codes, &mut scales);
            if codes != case.codes[r * d..(r + 1) * d] {
                bail!("codes mismatch tag={:?} row={r}", case.tag);
            }
            let want_scales = &case.scales[r * gcount..(r + 1) * gcount];
            if scales != want_scales {
                bail!("scales mismatch tag={:?} row={r}", case.tag);
            }
            let mut deq = vec![0f32; d];
            dequant_groups(&codes, &scales, case.tag, &mut deq);
            let want_deq = &case.deq[r * d..(r + 1) * d];
            for (a, b) in deq.iter().zip(want_deq) {
                if (a - b).abs() > 1e-6 {
                    bail!("dequant mismatch tag={:?} row={r}: {a} vs {b}", case.tag);
                }
            }
            rows += 1;
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn golden_path() -> Option<String> {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/quant_golden.bin");
        std::path::Path::new(p).exists().then(|| p.to_string())
    }

    #[test]
    fn rust_quantizer_is_bit_exact_vs_python() {
        let Some(path) = golden_path() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let rows = verify_golden(&path).expect("golden verification");
        assert_eq!(rows, 24); // 3 tags x 8 rows
    }
}
