//! Rust mirror of the L1 quantization formats (python/compile/formats.py).
//!
//! The cache-write path runs in Rust: after each decode step the coordinator
//! group-quantizes the new K/V vectors according to the active thought type
//! (TBQ, §4.2) and writes the codes into CT-chosen slots. The dequantization
//! happens inside the fused Pallas kernel, so encoder (here) and decoder
//! (kernel tables) must agree **bit-for-bit** — cross-checked against
//! `artifacts/quant_golden.bin` emitted from the Python reference.

pub mod formats;
pub mod golden;

pub use formats::{
    dequant_groups, e4m3_encode, e4m3_snap, e4m3_table, packed_bits_per_elem, quant_groups,
    Precision, FP8_MAX, GROUP_SIZE, NVFP4_MAG, NVFP4_MAX,
};
