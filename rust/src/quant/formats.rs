//! FP8-E4M3 / NVFP4 / Ternary group quantization (paper §4.2, §D.3).
//!
//! Semantics are defined by `python/compile/formats.py` +
//! `python/compile/kernels/ref.py`; this module reproduces them exactly
//! (same tables, same nearest-with-tie-to-smaller rounding, same E4M3
//! scale snapping). `quant::golden` asserts bit-equality at test time.

use std::sync::OnceLock;

pub const GROUP_SIZE: usize = 16;
pub const FP8_MAX: f32 = 448.0;
pub const NVFP4_MAX: f32 = 6.0;
/// NVFP4 (E2M1) magnitudes; code = sign*8 + index.
pub const NVFP4_MAG: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Cache-entry precision (the TBQ tag stored per slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Precision {
    /// 2-bit ternary {-1, 0, +1}, g=16 group scale (transition thoughts).
    Ternary = 0,
    /// 4-bit NVFP4 E2M1, g=16 group scale (reasoning/execution thoughts).
    Nvfp4 = 1,
    /// 8-bit FP8 E4M3, per-entry scale (highest precision).
    Fp8 = 2,
}

impl Precision {
    pub fn tag(self) -> u8 {
        self as u8
    }

    pub fn from_tag(t: u8) -> Precision {
        match t {
            0 => Precision::Ternary,
            1 => Precision::Nvfp4,
            2 => Precision::Fp8,
            _ => panic!("bad precision tag {t}"),
        }
    }

    /// Nominal element bits of the format (storage accounting, DESIGN §4).
    pub fn bits(self) -> f64 {
        match self {
            Precision::Ternary => 2.0,
            Precision::Nvfp4 => 4.0,
            Precision::Fp8 => 8.0,
        }
    }

    /// Bits for a quantization level `b` in the paper's B = {2,4,8}.
    pub fn from_bits(b: usize) -> Precision {
        match b {
            2 => Precision::Ternary,
            4 => Precision::Nvfp4,
            8 => Precision::Fp8,
            _ => panic!("unsupported bit width {b}"),
        }
    }
}

/// Packed element bits including group-scale overhead (8-bit E4M3 scale per
/// g=16 group for ternary/NVFP4, per-entry f32 scale amortized for FP8).
/// Ternary is packed two-per-nibble into 4-bit lanes per §6.1 — but its
/// *storage* accounting stays 2 bits + scale as the paper reports averages.
pub fn packed_bits_per_elem(p: Precision) -> f64 {
    match p {
        Precision::Ternary => 2.0 + 8.0 / GROUP_SIZE as f64,
        Precision::Nvfp4 => 4.0 + 8.0 / GROUP_SIZE as f64,
        Precision::Fp8 => 8.0 + 32.0 / 64.0, // f32 scale over a d_head=64-ish entry
    }
}

struct Tables {
    decode: [f32; 256],
    pos_vals: Vec<f32>,
    pos_codes: Vec<u8>,
}

fn tables() -> &'static Tables {
    static T: OnceLock<Tables> = OnceLock::new();
    T.get_or_init(|| {
        let mut decode = [0f32; 256];
        for code in 0..256usize {
            let s = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
            let e = (code >> 3) & 0xF;
            let m = code & 0x7;
            let val = if e == 0xF && m == 0x7 {
                0.0 // NaN slot (never emitted by the encoder)
            } else if e == 0 {
                (m as f32 / 8.0) * (2.0f32).powi(-6)
            } else {
                (1.0 + m as f32 / 8.0) * (2.0f32).powi(e as i32 - 7)
            };
            decode[code] = s * val;
        }
        let mut pos: Vec<(f32, u8)> = (0..0x80u16)
            .filter(|&c| !((c >> 3) == 0xF && (c & 7) == 7))
            .map(|c| (decode[usize::from(c)], c as u8))
            .collect();
        pos.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        Tables {
            decode,
            pos_vals: pos.iter().map(|p| p.0).collect(),
            pos_codes: pos.iter().map(|p| p.1).collect(),
        }
    })
}

/// The 256-entry E4M3 decode table (same values the Pallas kernel uses).
pub fn e4m3_table() -> &'static [f32; 256] {
    &tables().decode
}

/// Nearest-value E4M3 encode; ties toward the smaller magnitude.
/// Matches `formats.e4m3_encode` (which uses `np.signbit`, so -0.0 keeps
/// its sign bit).
pub fn e4m3_encode(x: f32) -> u8 {
    let t = tables();
    let mag = x.abs().min(FP8_MAX);
    // binary search for insertion point (== np.searchsorted side='left')
    let idx = t.pos_vals.partition_point(|&v| v < mag);
    let idx = idx.clamp(1, t.pos_vals.len() - 1);
    let (lo, hi) = (t.pos_vals[idx - 1], t.pos_vals[idx]);
    let pick = if (mag - lo) > (hi - mag) { idx } else { idx - 1 };
    let code = t.pos_codes[pick];
    if x.is_sign_negative() {
        code | 0x80
    } else {
        code
    }
}

pub fn e4m3_decode(code: u8) -> f32 {
    tables().decode[usize::from(code)]
}

/// Snap onto the E4M3 grid: decode(encode(x)).
pub fn e4m3_snap(x: f32) -> f32 {
    e4m3_decode(e4m3_encode(x))
}

fn nvfp4_encode_one(t: f32) -> u8 {
    let mag = t.abs();
    let mut best = 0usize;
    let mut bd = f32::INFINITY;
    for (i, &v) in NVFP4_MAG.iter().enumerate() {
        let d = (mag - v).abs();
        if d < bd {
            bd = d;
            best = i;
        }
    }
    let sign = if t < 0.0 { 8u8 } else { 0 };
    sign + best as u8
}

fn nvfp4_decode_one(code: u8) -> f32 {
    let mag = NVFP4_MAG[(code & 7) as usize];
    if code & 8 != 0 {
        -mag
    } else {
        mag
    }
}

fn ternary_encode_one(t: f32) -> u8 {
    if t > 0.5 {
        1
    } else if t < -0.5 {
        2
    } else {
        0
    }
}

fn ternary_decode_one(code: u8) -> f32 {
    match code {
        1 => 1.0,
        2 => -1.0,
        _ => 0.0,
    }
}

/// Group-quantize `x` (length D, D % 16 == 0) at precision `p`.
/// Writes codes (len D) and scales (len D/16). Mirrors
/// `ref.quant_groups_ref` exactly.
pub fn quant_groups(x: &[f32], p: Precision, codes: &mut [u8], scales: &mut [f32]) {
    let d = x.len();
    let g = GROUP_SIZE;
    assert_eq!(d % g, 0);
    assert_eq!(codes.len(), d);
    assert_eq!(scales.len(), d / g);
    match p {
        Precision::Fp8 => {
            let amax = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
            let mut scale = e4m3_snap(amax / FP8_MAX);
            if scale <= 0.0 {
                scale = 1.0;
            }
            for (c, &v) in codes.iter_mut().zip(x) {
                *c = e4m3_encode(v / scale);
            }
            scales.fill(scale);
        }
        Precision::Nvfp4 => {
            for gi in 0..d / g {
                let xs = &x[gi * g..(gi + 1) * g];
                let amax = xs.iter().fold(0f32, |a, &v| a.max(v.abs()));
                let mut scale = e4m3_snap(amax / NVFP4_MAX);
                if scale <= 0.0 {
                    scale = 1.0;
                }
                for (j, &v) in xs.iter().enumerate() {
                    codes[gi * g + j] = nvfp4_encode_one(v / scale);
                }
                scales[gi] = scale;
            }
        }
        Precision::Ternary => {
            for gi in 0..d / g {
                let xs = &x[gi * g..(gi + 1) * g];
                let amean = xs.iter().map(|v| v.abs()).sum::<f32>() / g as f32;
                let mut scale = e4m3_snap(amean);
                if scale <= 0.0 {
                    scale = 1.0;
                }
                for (j, &v) in xs.iter().enumerate() {
                    codes[gi * g + j] = ternary_encode_one(v / scale);
                }
                scales[gi] = scale;
            }
        }
    }
}

/// Inverse of `quant_groups` (same tables the kernel applies in-HLO).
pub fn dequant_groups(codes: &[u8], scales: &[f32], p: Precision, out: &mut [f32]) {
    let d = codes.len();
    let g = GROUP_SIZE;
    assert_eq!(scales.len(), d / g);
    assert_eq!(out.len(), d);
    for gi in 0..d / g {
        let s = scales[gi];
        for j in 0..g {
            let c = codes[gi * g + j];
            out[gi * g + j] = s * match p {
                Precision::Fp8 => e4m3_decode(c),
                Precision::Nvfp4 => nvfp4_decode_one(c),
                Precision::Ternary => ternary_decode_one(c),
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn table_extremes() {
        assert_eq!(e4m3_decode(0x7E), 448.0);
        assert!((e4m3_decode(0x01) - 2f32.powi(-9)).abs() < 1e-12);
        assert_eq!(e4m3_decode(0x00), 0.0);
        assert_eq!(e4m3_decode(0xFE), -448.0);
    }

    #[test]
    fn table_sign_symmetry() {
        for c in 0..0x80u8 {
            if (c >> 3) == 0xF && (c & 7) == 7 {
                continue;
            }
            assert_eq!(e4m3_decode(c), -e4m3_decode(c | 0x80));
        }
    }

    #[test]
    fn encode_roundtrips_grid_values() {
        for c in 0..=0x7Eu8 {
            if (c >> 3) == 0xF && (c & 7) == 7 {
                continue;
            }
            let v = e4m3_decode(c);
            if v == 0.0 {
                continue;
            }
            assert_eq!(e4m3_decode(e4m3_encode(v)), v, "code {c:#x}");
        }
    }

    #[test]
    fn encode_clips() {
        assert_eq!(e4m3_decode(e4m3_encode(1e9)).abs(), 448.0);
        assert_eq!(e4m3_decode(e4m3_encode(-1e9)).abs(), 448.0);
    }

    #[test]
    fn encode_is_nearest_property() {
        prop::check(300, |g| {
            let x = g.f32(-500.0, 500.0);
            let got = e4m3_decode(e4m3_encode(x)).abs();
            let mag = x.abs().min(FP8_MAX);
            // nearest positive grid value
            let t = e4m3_table();
            let best = (0..0x7Fu8)
                .filter(|&c| !((c >> 3) == 0xF && (c & 7) == 7))
                .map(|c| t[usize::from(c)])
                .fold((f32::INFINITY, 0.0f32), |(bd, bv), v| {
                    let d = (v - mag).abs();
                    if d < bd {
                        (d, v)
                    } else {
                        (bd, bv)
                    }
                })
                .1;
            if (got - best).abs() <= 1e-7 {
                Ok(())
            } else {
                Err(format!("x={x} got={got} best={best}"))
            }
        });
    }

    #[test]
    fn quant_error_hierarchy() {
        let mut rng = crate::util::rng::Rng::new(11);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let mut errs = Vec::new();
        for p in [Precision::Fp8, Precision::Nvfp4, Precision::Ternary] {
            let mut codes = vec![0u8; x.len()];
            let mut scales = vec![0f32; x.len() / GROUP_SIZE];
            let mut deq = vec![0f32; x.len()];
            quant_groups(&x, p, &mut codes, &mut scales);
            dequant_groups(&codes, &scales, p, &mut deq);
            let err: f32 = x.iter().zip(&deq).map(|(a, b)| (a - b).abs()).sum::<f32>()
                / x.len() as f32;
            errs.push(err);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }

    #[test]
    fn zero_vector_roundtrip() {
        for p in [Precision::Fp8, Precision::Nvfp4, Precision::Ternary] {
            let x = [0f32; 32];
            let mut codes = [0u8; 32];
            let mut scales = [0f32; 2];
            let mut deq = [1f32; 32];
            quant_groups(&x, p, &mut codes, &mut scales);
            dequant_groups(&codes, &scales, p, &mut deq);
            assert_eq!(deq, [0f32; 32]);
        }
    }

    #[test]
    fn ternary_codes_limited() {
        prop::check(50, |g| {
            let x = g.vec_normal_f32(64, 0.0, 2.0);
            let mut codes = vec![0u8; 64];
            let mut scales = vec![0f32; 4];
            quant_groups(&x, Precision::Ternary, &mut codes, &mut scales);
            if codes.iter().all(|&c| c <= 2) {
                Ok(())
            } else {
                Err("code out of range".into())
            }
        });
    }

    #[test]
    fn nvfp4_roundtrip_error_scales_with_groupmax() {
        prop::check(50, |g| {
            let scale = g.f32(0.01, 50.0);
            let x: Vec<f32> = g.vec_normal_f32(64, 0.0, scale);
            let mut codes = vec![0u8; 64];
            let mut scales = vec![0f32; 4];
            let mut deq = vec![0f32; 64];
            quant_groups(&x, Precision::Nvfp4, &mut codes, &mut scales);
            dequant_groups(&codes, &scales, Precision::Nvfp4, &mut deq);
            let max_err = x
                .iter()
                .zip(&deq)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            let amax = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
            // worst-case NVFP4 step is 2.0 at the top of the range (4->6),
            // scaled by groupmax/6 with E4M3 snap slack.
            if max_err <= amax * (2.0 / 6.0) * 1.1 + 1e-5 {
                Ok(())
            } else {
                Err(format!("max_err={max_err} amax={amax}"))
            }
        });
    }

    #[test]
    fn precision_tags_roundtrip() {
        for p in [Precision::Ternary, Precision::Nvfp4, Precision::Fp8] {
            assert_eq!(Precision::from_tag(p.tag()), p);
        }
        assert_eq!(Precision::from_bits(2), Precision::Ternary);
        assert_eq!(Precision::from_bits(4), Precision::Nvfp4);
        assert_eq!(Precision::from_bits(8), Precision::Fp8);
    }

    #[test]
    fn packed_accounting_ordering() {
        assert!(packed_bits_per_elem(Precision::Ternary) < packed_bits_per_elem(Precision::Nvfp4));
        assert!(packed_bits_per_elem(Precision::Nvfp4) < packed_bits_per_elem(Precision::Fp8));
    }
}
