//! Counterfactual accuracy oracle (DESIGN §1 substitution for pass@1 on
//! AIME / LiveCodeBench / MATH-500).
//!
//! The oracle scores a compression policy by *what information survived*:
//! each segment's retained info mass (token info weights × precision
//! fidelity), weighted by the segment's counterfactual importance (Obs 2),
//! with two failure modes the paper documents:
//!
//! * **Anchor loss** (§E.17, Fig 11a): if a backtracking transition anchor
//!   ever drops to zero retained tokens, the model loops endlessly —
//!   generation runs to the cap and the answer is wrong.
//! * **Quantization length inflation** (Fig 2, Fig 10d): noise on
//!   reasoning-critical tokens inflates generation length (up to ~5× at
//!   2-bit uniform), eroding memory savings and slightly hurting accuracy.

use crate::baselines::{PosAttn, RetentionEvent, RetentionTrace};
use crate::quant::Precision;
use crate::util::rng::Rng;

use super::trace::Trace;

/// Fidelity of a stored token by precision (1.0 = lossless fp16 reference).
pub fn fidelity(p: Option<Precision>) -> f64 {
    match p {
        None => 1.0, // fp16/fp32 (FullKV / eviction-only baselines)
        Some(Precision::Fp8) => 0.995,
        Some(Precision::Nvfp4) => 0.98,
        Some(Precision::Ternary) => 0.80,
    }
}

/// INT4/INT2 ablation fidelities (Table 10: INT formats lose accuracy).
pub fn fidelity_int(bits: usize) -> f64 {
    match bits {
        8 => 0.99,
        4 => 0.935,
        _ => 0.72,
    }
}

/// What a policy retained of one segment, measured when the segment went
/// stale (3+ transitions old) or at trace end.
#[derive(Debug, Clone)]
pub struct RetentionRecord {
    pub seg: usize,
    /// Σ_{kept j} info_j · fid_j   (∈ [0, 1]).
    pub kept_info_fid: f64,
    /// Minimum retained token count observed over the segment's lifetime.
    pub min_kept_count: usize,
    pub importance: f64,
    pub anchor: bool,
}

/// Oracle tuning (calibrated in tests against the paper's headline shapes).
#[derive(Debug, Clone)]
pub struct Oracle {
    /// Damage multiplier per unit importance-weighted info loss.
    pub damage: f64,
    /// Diminishing-returns exponent on retained info.
    pub beta: f64,
    /// Length-inflation curve: 1 + a · qloss^p.
    pub infl_a: f64,
    pub infl_p: f64,
    /// Probability a rollout loops when an anchor was fully lost.
    pub loop_prob: f64,
    pub rollouts: usize,
}

impl Default for Oracle {
    fn default() -> Self {
        Oracle {
            damage: 0.35,
            beta: 0.25,
            infl_a: 45.0,
            infl_p: 1.55,
            loop_prob: 0.85,
            rollouts: 8,
        }
    }
}

/// Oracle verdict for one (trace, policy) run.
#[derive(Debug, Clone)]
pub struct OracleOut {
    /// pass@1 over `rollouts` samples (mean correctness).
    pub pass1: f64,
    /// Expected correctness probability (before rollout sampling).
    pub p_correct: f64,
    /// Generation-length inflation factor (quantization noise, Fig 10d).
    pub len_inflation: f64,
    /// Fraction of rollouts that entered an endless loop.
    pub looped: f64,
}

impl Oracle {
    /// `records` — one per trace segment; `qloss` — importance-weighted
    /// quantization fidelity deficit over R/E tokens (drives inflation).
    pub fn evaluate(
        &self,
        trace: &Trace,
        records: &[RetentionRecord],
        qloss: f64,
        seed: u64,
    ) -> OracleOut {
        let mut rng = Rng::new(seed ^ 0x04ac1e31);
        // importance-weighted damage
        let mut damage = 0.0;
        let mut wsum = 0.0;
        let mut anchor_lost = false;
        for r in records {
            wsum += r.importance;
            let retained = r.kept_info_fid.clamp(0.0, 1.0).powf(self.beta);
            damage += r.importance * (1.0 - retained);
            if r.anchor && r.min_kept_count == 0 {
                anchor_lost = true;
            }
        }
        let damage = if wsum > 0.0 { damage / wsum } else { 0.0 };
        let len_inflation = 1.0 + self.infl_a * qloss.max(0.0).powf(self.infl_p);
        // Inflated chains wander and run into the generation cap: the
        // dominant accuracy cost of aggressive uniform quantization
        // (Table 1: KIVI 2-bit loses ~13 points on AIME).
        let inflation_penalty = (0.08 * (len_inflation - 1.0)).min(0.5);
        let p = trace.dataset.base_acc * (1.0 - self.damage * damage).max(0.0)
            * (1.0 - inflation_penalty);

        let mut correct = 0usize;
        let mut looped = 0usize;
        for _ in 0..self.rollouts {
            if anchor_lost && rng.chance(self.loop_prob) {
                looped += 1;
                continue; // endless loop: wrong by truncation
            }
            let jitter = (rng.normal() * 0.02).clamp(-0.06, 0.06);
            if rng.chance((p + jitter).clamp(0.0, 1.0)) {
                correct += 1;
            }
        }
        OracleOut {
            pass1: correct as f64 / self.rollouts as f64,
            p_correct: if anchor_lost { p * (1.0 - self.loop_prob) } else { p },
            len_inflation,
            looped: looped as f64 / self.rollouts as f64,
        }
    }
}

/// Outcome of replaying a live backend's retention audit log through a
/// freshly built sim twin of the same policy (the differential half of
/// the policy-arena conformance suite).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayDiff {
    /// Events replayed (observed attention rows, keep/skip verdicts,
    /// eviction selections).
    pub events: usize,
    /// Events where the twin disagreed with the recorded decision.
    pub mismatches: usize,
    /// Fidelity-weighted divergence in `[0, 1]`: the fp32 arena stores
    /// losslessly, so the weight is `fidelity(None)` and the score is
    /// simply the mismatch fraction. `0.0` = the live backend and the
    /// sim twin made bit-identical decisions.
    pub divergence: f64,
    /// Index of the first mismatching event (`None` = exact replay).
    pub first_mismatch: Option<usize>,
}

/// Differential conformance oracle: rebuild `trace.kind` from the
/// [`PolicyKind`](crate::baselines::PolicyKind) registry with the
/// recorded build budget, feed it the recorded observation history, and
/// check every keep / skip / evict decision against what the live
/// backend actually did. Deterministic policies must replay exactly
/// (divergence `0.0`); any drift pinpoints the first divergent event.
pub fn replay_divergence(trace: &RetentionTrace) -> ReplayDiff {
    let mut twin = trace.kind.build(trace.budget);
    let mut mismatches = 0usize;
    let mut first = None;
    for (i, ev) in trace.events.iter().enumerate() {
        let agrees = match ev {
            RetentionEvent::Observe { step, attn } => {
                twin.observe(&PosAttn { step: *step, attn: attn.clone() });
                true
            }
            RetentionEvent::Keep { pos } => !twin.skip_kv(*pos),
            RetentionEvent::Skip { pos } => twin.skip_kv(*pos),
            RetentionEvent::Evict { live, target, evicted } => {
                twin.select_evictions(live, *target) == *evicted
            }
        };
        if !agrees {
            mismatches += 1;
            if first.is_none() {
                first = Some(i);
            }
        }
    }
    let events = trace.events.len();
    ReplayDiff {
        events,
        mismatches,
        divergence: fidelity(None) * mismatches as f64 / events.max(1) as f64,
        first_mismatch: first,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace::DatasetProfile;

    fn full_records(trace: &Trace) -> Vec<RetentionRecord> {
        trace
            .segments
            .iter()
            .map(|s| RetentionRecord {
                seg: s.id,
                kept_info_fid: 1.0,
                min_kept_count: s.len,
                importance: s.importance,
                anchor: s.anchor,
            })
            .collect()
    }

    #[test]
    fn full_retention_matches_base_accuracy() {
        let trace = Trace::generate(&DatasetProfile::aime(), 1, 0.25);
        let o = Oracle { rollouts: 400, ..Oracle::default() };
        let out = o.evaluate(&trace, &full_records(&trace), 0.0, 7);
        assert!((out.pass1 - trace.dataset.base_acc).abs() < 0.08, "{}", out.pass1);
        assert!((out.len_inflation - 1.0).abs() < 1e-9);
        assert_eq!(out.looped, 0.0);
    }

    #[test]
    fn losing_important_segments_hurts_more() {
        let trace = Trace::generate(&DatasetProfile::aime(), 2, 0.25);
        let o = Oracle::default();
        let drop = |pred: &dyn Fn(&crate::sim::trace::TraceSegment) -> bool| {
            let recs: Vec<RetentionRecord> = trace
                .segments
                .iter()
                .map(|s| RetentionRecord {
                    seg: s.id,
                    kept_info_fid: if pred(s) { 0.05 } else { 1.0 },
                    min_kept_count: if pred(s) { 1 } else { s.len },
                    importance: s.importance,
                    anchor: s.anchor,
                })
                .collect();
            o.evaluate(&trace, &recs, 0.0, 3).p_correct
        };
        let lose_r = drop(&|s| s.thought == crate::kvcache::Thought::Reasoning);
        let lose_t =
            drop(&|s| s.thought == crate::kvcache::Thought::Transition && !s.anchor);
        assert!(lose_r < lose_t, "losing R ({lose_r}) must hurt more than non-anchor T ({lose_t})");
    }

    #[test]
    fn anchor_loss_causes_loops() {
        let trace = Trace::generate(&DatasetProfile::aime(), 3, 0.3);
        let Some(anchor) = trace.segments.iter().find(|s| s.anchor) else {
            return; // rare seed without anchors
        };
        let recs: Vec<RetentionRecord> = trace
            .segments
            .iter()
            .map(|s| RetentionRecord {
                seg: s.id,
                kept_info_fid: if s.id == anchor.id { 0.0 } else { 1.0 },
                min_kept_count: if s.id == anchor.id { 0 } else { s.len },
                importance: s.importance,
                anchor: s.anchor,
            })
            .collect();
        let o = Oracle { rollouts: 200, ..Oracle::default() };
        let out = o.evaluate(&trace, &recs, 0.0, 5);
        assert!(out.looped > 0.6, "looped {}", out.looped);
        assert!(out.pass1 < trace.dataset.base_acc * 0.5);
    }

    #[test]
    fn inflation_curve_matches_paper_regimes() {
        let o = Oracle::default();
        // KIVI-2: uniform ternary-level noise on everything important
        let q2 = 1.0 - fidelity(Some(Precision::Ternary)); // 0.2
        let infl2 = 1.0 + o.infl_a * q2.powf(o.infl_p);
        assert!((3.5..7.0).contains(&infl2), "2-bit inflation {infl2} (paper ~5.1x)");
        // KIVI-4
        let q4 = 1.0 - fidelity(Some(Precision::Nvfp4));
        let infl4 = 1.0 + o.infl_a * q4.powf(o.infl_p);
        assert!((1.0..1.6).contains(&infl4), "4-bit inflation {infl4}");
        // ThinKV: only low-importance T tokens at 2 bits -> tiny qloss
        let qthink = 0.27 * 0.12 * q2 + 0.73 * q4; // rough mix
        let inflt = 1.0 + o.infl_a * qthink.powf(o.infl_p);
        assert!(inflt < 1.35, "ThinKV inflation {inflt}");
    }

    #[test]
    fn replay_divergence_zero_on_faithful_trace_and_flags_tampering() {
        use crate::baselines::PolicyKind;
        // a faithful H2O history: the recorded decisions are literally
        // what a fresh twin produces, so replay must be exact
        let mut probe = PolicyKind::H2O.build(8);
        let mut trace = RetentionTrace::new(PolicyKind::H2O, 8);
        let live: Vec<usize> = (0..12).collect();
        for step in 0..6 {
            let attn: Vec<(usize, f32)> =
                live.iter().map(|&p| (p, ((p * 7 + step) % 13) as f32 / 13.0)).collect();
            probe.observe(&PosAttn { step, attn: attn.clone() });
            trace.events.push(RetentionEvent::Observe { step, attn });
            let pos = 12 + step;
            assert!(!probe.skip_kv(pos));
            trace.events.push(RetentionEvent::Keep { pos });
        }
        let evicted = probe.select_evictions(&live, 8);
        trace.events.push(RetentionEvent::Evict { live: live.clone(), target: 8, evicted });
        let d = replay_divergence(&trace);
        assert_eq!(d.mismatches, 0, "faithful trace must replay exactly");
        assert_eq!(d.divergence, 0.0);
        assert_eq!(d.first_mismatch, None);
        assert_eq!(d.events, trace.events.len());

        // tamper with the recorded eviction: the diff localizes it
        let mut bad = trace.clone();
        if let Some(RetentionEvent::Evict { evicted, .. }) = bad.events.last_mut() {
            evicted.clear();
        }
        let d = replay_divergence(&bad);
        assert_eq!(d.mismatches, 1);
        assert_eq!(d.first_mismatch, Some(bad.events.len() - 1));
        assert!(d.divergence > 0.0);
    }

    /// FNV-1a over a canonical byte encoding of the oracle inputs and
    /// outputs — any nondeterminism (map iteration order, uninitialized
    /// float paths) shows up as a digest mismatch between runs.
    fn fnv_digest(records: &[RetentionRecord], out: &OracleOut) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        for r in records {
            eat(&(r.seg as u64).to_le_bytes());
            eat(&r.kept_info_fid.to_bits().to_le_bytes());
            eat(&(r.min_kept_count as u64).to_le_bytes());
            eat(&r.importance.to_bits().to_le_bytes());
            eat(&[u8::from(r.anchor)]);
        }
        eat(&out.pass1.to_bits().to_le_bytes());
        eat(&out.p_correct.to_bits().to_le_bytes());
        eat(&out.len_inflation.to_bits().to_le_bytes());
        eat(&out.looped.to_bits().to_le_bytes());
        h
    }

    /// Satellite golden: `Oracle::evaluate` is a pure function of
    /// (trace, records, qloss, seed). Two fully independent
    /// reconstructions of the same seeded inputs must produce
    /// bit-identical outputs — compared through an FNV-1a digest so any
    /// single-bit drift in any field fails loudly.
    #[test]
    fn oracle_evaluate_is_deterministic_golden() {
        let run = || {
            let trace = Trace::generate(&DatasetProfile::aime(), 41, 0.3);
            let records: Vec<RetentionRecord> = trace
                .segments
                .iter()
                .map(|s| RetentionRecord {
                    seg: s.id,
                    kept_info_fid: if s.id % 3 == 0 { 0.4 } else { 0.9 },
                    min_kept_count: s.len.min(2),
                    importance: s.importance,
                    anchor: s.anchor,
                })
                .collect();
            let out = Oracle::default().evaluate(&trace, &records, 0.01, 99);
            fnv_digest(&records, &out)
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "oracle digest must be reproducible from the seed");
    }

    #[test]
    fn precision_fidelity_ordering() {
        assert!(fidelity(None) > fidelity(Some(Precision::Fp8)));
        assert!(fidelity(Some(Precision::Fp8)) > fidelity(Some(Precision::Nvfp4)));
        assert!(fidelity(Some(Precision::Nvfp4)) > fidelity(Some(Precision::Ternary)));
        // NVFP4 beats INT4, ternary beats INT2 (Table 10)
        assert!(fidelity(Some(Precision::Nvfp4)) > fidelity_int(4));
        assert!(fidelity(Some(Precision::Ternary)) > fidelity_int(2));
    }
}
