//! Counterfactual accuracy oracle (DESIGN §1 substitution for pass@1 on
//! AIME / LiveCodeBench / MATH-500).
//!
//! The oracle scores a compression policy by *what information survived*:
//! each segment's retained info mass (token info weights × precision
//! fidelity), weighted by the segment's counterfactual importance (Obs 2),
//! with two failure modes the paper documents:
//!
//! * **Anchor loss** (§E.17, Fig 11a): if a backtracking transition anchor
//!   ever drops to zero retained tokens, the model loops endlessly —
//!   generation runs to the cap and the answer is wrong.
//! * **Quantization length inflation** (Fig 2, Fig 10d): noise on
//!   reasoning-critical tokens inflates generation length (up to ~5× at
//!   2-bit uniform), eroding memory savings and slightly hurting accuracy.

use crate::quant::Precision;
use crate::util::rng::Rng;

use super::trace::Trace;

/// Fidelity of a stored token by precision (1.0 = lossless fp16 reference).
pub fn fidelity(p: Option<Precision>) -> f64 {
    match p {
        None => 1.0, // fp16/fp32 (FullKV / eviction-only baselines)
        Some(Precision::Fp8) => 0.995,
        Some(Precision::Nvfp4) => 0.98,
        Some(Precision::Ternary) => 0.80,
    }
}

/// INT4/INT2 ablation fidelities (Table 10: INT formats lose accuracy).
pub fn fidelity_int(bits: usize) -> f64 {
    match bits {
        8 => 0.99,
        4 => 0.935,
        _ => 0.72,
    }
}

/// What a policy retained of one segment, measured when the segment went
/// stale (3+ transitions old) or at trace end.
#[derive(Debug, Clone)]
pub struct RetentionRecord {
    pub seg: usize,
    /// Σ_{kept j} info_j · fid_j   (∈ [0, 1]).
    pub kept_info_fid: f64,
    /// Minimum retained token count observed over the segment's lifetime.
    pub min_kept_count: usize,
    pub importance: f64,
    pub anchor: bool,
}

/// Oracle tuning (calibrated in tests against the paper's headline shapes).
#[derive(Debug, Clone)]
pub struct Oracle {
    /// Damage multiplier per unit importance-weighted info loss.
    pub damage: f64,
    /// Diminishing-returns exponent on retained info.
    pub beta: f64,
    /// Length-inflation curve: 1 + a · qloss^p.
    pub infl_a: f64,
    pub infl_p: f64,
    /// Probability a rollout loops when an anchor was fully lost.
    pub loop_prob: f64,
    pub rollouts: usize,
}

impl Default for Oracle {
    fn default() -> Self {
        Oracle {
            damage: 0.35,
            beta: 0.25,
            infl_a: 45.0,
            infl_p: 1.55,
            loop_prob: 0.85,
            rollouts: 8,
        }
    }
}

/// Oracle verdict for one (trace, policy) run.
#[derive(Debug, Clone)]
pub struct OracleOut {
    /// pass@1 over `rollouts` samples (mean correctness).
    pub pass1: f64,
    /// Expected correctness probability (before rollout sampling).
    pub p_correct: f64,
    /// Generation-length inflation factor (quantization noise, Fig 10d).
    pub len_inflation: f64,
    /// Fraction of rollouts that entered an endless loop.
    pub looped: f64,
}

impl Oracle {
    /// `records` — one per trace segment; `qloss` — importance-weighted
    /// quantization fidelity deficit over R/E tokens (drives inflation).
    pub fn evaluate(
        &self,
        trace: &Trace,
        records: &[RetentionRecord],
        qloss: f64,
        seed: u64,
    ) -> OracleOut {
        let mut rng = Rng::new(seed ^ 0x04ac1e31);
        // importance-weighted damage
        let mut damage = 0.0;
        let mut wsum = 0.0;
        let mut anchor_lost = false;
        for r in records {
            wsum += r.importance;
            let retained = r.kept_info_fid.clamp(0.0, 1.0).powf(self.beta);
            damage += r.importance * (1.0 - retained);
            if r.anchor && r.min_kept_count == 0 {
                anchor_lost = true;
            }
        }
        let damage = if wsum > 0.0 { damage / wsum } else { 0.0 };
        let len_inflation = 1.0 + self.infl_a * qloss.max(0.0).powf(self.infl_p);
        // Inflated chains wander and run into the generation cap: the
        // dominant accuracy cost of aggressive uniform quantization
        // (Table 1: KIVI 2-bit loses ~13 points on AIME).
        let inflation_penalty = (0.08 * (len_inflation - 1.0)).min(0.5);
        let p = trace.dataset.base_acc * (1.0 - self.damage * damage).max(0.0)
            * (1.0 - inflation_penalty);

        let mut correct = 0usize;
        let mut looped = 0usize;
        for _ in 0..self.rollouts {
            if anchor_lost && rng.chance(self.loop_prob) {
                looped += 1;
                continue; // endless loop: wrong by truncation
            }
            let jitter = (rng.normal() * 0.02).clamp(-0.06, 0.06);
            if rng.chance((p + jitter).clamp(0.0, 1.0)) {
                correct += 1;
            }
        }
        OracleOut {
            pass1: correct as f64 / self.rollouts as f64,
            p_correct: if anchor_lost { p * (1.0 - self.loop_prob) } else { p },
            len_inflation,
            looped: looped as f64 / self.rollouts as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace::DatasetProfile;

    fn full_records(trace: &Trace) -> Vec<RetentionRecord> {
        trace
            .segments
            .iter()
            .map(|s| RetentionRecord {
                seg: s.id,
                kept_info_fid: 1.0,
                min_kept_count: s.len,
                importance: s.importance,
                anchor: s.anchor,
            })
            .collect()
    }

    #[test]
    fn full_retention_matches_base_accuracy() {
        let trace = Trace::generate(&DatasetProfile::aime(), 1, 0.25);
        let o = Oracle { rollouts: 400, ..Oracle::default() };
        let out = o.evaluate(&trace, &full_records(&trace), 0.0, 7);
        assert!((out.pass1 - trace.dataset.base_acc).abs() < 0.08, "{}", out.pass1);
        assert!((out.len_inflation - 1.0).abs() < 1e-9);
        assert_eq!(out.looped, 0.0);
    }

    #[test]
    fn losing_important_segments_hurts_more() {
        let trace = Trace::generate(&DatasetProfile::aime(), 2, 0.25);
        let o = Oracle::default();
        let drop = |pred: &dyn Fn(&crate::sim::trace::TraceSegment) -> bool| {
            let recs: Vec<RetentionRecord> = trace
                .segments
                .iter()
                .map(|s| RetentionRecord {
                    seg: s.id,
                    kept_info_fid: if pred(s) { 0.05 } else { 1.0 },
                    min_kept_count: if pred(s) { 1 } else { s.len },
                    importance: s.importance,
                    anchor: s.anchor,
                })
                .collect();
            o.evaluate(&trace, &recs, 0.0, 3).p_correct
        };
        let lose_r = drop(&|s| s.thought == crate::kvcache::Thought::Reasoning);
        let lose_t =
            drop(&|s| s.thought == crate::kvcache::Thought::Transition && !s.anchor);
        assert!(lose_r < lose_t, "losing R ({lose_r}) must hurt more than non-anchor T ({lose_t})");
    }

    #[test]
    fn anchor_loss_causes_loops() {
        let trace = Trace::generate(&DatasetProfile::aime(), 3, 0.3);
        let Some(anchor) = trace.segments.iter().find(|s| s.anchor) else {
            return; // rare seed without anchors
        };
        let recs: Vec<RetentionRecord> = trace
            .segments
            .iter()
            .map(|s| RetentionRecord {
                seg: s.id,
                kept_info_fid: if s.id == anchor.id { 0.0 } else { 1.0 },
                min_kept_count: if s.id == anchor.id { 0 } else { s.len },
                importance: s.importance,
                anchor: s.anchor,
            })
            .collect();
        let o = Oracle { rollouts: 200, ..Oracle::default() };
        let out = o.evaluate(&trace, &recs, 0.0, 5);
        assert!(out.looped > 0.6, "looped {}", out.looped);
        assert!(out.pass1 < trace.dataset.base_acc * 0.5);
    }

    #[test]
    fn inflation_curve_matches_paper_regimes() {
        let o = Oracle::default();
        // KIVI-2: uniform ternary-level noise on everything important
        let q2 = 1.0 - fidelity(Some(Precision::Ternary)); // 0.2
        let infl2 = 1.0 + o.infl_a * q2.powf(o.infl_p);
        assert!((3.5..7.0).contains(&infl2), "2-bit inflation {infl2} (paper ~5.1x)");
        // KIVI-4
        let q4 = 1.0 - fidelity(Some(Precision::Nvfp4));
        let infl4 = 1.0 + o.infl_a * q4.powf(o.infl_p);
        assert!((1.0..1.6).contains(&infl4), "4-bit inflation {infl4}");
        // ThinKV: only low-importance T tokens at 2 bits -> tiny qloss
        let qthink = 0.27 * 0.12 * q2 + 0.73 * q4; // rough mix
        let inflt = 1.0 + o.infl_a * qthink.powf(o.infl_p);
        assert!(inflt < 1.35, "ThinKV inflation {inflt}");
    }

    #[test]
    fn precision_fidelity_ordering() {
        assert!(fidelity(None) > fidelity(Some(Precision::Fp8)));
        assert!(fidelity(Some(Precision::Fp8)) > fidelity(Some(Precision::Nvfp4)));
        assert!(fidelity(Some(Precision::Nvfp4)) > fidelity(Some(Precision::Ternary)));
        // NVFP4 beats INT4, ternary beats INT2 (Table 10)
        assert!(fidelity(Some(Precision::Nvfp4)) > fidelity_int(4));
        assert!(fidelity(Some(Precision::Ternary)) > fidelity_int(2));
    }
}
