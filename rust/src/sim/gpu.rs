//! Analytic GPU cost model (hardware substitution, DESIGN §1).
//!
//! The decode stage of LRM serving is memory-bandwidth bound (paper §1,
//! Recasens et al. 2025): per decode step each layer must stream its
//! weights once per batch plus every request's live KV; eviction gathers
//! add their own traffic which either serializes (R-KV seq) or contends
//! with attention reads on HBM (R-KV ovl, Observation 4b). This module
//! prices those byte flows on A100-80GB / GH200 profiles to regenerate the
//! shape of Tables 2/3/4 and Figures 1c/7/9/10e.

/// GPU hardware profile.
#[derive(Debug, Clone)]
pub struct GpuProfile {
    pub name: &'static str,
    pub hbm_gbps: f64,
    pub mem_gb: f64,
    /// Kernel launch + runtime overhead per layer per step (µs).
    pub launch_us: f64,
    /// Fraction of peak HBM bandwidth attainable by attention kernels.
    pub bw_efficiency: f64,
    /// Fraction of peak HBM bandwidth attainable by gather kernels —
    /// irregular index-based accesses run far below streaming rate (§5.1).
    pub gather_efficiency: f64,
    /// Device<->host link bandwidth (GB/s, one direction) — the rate at
    /// which suspend-to-host swaps move KV snapshots (PCIe on A100,
    /// NVLink-C2C on GH200).
    pub host_link_gbps: f64,
}

impl GpuProfile {
    pub fn a100_80gb() -> GpuProfile {
        GpuProfile {
            name: "A100-80GB",
            hbm_gbps: 2039.0,
            mem_gb: 80.0,
            launch_us: 4.0,
            bw_efficiency: 0.6,
            gather_efficiency: 0.05,
            host_link_gbps: 32.0, // PCIe 4.0 x16
        }
    }

    pub fn gh200() -> GpuProfile {
        GpuProfile {
            name: "GH200",
            hbm_gbps: 4023.0,
            mem_gb: 96.0,
            launch_us: 3.0,
            bw_efficiency: 0.6,
            gather_efficiency: 0.05,
            host_link_gbps: 450.0, // NVLink-C2C (one direction)
        }
    }
}

/// Modeled LRM (the paper's evaluation models, not the toy PJRT model).
#[derive(Debug, Clone)]
pub struct LrmProfile {
    pub name: &'static str,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub weight_gb: f64,
    /// Activation/workspace overhead per request (GB).
    pub act_gb_per_req: f64,
}

impl LrmProfile {
    pub fn r1_llama_8b() -> LrmProfile {
        LrmProfile {
            name: "R1-Llama-8B",
            n_layers: 32,
            n_kv_heads: 8,
            d_head: 128,
            weight_gb: 16.0,
            act_gb_per_req: 0.08,
        }
    }

    pub fn gpt_oss_20b() -> LrmProfile {
        LrmProfile {
            name: "GPT-OSS-20B",
            n_layers: 24,
            n_kv_heads: 8,
            d_head: 64,
            weight_gb: 40.0,
            act_gb_per_req: 0.09,
        }
    }

    /// KV bytes per token (all layers) at `bits` per element, including
    /// group-scale overhead already folded into `bits`.
    pub fn kv_bytes_per_token(&self, bits: f64) -> f64 {
        2.0 * self.n_layers as f64 * self.n_kv_heads as f64 * self.d_head as f64 * bits / 8.0
    }

    /// FullKV fp16 bytes per token.
    pub fn fullkv_bytes_per_token(&self) -> f64 {
        self.kv_bytes_per_token(16.0)
    }
}

/// End-to-end serving cost calculator.
#[derive(Debug, Clone)]
pub struct ServingCost {
    pub gpu: GpuProfile,
    pub model: LrmProfile,
}

/// Per-step cost breakdown (µs) — the Table-5 style decomposition.
#[derive(Debug, Clone, Default)]
pub struct StepCost {
    pub attention_us: f64,
    pub mlp_weights_us: f64,
    pub gather_us: f64,
    pub overhead_us: f64, // policy bookkeeping (TBE / refresh / R-KV scoring)
    pub launch_us: f64,
}

impl StepCost {
    pub fn total_us(&self) -> f64 {
        self.attention_us + self.mlp_weights_us + self.gather_us + self.overhead_us + self.launch_us
    }
}

impl ServingCost {
    pub fn new(gpu: GpuProfile, model: LrmProfile) -> ServingCost {
        ServingCost { gpu, model }
    }

    fn eff_bw_bytes_per_us(&self) -> f64 {
        self.gpu.hbm_gbps * self.gpu.bw_efficiency * 1e9 / 1e6
    }

    /// Max concurrent requests given per-request KV bytes (Table 2 "Batch").
    pub fn max_batch(&self, kv_bytes_per_request: f64) -> usize {
        let free = (self.gpu.mem_gb - self.model.weight_gb) * 1e9;
        if free <= 0.0 {
            return 0;
        }
        let per_req = kv_bytes_per_request + self.model.act_gb_per_req * 1e9;
        (free / per_req).floor().max(0.0) as usize
    }

    /// One decode step for a batch.
    ///
    /// * `batch` — concurrent requests.
    /// * `live_kv_bytes_per_req` — average live KV bytes per request (all
    ///   layers, packed precision).
    /// * `gather_bytes_per_req` — bytes moved by compaction this step.
    /// * `overlapped_gather` — R-KV (ovl): gather runs on a side stream and
    ///   contends with attention for HBM instead of serializing.
    /// * `policy_overhead_us` — host/kernel bookkeeping (TBE k-means,
    ///   thought refresh, R-KV scoring), already amortized per step.
    pub fn decode_step(
        &self,
        batch: usize,
        live_kv_bytes_per_req: f64,
        gather_bytes_per_req: f64,
        overlapped_gather: bool,
        policy_overhead_us: f64,
    ) -> StepCost {
        let bw = self.eff_bw_bytes_per_us();
        let weights_bytes = self.model.weight_gb * 1e9;
        let kv_bytes = live_kv_bytes_per_req * batch as f64;
        let gather_bytes = gather_bytes_per_req * batch as f64;

        let attention_raw = kv_bytes / bw;
        let mlp = weights_bytes / bw;
        let launch = self.gpu.launch_us * self.model.n_layers as f64;

        // gather runs at a fraction of streaming bandwidth (irregular,
        // index-based accesses: the reason Figure 7 shows up-to-37x TPOT
        // blowups for per-step compaction)
        let gather_bw = bw * (self.gpu.gather_efficiency / self.gpu.bw_efficiency);
        let (attention, gather) = if gather_bytes == 0.0 {
            (attention_raw, 0.0)
        } else if overlapped_gather {
            // Observation 4b: overlapped gather hides behind attention at
            // small batch, but contends for HBM as traffic grows — model as
            // shared-bandwidth slowdown on attention (up to ~35%), plus the
            // spill once gather outlasts the inflated attention.
            let share = gather_bytes / (gather_bytes + kv_bytes.max(1.0));
            let contention = 1.0 + (0.35_f64).min(share * 1.2);
            let att = attention_raw * contention;
            let spill = (gather_bytes / gather_bw - att).max(0.0) * 0.5;
            (att, spill)
        } else {
            // Observation 4a: sequential gather serializes fully.
            (attention_raw, gather_bytes / gather_bw)
        };

        StepCost {
            attention_us: attention,
            mlp_weights_us: mlp,
            gather_us: gather,
            overhead_us: policy_overhead_us,
            launch_us: launch,
        }
    }

    /// One decode step where each session is advanced by its **own**
    /// engine call (per-session kernel launches) instead of one fused
    /// call for the whole batch — the pre-batching worker behavior.
    /// Byte traffic is identical to [`ServingCost::decode_step`]; only
    /// the launch overhead multiplies by the batch size. The gap
    /// between the two is the launch-amortization win of cross-session
    /// batched decode (`bench_scheduler`'s amortization sweep).
    pub fn decode_step_per_session(
        &self,
        batch: usize,
        live_kv_bytes_per_req: f64,
        gather_bytes_per_req: f64,
        overlapped_gather: bool,
        policy_overhead_us: f64,
    ) -> StepCost {
        let mut step = self.decode_step(
            batch,
            live_kv_bytes_per_req,
            gather_bytes_per_req,
            overlapped_gather,
            policy_overhead_us,
        );
        step.launch_us *= batch.max(1) as f64;
        step
    }

    /// Aggregate throughput (tokens/s) for steady-state decode.
    pub fn throughput_tok_s(&self, batch: usize, step: &StepCost) -> f64 {
        if step.total_us() <= 0.0 {
            return 0.0;
        }
        batch as f64 / (step.total_us() / 1e6)
    }

    /// Time-per-output-token (ms) for one user.
    pub fn tpot_ms(&self, step: &StepCost) -> f64 {
        step.total_us() / 1e3
    }

    /// Full suspend/resume cost (ms) of a preempted request whose live
    /// cache snapshot is `snapshot_bytes`: one copy host-ward at
    /// swap-out plus one copy device-ward at swap-in over the
    /// device<->host link.
    pub fn swap_roundtrip_ms(&self, snapshot_bytes: f64) -> f64 {
        2.0 * snapshot_bytes / (self.gpu.host_link_gbps * 1e9) * 1e3
    }

    /// Re-anchor the analytic launch-amortization and host-link terms
    /// against **measured** numbers (e.g. `bench_scheduler`'s measured
    /// PJRT execute sweep): replaces the profile's per-layer launch
    /// overhead and device<->host link bandwidth in place, so every
    /// analytically-priced assertion can re-run against measured
    /// anchors instead of datasheet guesses. Non-positive or non-finite
    /// inputs leave the corresponding term unchanged — a failed
    /// measurement must not zero the model.
    pub fn reanchor(&mut self, launch_us_per_layer: f64, host_link_gbps: f64) {
        if launch_us_per_layer > 0.0 && launch_us_per_layer.is_finite() {
            self.gpu.launch_us = launch_us_per_layer;
        }
        if host_link_gbps > 0.0 && host_link_gbps.is_finite() {
            self.gpu.host_link_gbps = host_link_gbps;
        }
    }

    /// Least-squares intercept of measured execute time (µs) against
    /// batch width: the per-execute launch/runtime overhead a fused
    /// step pays once however wide it is — the quantity batching
    /// amortizes. Divide by `n_layers` to feed
    /// [`ServingCost::reanchor`]. Returns `None` without at least two
    /// distinct widths (no slope to separate the intercept from);
    /// negative intercepts (measurement noise) clamp to 0.
    pub fn launch_intercept_us(points: &[(usize, f64)]) -> Option<f64> {
        let n = points.len() as f64;
        let first = points.first()?.0;
        if points.iter().all(|&(b, _)| b == first) {
            return None;
        }
        let sx: f64 = points.iter().map(|&(b, _)| b as f64).sum();
        let sy: f64 = points.iter().map(|&(_, t)| t).sum();
        let sxx: f64 = points.iter().map(|&(b, _)| (b as f64) * (b as f64)).sum();
        let sxy: f64 = points.iter().map(|&(b, t)| b as f64 * t).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < f64::EPSILON {
            return None;
        }
        Some(((sy * sxx - sx * sxy) / denom).max(0.0))
    }

    /// Recompute cost (ms) of a preempted request: replay
    /// `replay_steps` decode steps (the generated CoT so far) at the
    /// running batch's step time. This is what suspend-to-host
    /// preemption avoids.
    pub fn recompute_ms(
        &self,
        batch: usize,
        live_kv_bytes_per_req: f64,
        replay_steps: usize,
    ) -> f64 {
        let step = self.decode_step(batch.max(1), live_kv_bytes_per_req, 0.0, false, 0.0);
        replay_steps as f64 * step.total_us() / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> ServingCost {
        ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::r1_llama_8b())
    }

    #[test]
    fn fullkv_max_batch_is_small() {
        let c = cost();
        // FullKV at 32K tokens: 2*32*8*128*2B = 128KB/token -> 4.3GB/request
        let kv = c.model.fullkv_bytes_per_token() * 32_768.0;
        let b = c.max_batch(kv);
        assert!((8..=20).contains(&b), "fullkv batch {b}"); // paper: 13
    }

    #[test]
    fn compressed_cache_multiplies_batch() {
        let c = cost();
        let full = c.max_batch(c.model.fullkv_bytes_per_token() * 32_768.0);
        // ThinKV: 1024-token budget at ~3.4 bits + fp buffer
        let thinkv = c.max_batch(c.model.kv_bytes_per_token(3.4) * 1024.0);
        assert!(thinkv > 20 * full, "full={full} thinkv={thinkv}");
    }

    #[test]
    fn sequential_gather_serializes() {
        let c = cost();
        let kv = c.model.kv_bytes_per_token(16.0) * 1024.0;
        let none = c.decode_step(64, kv, 0.0, false, 0.0);
        let seq = c.decode_step(64, kv, kv * 0.5, false, 0.0);
        let ovl = c.decode_step(64, kv, kv * 0.5, true, 0.0);
        assert!(seq.total_us() > none.total_us());
        assert!(ovl.total_us() < seq.total_us(), "overlap should help");
        assert!(ovl.attention_us > none.attention_us, "contention inflates attention");
    }

    #[test]
    fn contention_caps_at_35_percent() {
        let c = cost();
        let kv = c.model.kv_bytes_per_token(16.0) * 1024.0;
        let ovl = c.decode_step(256, kv, kv * 10.0, true, 0.0);
        let none = c.decode_step(256, kv, 0.0, false, 0.0);
        assert!(ovl.attention_us <= none.attention_us * 1.351);
    }

    #[test]
    fn throughput_scales_with_batch_until_kv_bound() {
        let c = cost();
        let kv = c.model.kv_bytes_per_token(3.4) * 1024.0;
        let t1 = {
            let s = c.decode_step(1, kv, 0.0, false, 0.0);
            c.throughput_tok_s(1, &s)
        };
        let t256 = {
            let s = c.decode_step(256, kv, 0.0, false, 0.0);
            c.throughput_tok_s(256, &s)
        };
        assert!(t256 > 50.0 * t1, "batching must amortize weights: {t1} vs {t256}");
    }

    #[test]
    fn fused_step_amortizes_launch_overhead() {
        let c = cost();
        let kv = c.model.kv_bytes_per_token(3.4) * 1024.0;
        let single = c.decode_step(1, kv, 0.0, false, 0.0);
        let mut last_tput = 0.0;
        for batch in [1usize, 2, 4, 8, 16, 32] {
            let fused = c.decode_step(batch, kv, 0.0, false, 0.0);
            let per = c.decode_step_per_session(batch, kv, 0.0, false, 0.0);
            // per-session launches pay the launch overhead batch times
            assert!(
                (per.launch_us - batch as f64 * fused.launch_us).abs() < 1e-9,
                "launch not multiplied at batch {batch}"
            );
            assert!(fused.total_us() <= per.total_us());
            if batch >= 4 {
                // acceptance bar: one fused step is cheaper than N
                // sequential single-session steps
                assert!(
                    fused.total_us() < batch as f64 * single.total_us(),
                    "fused step not amortizing at batch {batch}"
                );
            }
            let tput = c.throughput_tok_s(batch, &fused);
            assert!(tput > last_tput, "throughput must grow with batch: {batch}");
            last_tput = tput;
        }
    }

    #[test]
    fn swap_beats_recompute_for_compressed_caches() {
        let c = cost();
        // ThinKV snapshot: 1024-token budget at ~3.4 bits -> a few MB
        let thinkv_snap = c.model.kv_bytes_per_token(3.4) * 1024.0;
        let swap = c.swap_roundtrip_ms(thinkv_snap);
        // recompute replays the whole CoT generated so far
        let recompute = c.recompute_ms(32, thinkv_snap, 8_192);
        assert!(
            swap * 100.0 < recompute,
            "swap {swap:.2} ms must be >>100x cheaper than recompute {recompute:.2} ms"
        );
        // FullKV at 16K tokens swaps 100x+ more bytes than ThinKV
        let full_snap = c.model.fullkv_bytes_per_token() * 16_384.0;
        assert!(c.swap_roundtrip_ms(full_snap) > 50.0 * swap);
    }

    /// Re-anchoring swaps the datasheet launch/link guesses for
    /// measured ones, and the amortization ordering (fused < N singles
    /// for batch >= 4) must survive any positive anchor.
    #[test]
    fn reanchor_applies_measured_terms_and_preserves_amortization() {
        let mut c = cost();
        c.reanchor(9.5, 12.0);
        assert!((c.gpu.launch_us - 9.5).abs() < 1e-12);
        assert!((c.gpu.host_link_gbps - 12.0).abs() < 1e-12);
        // bad measurements leave the model untouched
        c.reanchor(-1.0, f64::NAN);
        assert!((c.gpu.launch_us - 9.5).abs() < 1e-12);
        assert!((c.gpu.host_link_gbps - 12.0).abs() < 1e-12);
        c.reanchor(0.0, 0.0);
        assert!((c.gpu.launch_us - 9.5).abs() < 1e-12);
        let kv = c.model.kv_bytes_per_token(3.4) * 1024.0;
        let single = c.decode_step(1, kv, 0.0, false, 0.0);
        for batch in [4usize, 8, 16] {
            let fused = c.decode_step(batch, kv, 0.0, false, 0.0);
            assert!(
                fused.total_us() < batch as f64 * single.total_us(),
                "fused not amortizing at batch {batch} under measured anchors"
            );
        }
    }

    /// The intercept of execute time vs batch width is the per-execute
    /// launch overhead — recovered exactly from synthetic linear data.
    #[test]
    fn launch_intercept_recovers_fixed_overhead() {
        // t(B) = 120 + 35 * B
        let pts: Vec<(usize, f64)> =
            [1usize, 2, 4, 8].iter().map(|&b| (b, 120.0 + 35.0 * b as f64)).collect();
        let a = ServingCost::launch_intercept_us(&pts).unwrap();
        assert!((a - 120.0).abs() < 1e-9, "intercept {a}");
        // all-equal widths: intercept is unidentifiable
        assert!(ServingCost::launch_intercept_us(&[(4, 1.0), (4, 2.0)]).is_none());
        assert!(ServingCost::launch_intercept_us(&[]).is_none());
        // noise can drive the fit negative; it clamps to 0
        let neg = ServingCost::launch_intercept_us(&[(1, 0.0), (2, 50.0)]).unwrap();
        assert_eq!(neg, 0.0);
    }

    #[test]
    fn gh200_faster_than_a100() {
        let a = cost();
        let g = ServingCost::new(GpuProfile::gh200(), LrmProfile::r1_llama_8b());
        let kv = a.model.kv_bytes_per_token(3.4) * 1024.0;
        let sa = a.decode_step(128, kv, 0.0, false, 0.0);
        let sg = g.decode_step(128, kv, 0.0, false, 0.0);
        assert!(sg.total_us() < sa.total_us());
    }
}
