//! Simulation substrates (DESIGN §1 substitution table):
//!
//! * [`gpu`] — analytic A100-80GB / GH200 cost model. Decode is memory
//!   bound (paper §1), so step time ≈ bytes-moved / HBM bandwidth with
//!   launch overheads and the §5.1 gather-contention term. Reproduces the
//!   *shape* of Tables 2/3, Figures 7/9/10e.
//! * [`trace`] — LRM reasoning-trace generator: thought-segmented token
//!   streams with tri-modal attention sparsity (Obs. 1), importance
//!   hierarchy R>E>T with outlier transition anchors (Obs. 2), and
//!   association decay across transitions (Obs. 3), parameterized per
//!   dataset (AIME / LiveCodeBench / MATH-500 / GSM8K, Fig 10f mixes);
//!   plus the deterministic multi-tenant [`ArrivalTrace`] generator —
//!   seeded Poisson + bursty arrivals over SLO-classed tenant mixes
//!   with shared per-class system prompts.
//! * [`oracle`] — counterfactual accuracy oracle: pass@1 as a function of
//!   which tokens a policy retained, at what precision; quantization-noise
//!   driven generation-length inflation (Fig 2/10d); endless-loop failure
//!   when transition anchors are lost (§E.17, Fig 11a min-R).
//! * [`harness`] — the simulation twin of the serving coordinator: runs any
//!   compression method over a trace and reports accuracy / compression /
//!   recall / call-rate metrics.

pub mod gpu;
pub mod harness;
pub mod oracle;
pub mod trace;

pub use gpu::{GpuProfile, LrmProfile, ServingCost};
pub use harness::{run_method, Method, SimConfig, SimResult};
pub use oracle::{replay_divergence, Oracle, ReplayDiff};
pub use trace::{ArrivalEvent, ArrivalTrace, DatasetProfile, TenantClass, Trace, TraceSegment};
