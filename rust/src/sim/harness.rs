//! The simulation twin of the serving coordinator: runs any compression
//! method over an LRM trace and reports the paper's metrics.
//!
//! All methods operate on the same primitive state: the set of *retained*
//! CoT positions with their storage precision. ThinKV manages it with the
//! real classifier + TBE schedule semantics (windows of τ tokens, case 1 /
//! case 2, min retention); baselines manage it with their
//! [`EvictionPolicy`] implementations over simulated attention rows.

use std::collections::BTreeMap;

use crate::baselines::eviction::{
    EvictionPolicy, FullKv, LazyEviction, PosAttn, RaaS, Rkv, SnapKv, StreamingLlm, H2O,
};
use crate::baselines::quant_baselines::PmKvq;
use crate::compress::tbq::PrecisionAssignment;
use crate::kvcache::Thought;
use crate::quant::Precision;
use crate::util::rng::Rng;

use super::oracle::{fidelity, Oracle, RetentionRecord};
use super::trace::Trace;

/// A compression method under simulation.
#[derive(Debug, Clone)]
pub enum Method {
    FullKv,
    /// ThinKV: hybrid TBQ+TBE with CT semantics.
    ThinKv(ThinKvSim),
    /// Eviction-only baseline at fp16.
    Evict(EvictKind),
    /// Uniform quantization (KIVI-style), no eviction.
    Kivi { prec: Precision },
    /// Progressive mixed-precision quantization, no eviction.
    PmKvq,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictKind {
    H2O,
    Rkv,
    RkvOverlapped,
    LazyEviction,
    RaaS,
    SnapKv,
    StreamingLlm,
}

impl EvictKind {
    pub fn label(&self) -> &'static str {
        match self {
            EvictKind::H2O => "H2O",
            EvictKind::Rkv => "R-KV (seq)",
            EvictKind::RkvOverlapped => "R-KV (ovl)",
            EvictKind::LazyEviction => "LazyEviction",
            EvictKind::RaaS => "RaaS",
            EvictKind::SnapKv => "SnapKV",
            EvictKind::StreamingLlm => "StreamingLLM",
        }
    }
}

/// ThinKV simulation knobs (paper §6.1 hyperparameters).
#[derive(Debug, Clone)]
pub struct ThinKvSim {
    pub assignment: PrecisionAssignment,
    /// Refresh interval τ.
    pub refresh: usize,
    /// Retention schedule R.
    pub retention: Vec<usize>,
    /// Minimum retention (last entry of R unless overridden).
    pub min_keep: usize,
    /// Disable TBQ (eviction-only ThinKV, Table 4 / Table 2 iso-compression).
    pub no_tbq: bool,
    /// Disable TBE (quantization-only ThinKV, Table 4).
    pub no_tbe: bool,
    /// Classifier thresholds Θ (sparsity space).
    pub thresholds: Vec<f64>,
    /// Number of thought types |T| (Fig 11a sweep; 1 = LLM mode).
    pub n_thoughts: usize,
}

impl Default for ThinKvSim {
    fn default() -> Self {
        ThinKvSim {
            assignment: PrecisionAssignment::r4e4t2(),
            refresh: 128,
            retention: vec![64, 32, 16, 8, 4],
            min_keep: 4,
            no_tbq: false,
            no_tbe: false,
            thresholds: crate::thought::calibration::default_thresholds(3),
            n_thoughts: 3,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub budget: usize,
    pub seed: u64,
    /// Baselines observe attention every `stride` steps (simulation cost).
    pub stride: usize,
    pub rollouts: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { budget: 1024, seed: 0, stride: 4, rollouts: 8 }
    }
}

/// Metrics of one (trace, method) simulation.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub method: String,
    pub pass1: f64,
    pub p_correct: f64,
    /// Average nominal storage bits over retained tokens (16 = fp16).
    pub avg_bits: f64,
    /// Mean live KV bytes / FullKV bytes at same step (packed accounting).
    pub mem_frac: f64,
    /// Mean live retained tokens.
    pub avg_live: f64,
    pub len_inflation: f64,
    pub looped: f64,
    /// Top-10 ground-truth recall averaged over probes (Fig 10a).
    pub recall10: f64,
    /// Fraction of decode steps that ran any eviction work (Table 5).
    pub evict_call_rate: f64,
    /// Gather traffic per decode step, bytes/token of KV (cost-model input).
    pub gather_bytes_per_step: f64,
    /// Whether this method's evictions require gather compaction.
    pub needs_gather: bool,
    /// Count of eviction events.
    pub evict_events: u64,
}

/// Storage precision of a retained token (None = fp16).
type Kept = BTreeMap<usize, Option<Precision>>;

/// Retention tracker implementing the paper's association decay (Obs 3):
/// what matters is the info a segment still held at hop h = number of
/// transitions since it ended, weighted by decay^h — evicting *after* the
/// trajectory moved on is nearly free (ThinKV's bet), evicting while the
/// segment is still hot is expensive.
struct SegTracker {
    min_kept: Vec<usize>,
    /// Per segment: retained info·fidelity snapshots at hop 0, 1, 2, ...
    hop_retained: Vec<Vec<f64>>,
    /// Transition ends already processed (by segment id).
    transitions_seen: usize,
}

const HOP_DECAY: f64 = 0.5;
const MAX_HOPS: usize = 4;

impl SegTracker {
    fn new(trace: &Trace) -> SegTracker {
        SegTracker {
            min_kept: trace.segments.iter().map(|s| s.len).collect(),
            hop_retained: vec![Vec::new(); trace.segments.len()],
            transitions_seen: 0,
        }
    }

    fn retained_info(trace: &Trace, kept: &Kept, seg: usize) -> f64 {
        let s = &trace.segments[seg];
        let mut info = 0.0;
        for (&pos, prec) in kept.range(s.start..s.end()) {
            info += s.token_info[pos - s.start] * fidelity(*prec);
        }
        info
    }

    /// Call once per decode step with the current position.
    fn observe(&mut self, trace: &Trace, kept: &Kept, pos: usize) {
        for s in &trace.segments {
            if s.start > pos {
                break;
            }
            if s.end() > pos + 1 {
                continue; // still open
            }
            let n = kept.range(s.start..s.end()).count();
            if n < self.min_kept[s.id] {
                self.min_kept[s.id] = n;
            }
            // hop-0 snapshot at the segment's own close (hot state)
            if s.end() == pos + 1 && self.hop_retained[s.id].is_empty() {
                self.hop_retained[s.id].push(Self::retained_info(trace, kept, s.id));
            }
        }
        // a transition segment fully ended at `pos`: snapshot all closed
        // segments at their next hop
        let transition_closed = trace
            .segments
            .iter()
            .any(|s| s.thought == Thought::Transition && s.end() == pos + 1);
        if transition_closed {
            self.transitions_seen += 1;
            for s in &trace.segments {
                if s.end() > pos + 1 {
                    break;
                }
                if !self.hop_retained[s.id].is_empty()
                    && self.hop_retained[s.id].len() < MAX_HOPS
                {
                    self.hop_retained[s.id].push(Self::retained_info(trace, kept, s.id));
                }
            }
        }
    }

    fn finish(mut self, trace: &Trace, kept: &Kept) -> Vec<RetentionRecord> {
        let mut out = Vec::with_capacity(trace.segments.len());
        for s in &trace.segments {
            // final snapshot (answer time)
            if self.hop_retained[s.id].len() < MAX_HOPS + 1 {
                self.hop_retained[s.id].push(Self::retained_info(trace, kept, s.id));
            }
            // hop-decay weighted effective retention; hop 0 = while still
            // hot (before any transition passed)
            let snaps = &self.hop_retained[s.id];
            let mut num = 0.0;
            let mut den = 0.0;
            for (h, r) in snaps.iter().enumerate() {
                let w = HOP_DECAY.powi(h as i32);
                num += w * r;
                den += w;
            }
            let eff = if den > 0.0 { num / den } else { 1.0 };
            out.push(RetentionRecord {
                seg: s.id,
                kept_info_fid: eff,
                min_kept_count: self.min_kept[s.id],
                importance: s.importance,
                anchor: s.anchor,
            });
        }
        out
    }
}

/// Importance-weighted quantization fidelity deficit (inflation driver):
/// only R/E tokens count — noise on them forces re-derivation.
fn quant_loss(trace: &Trace, kept: &Kept) -> f64 {
    let mut loss = 0.0;
    let mut w = 0.0;
    for s in &trace.segments {
        if s.thought == Thought::Transition {
            continue;
        }
        for (&pos, prec) in kept.range(s.start..s.end()) {
            let info = s.token_info[pos - s.start];
            loss += s.importance * info * (1.0 - fidelity(*prec));
            w += s.importance * info;
        }
    }
    if w > 0.0 {
        loss / w
    } else {
        0.0
    }
}

fn nominal_bits(p: Option<Precision>) -> f64 {
    p.map(|x| crate::quant::packed_bits_per_elem(x)).unwrap_or(16.0)
}

/// Run one method over one trace.
pub fn run_method(trace: &Trace, method: &Method, cfg: &SimConfig) -> SimResult {
    match method {
        Method::FullKv => run_baseline(trace, Box::new(FullKv), "FullKV", usize::MAX, cfg, false),
        Method::Evict(kind) => {
            let budget = cfg.budget;
            let (policy, gather): (Box<dyn EvictionPolicy>, bool) = match kind {
                EvictKind::H2O => (Box::new(H2O::new()), false),
                EvictKind::Rkv | EvictKind::RkvOverlapped => (Box::new(Rkv::new()), true),
                EvictKind::LazyEviction => (Box::new(LazyEviction::new()), true),
                EvictKind::RaaS => (Box::new(RaaS::new()), true),
                EvictKind::SnapKv => {
                    // prefill obs scores ~ token info of the prompt segment
                    let obs: Vec<f32> = trace.segments[0]
                        .token_info
                        .iter()
                        .map(|&x| x as f32)
                        .collect();
                    (Box::new(SnapKv::from_prefill_obs(&obs, budget.min(trace.prompt_len) / 2)), false)
                }
                EvictKind::StreamingLlm => (Box::new(StreamingLlm::new(4)), false),
            };
            run_baseline(trace, policy, kind.label(), budget, cfg, gather)
        }
        Method::Kivi { prec } => run_quant_only(trace, cfg, QuantMode::Uniform(*prec)),
        Method::PmKvq => run_quant_only(trace, cfg, QuantMode::Progressive(PmKvq::default_schedule())),
        Method::ThinKv(tk) => run_thinkv(trace, tk, cfg),
    }
}

// ---------------------------------------------------------------------------
// Baseline runner (fp16 eviction policies + FullKV)
// ---------------------------------------------------------------------------

fn run_baseline(
    trace: &Trace,
    mut policy: Box<dyn EvictionPolicy>,
    label: &str,
    budget: usize,
    cfg: &SimConfig,
    needs_gather: bool,
) -> SimResult {
    let mut rng = Rng::new(cfg.seed ^ 0xBA5E);
    let mut kept: Kept = BTreeMap::new();
    for pos in 0..trace.prompt_len {
        kept.insert(pos, None);
    }
    let mut tracker = SegTracker::new(trace);
    let mut live_sum = 0f64;
    let mut bytes_sum = 0f64;
    let mut full_bytes_sum = 0f64;
    let mut recall_sum = 0f64;
    let mut recall_n = 0usize;
    let mut evict_steps = 0u64;
    let mut evict_events = 0u64;
    let mut gather_tokens = 0f64;
    let total = trace.total_len();

    for pos in trace.prompt_len..total {
        kept.insert(pos, None);
        // observe attention (strided)
        if pos % cfg.stride == 0 {
            let attn = sim_attention(trace, &kept, pos, &mut rng);
            policy.observe(&attn);
        }
        // budget enforcement. Every practical eviction system protects a
        // recent local window (R-KV, LazyEviction, RaaS all keep one);
        // without it newly-generated tokens have no accumulated score and
        // would be evicted immediately.
        if kept.len() > budget {
            let recent = 32.min(budget / 2);
            let live_all: Vec<usize> = kept.keys().copied().collect();
            let cut = live_all.len() - recent.min(live_all.len());
            let live: Vec<usize> = live_all[..cut].to_vec();
            let target = budget.saturating_sub(recent);
            let evict = policy.select_evictions(&live, target);
            if !evict.is_empty() {
                evict_steps += 1;
                evict_events += 1;
                for p in &evict {
                    kept.remove(p);
                }
                if needs_gather {
                    // compaction rewrites the live cache
                    gather_tokens += kept.len() as f64;
                }
            }
        }
        live_sum += kept.len() as f64;
        bytes_sum += kept.len() as f64 * 16.0;
        full_bytes_sum += (pos + 1) as f64 * 16.0;
        if pos % 64 == 0 && pos > trace.prompt_len + 64 {
            recall_sum += recall10(trace, &kept, pos);
            recall_n += 1;
        }
        tracker.observe(trace, &kept, pos);
    }

    let records = tracker.finish(trace, &kept);
    let oracle = Oracle { rollouts: cfg.rollouts, ..Oracle::default() };
    let out = oracle.evaluate(trace, &records, 0.0, cfg.seed);
    let steps = (total - trace.prompt_len).max(1) as f64;
    SimResult {
        method: label.to_string(),
        pass1: out.pass1,
        p_correct: out.p_correct,
        avg_bits: 16.0,
        mem_frac: bytes_sum / full_bytes_sum,
        avg_live: live_sum / steps,
        len_inflation: out.len_inflation,
        looped: out.looped,
        recall10: if recall_n > 0 { recall_sum / recall_n as f64 } else { 1.0 },
        evict_call_rate: evict_steps as f64 / steps,
        gather_bytes_per_step: gather_tokens / steps,
        needs_gather,
        evict_events,
    }
}

// ---------------------------------------------------------------------------
// Quantization-only runners (KIVI / PM-KVQ)
// ---------------------------------------------------------------------------

enum QuantMode {
    Uniform(Precision),
    Progressive(PmKvq),
}

fn run_quant_only(trace: &Trace, cfg: &SimConfig, mode: QuantMode) -> SimResult {
    let mut kept: Kept = BTreeMap::new();
    let total = trace.total_len();
    for pos in 0..total {
        let prec = match &mode {
            QuantMode::Uniform(p) => Some(*p),
            QuantMode::Progressive(pm) => Some(pm.precision_for_age(0)),
        };
        kept.insert(pos, prec);
        if let QuantMode::Progressive(pm) = &mode {
            // age-driven requantization of older tokens
            if pos % 128 == 0 {
                let entries: Vec<usize> = kept.keys().copied().collect();
                for p in entries {
                    let want = pm.precision_for_age(pos - p);
                    let cur = kept[&p];
                    if nominal_bits(Some(want)) < nominal_bits(cur) {
                        kept.insert(p, Some(want));
                    }
                }
            }
        }
    }
    let mut tracker = SegTracker::new(trace);
    for pos in 0..total {
        tracker.observe(trace, &kept, pos);
    }
    let records = tracker.finish(trace, &kept);
    let qloss = quant_loss(trace, &kept);
    let oracle = Oracle { rollouts: cfg.rollouts, ..Oracle::default() };
    let out = oracle.evaluate(trace, &records, qloss, cfg.seed);
    let bits: f64 =
        kept.values().map(|p| nominal_bits(*p)).sum::<f64>() / kept.len().max(1) as f64;
    let label = match &mode {
        QuantMode::Uniform(Precision::Ternary) => "KIVI-2".to_string(),
        QuantMode::Uniform(Precision::Nvfp4) => "KIVI-4".to_string(),
        QuantMode::Uniform(Precision::Fp8) => "KIVI-8".to_string(),
        QuantMode::Progressive(_) => "PM-KVQ".to_string(),
    };
    // quantization-only keeps all (inflated) tokens: memory = bits/16 × len
    // inflation
    SimResult {
        method: label,
        pass1: out.pass1,
        p_correct: out.p_correct,
        avg_bits: bits,
        mem_frac: (bits / 16.0) * out.len_inflation.min(3.0), // erosion, Fig 2
        avg_live: kept.len() as f64,
        len_inflation: out.len_inflation,
        looped: out.looped,
        recall10: 1.0,
        evict_call_rate: 0.0,
        gather_bytes_per_step: 0.0,
        needs_gather: false,
        evict_events: 0,
    }
}

// ---------------------------------------------------------------------------
// ThinKV runner
// ---------------------------------------------------------------------------

fn run_thinkv(trace: &Trace, tk: &ThinKvSim, cfg: &SimConfig) -> SimResult {
    let mut rng = Rng::new(cfg.seed ^ 0x7717);
    let mut kept: Kept = BTreeMap::new();
    let psi = |t: Thought| -> Option<Precision> {
        if tk.no_tbq {
            return None; // fp16
        }
        Some(match t {
            Thought::Reasoning => tk.assignment.r,
            Thought::Execution => tk.assignment.e,
            Thought::Transition => tk.assignment.t,
        })
    };
    // prefill = R thoughts
    for pos in 0..trace.prompt_len {
        kept.insert(pos, psi(Thought::Reasoning));
    }

    // ThinKV windows: every τ tokens the classifier labels the window from
    // mean simulated sparsity.
    struct Window {
        start: usize,
        end: usize,
        label: Thought,
        evict_level: usize,
    }
    let mut windows: Vec<Window> = vec![Window {
        start: 0,
        end: trace.prompt_len,
        label: Thought::Reasoning,
        evict_level: 0,
    }];

    let classify = |mean_sparsity: f64| -> Thought {
        if tk.n_thoughts <= 1 || tk.thresholds.is_empty() {
            return Thought::Reasoning;
        }
        if tk.n_thoughts == 2 {
            return if mean_sparsity <= tk.thresholds[0] {
                Thought::Execution
            } else {
                Thought::Reasoning
            };
        }
        if mean_sparsity <= tk.thresholds[0] {
            Thought::Execution
        } else if mean_sparsity <= tk.thresholds[1] {
            Thought::Reasoning
        } else {
            Thought::Transition
        }
    };

    let keep_at = |level: usize| -> usize {
        *tk.retention
            .get(level.min(tk.retention.len() - 1))
            .unwrap_or(&tk.min_keep)
            .max(&tk.min_keep)
    };

    // anneal one window to its next level: keep top-info tokens (the
    // k-means policy π keeps cluster representatives ≈ info-coverage).
    let anneal = |kept: &mut Kept, w: &mut Window, trace: &Trace| -> usize {
        let target = keep_at(w.evict_level);
        let live: Vec<usize> = kept.range(w.start..w.end).map(|(&p, _)| p).collect();
        if live.len() <= target {
            w.evict_level += 1;
            return 0;
        }
        let mut by_info: Vec<(f64, usize)> = live
            .iter()
            .map(|&p| {
                let s = trace.segment_of(p);
                (s.token_info[p - s.start], p)
            })
            .collect();
        by_info.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let evict: Vec<usize> = by_info[target..].iter().map(|&(_, p)| p).collect();
        for p in &evict {
            kept.remove(p);
        }
        w.evict_level += 1;
        evict.len()
    };

    let mut tracker = SegTracker::new(trace);
    let mut live_sum = 0f64;
    let mut bytes_sum = 0f64;
    let mut full_bytes_sum = 0f64;
    let mut recall_sum = 0f64;
    let mut recall_n = 0usize;
    let mut evict_steps = 0u64;
    let mut evict_events = 0u64;
    let mut sparsity_acc = 0f64;
    let mut sparsity_n = 0usize;
    let total = trace.total_len();
    let mut cur_label = Thought::Reasoning;

    for pos in trace.prompt_len..total {
        // window refresh
        if (pos - trace.prompt_len) % tk.refresh == 0 && pos > trace.prompt_len {
            let mean = if sparsity_n > 0 { sparsity_acc / sparsity_n as f64 } else { 0.5 };
            sparsity_acc = 0.0;
            sparsity_n = 0;
            let closing_label = cur_label;
            windows.last_mut().unwrap().end = pos;
            cur_label = classify(mean);
            // TBE case 1: a transition window just closed
            if !tk.no_tbe && closing_label == Thought::Transition {
                let mut did = 0;
                let n = windows.len();
                for w in windows[..n].iter_mut() {
                    did += anneal(&mut kept, w, trace);
                }
                if did > 0 {
                    evict_steps += 1;
                    evict_events += 1;
                }
            }
            windows.push(Window {
                start: pos,
                end: pos,
                label: cur_label,
                evict_level: 0,
            });
        }
        sparsity_acc += trace.sparsity[pos] + rng.normal() * 0.01;
        sparsity_n += 1;

        kept.insert(pos, psi(cur_label));

        // TBE case 2: budget pressure
        if !tk.no_tbe && kept.len() > cfg.budget {
            let mut did = 0;
            // oldest least-important window that can still shrink
            let nw = windows.len();
            let mut order: Vec<usize> = (0..nw.saturating_sub(1)).collect();
            order.sort_by_key(|&i| (windows[i].label.importance(), windows[i].start));
            for i in order {
                if kept.len() <= cfg.budget {
                    break;
                }
                did += anneal(&mut kept, &mut windows[i], trace);
            }
            if did > 0 {
                evict_steps += 1;
                evict_events += 1;
            }
        } else if tk.no_tbe && kept.len() > cfg.budget {
            // quantization-only ThinKV still must fit somewhere: emulate
            // no-eviction (budget ignored, like KIVI) — nothing to do.
        }

        live_sum += kept.len() as f64;
        bytes_sum += kept
            .values()
            .map(|p| nominal_bits(*p))
            .sum::<f64>();
        full_bytes_sum += (pos + 1) as f64 * 16.0;
        if pos % 64 == 0 && pos > trace.prompt_len + 64 {
            recall_sum += recall10(trace, &kept, pos);
            recall_n += 1;
        }
        tracker.observe(trace, &kept, pos);
    }

    let records = tracker.finish(trace, &kept);
    let qloss = if tk.no_tbq { 0.0 } else { quant_loss(trace, &kept) };
    let oracle = Oracle { rollouts: cfg.rollouts, ..Oracle::default() };
    let out = oracle.evaluate(trace, &records, qloss, cfg.seed);
    let steps = (total - trace.prompt_len).max(1) as f64;
    let avg_bits = if kept.is_empty() {
        16.0
    } else {
        kept.values().map(|p| nominal_bits(*p)).sum::<f64>() / kept.len() as f64
    };
    let name = if tk.no_tbq {
        "ThinKV w/o TBQ".to_string()
    } else if tk.no_tbe {
        "ThinKV w/o TBE (TBQ)".to_string()
    } else {
        "ThinKV".to_string()
    };
    SimResult {
        method: name,
        pass1: out.pass1,
        p_correct: out.p_correct,
        avg_bits,
        mem_frac: bytes_sum / full_bytes_sum,
        avg_live: live_sum / steps,
        len_inflation: out.len_inflation,
        looped: out.looped,
        recall10: if recall_n > 0 { recall_sum / recall_n as f64 } else { 1.0 },
        evict_call_rate: evict_steps as f64 / steps,
        gather_bytes_per_step: 0.0, // CT: in-place reuse, no gather ever
        needs_gather: false,
        evict_events,
    }
}

// ---------------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------------

/// Simulated attention row over currently-kept positions.
fn sim_attention(trace: &Trace, kept: &Kept, pos: usize, rng: &mut Rng) -> PosAttn {
    let mut attn: Vec<(usize, f32)> = kept
        .keys()
        .filter(|&&p| p < pos)
        .map(|&p| {
            let w = trace.attn_weight(pos, p) * rng.uniform(0.6, 1.4);
            (p, w as f32)
        })
        .collect();
    let z: f32 = attn.iter().map(|(_, a)| *a).sum::<f32>().max(1e-9);
    for (_, a) in &mut attn {
        *a /= z;
    }
    PosAttn { step: pos, attn }
}

/// Fraction of the ground-truth top-10 positions still retained.
fn recall10(trace: &Trace, kept: &Kept, pos: usize) -> f64 {
    let top = trace.top_k_positions(pos, 10);
    let hit = top.iter().filter(|p| kept.contains_key(p)).count();
    hit as f64 / top.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace::DatasetProfile;

    fn trace() -> Trace {
        Trace::generate(&DatasetProfile::aime(), 11, 0.25)
    }

    fn cfg(budget: usize) -> SimConfig {
        SimConfig { budget, seed: 3, stride: 4, rollouts: 64 }
    }

    #[test]
    fn fullkv_is_lossless() {
        let t = trace();
        let r = run_method(&t, &Method::FullKv, &cfg(usize::MAX));
        assert!((r.pass1 - t.dataset.base_acc).abs() < 0.15);
        assert_eq!(r.evict_events, 0);
        assert!((r.recall10 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn thinkv_beats_baselines_at_tight_budget() {
        // Full-length AIME trace (where the paper's separation appears):
        // at a 64-token budget baselines lose transition anchors and loop,
        // ThinKV's min-retention keeps the trajectory intact (Fig 8).
        let t = Trace::generate(&DatasetProfile::aime(), 12, 1.0);
        let budget = 64;
        let think = run_method(&t, &Method::ThinKv(ThinKvSim::default()), &cfg(budget));
        let rkv = run_method(&t, &Method::Evict(EvictKind::Rkv), &cfg(budget));
        let h2o = run_method(&t, &Method::Evict(EvictKind::H2O), &cfg(budget));
        let stream = run_method(&t, &Method::Evict(EvictKind::StreamingLlm), &cfg(budget));
        assert!(
            think.p_correct > rkv.p_correct + 0.05,
            "ThinKV {} vs R-KV {}",
            think.p_correct,
            rkv.p_correct
        );
        assert!(think.p_correct > h2o.p_correct + 0.05, "vs H2O");
        assert!(think.p_correct > stream.p_correct + 0.05, "vs StreamingLLM");
        // near-lossless: within 15% of base accuracy even at 64 tokens
        assert!(think.p_correct > t.dataset.base_acc * 0.85, "{}", think.p_correct);
        // at matched 1024-token budgets the hybrid uses ~4x less memory
        // than fp16 eviction (TBQ at ~3.4-4.4 bits)
        let think1k = run_method(&t, &Method::ThinKv(ThinKvSim::default()), &cfg(1024));
        let rkv1k = run_method(&t, &Method::Evict(EvictKind::Rkv), &cfg(1024));
        assert!(
            think1k.mem_frac < rkv1k.mem_frac * 0.5,
            "mem {} vs {}",
            think1k.mem_frac,
            rkv1k.mem_frac
        );
    }

    #[test]
    fn thinkv_recall_tracks_fullkv(){
        let t = trace();
        let think = run_method(&t, &Method::ThinKv(ThinKvSim::default()), &cfg(1024));
        let rkv = run_method(&t, &Method::Evict(EvictKind::Rkv), &cfg(1024));
        assert!(think.recall10 >= rkv.recall10 - 0.05, "{} vs {}", think.recall10, rkv.recall10);
        assert!(think.recall10 > 0.6, "{}", think.recall10);
    }

    #[test]
    fn kivi2_inflates_generation() {
        let t = trace();
        let k2 = run_method(&t, &Method::Kivi { prec: Precision::Ternary }, &cfg(1024));
        let k4 = run_method(&t, &Method::Kivi { prec: Precision::Nvfp4 }, &cfg(1024));
        let think = run_method(&t, &Method::ThinKv(ThinKvSim::default()), &cfg(1024));
        assert!(k2.len_inflation > 3.0, "{}", k2.len_inflation);
        assert!(k4.len_inflation < 1.6);
        assert!(think.len_inflation < 1.45, "{}", think.len_inflation);
        assert!(k2.pass1 < think.pass1);
    }

    #[test]
    fn thinkv_call_rate_far_below_rkv() {
        let t = trace();
        let think = run_method(&t, &Method::ThinKv(ThinKvSim::default()), &cfg(512));
        let rkv = run_method(&t, &Method::Evict(EvictKind::Rkv), &cfg(512));
        assert!(
            think.evict_call_rate < rkv.evict_call_rate * 0.4,
            "ThinKV {} vs R-KV {}",
            think.evict_call_rate,
            rkv.evict_call_rate
        );
        assert_eq!(think.gather_bytes_per_step, 0.0);
        assert!(rkv.gather_bytes_per_step > 0.0);
    }

    #[test]
    fn min_keep_zero_causes_loops() {
        let t = trace();
        let mut tk = ThinKvSim::default();
        tk.min_keep = 0;
        tk.retention = vec![64, 32, 16, 8, 0];
        let r = run_method(&t, &Method::ThinKv(tk), &cfg(128));
        let ok = run_method(&t, &Method::ThinKv(ThinKvSim::default()), &cfg(128));
        assert!(
            r.looped > 0.0 || r.pass1 < ok.pass1,
            "minR=0 should degrade: {} vs {}",
            r.pass1,
            ok.pass1
        );
    }

    #[test]
    fn avg_bits_in_paper_range() {
        let t = trace();
        let r = run_method(&t, &Method::ThinKv(ThinKvSim::default()), &cfg(1024));
        assert!(r.avg_bits > 2.2 && r.avg_bits < 6.0, "{}", r.avg_bits);
    }
}
