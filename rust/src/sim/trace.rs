//! LRM reasoning-trace simulator (data substitution, DESIGN §1).
//!
//! We cannot run R1-Llama-70B on AIME, but the paper tells us exactly which
//! statistics of those runs its method depends on:
//!
//! * CoT = thought segments of ~100–300 tokens (§4.1) with dataset-specific
//!   R/E/T mixes (Fig 10f) and mean generation lengths (§6.2).
//! * Attention sparsity per thought is tri-modal: T ≈ 0.85 > R ≈ 0.55 >
//!   E ≈ 0.25 (Fig 3 / Obs 1b).
//! * Counterfactual importance: R > E > T, with ~10% outlier T anchors
//!   (backtracking) of very high importance (Fig 4 / Obs 2, §E.17).
//! * Association decays with every transition between segments (Fig 5 /
//!   Obs 3); E thoughts depend strongly on the context bounded by
//!   transitions.
//!
//! The generator reproduces those statistics; everything downstream
//! (classifier, TBE, baselines, oracle) consumes only such statistics, so
//! curve *shapes* transfer.

use crate::kvcache::Thought;
use crate::util::rng::Rng;

/// Dataset workload profile.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub mean_gen_len: usize,
    /// (R, E, T) segment-type probabilities after the current segment.
    pub mix: [f64; 3],
    /// Mean segment length in tokens.
    pub seg_len_mean: f64,
    /// Base pass@1 accuracy of the uncompressed model (per-model scaling is
    /// applied by the harness).
    pub base_acc: f64,
    /// Probability a transition segment is a high-importance anchor.
    pub t_anchor_prob: f64,
    pub prompt_len: usize,
}

impl DatasetProfile {
    pub fn aime() -> DatasetProfile {
        DatasetProfile {
            name: "AIME",
            mean_gen_len: 9020,
            mix: [0.40, 0.33, 0.27], // R, E, T — complex: many transitions
            seg_len_mean: 160.0,
            base_acc: 0.50,
            t_anchor_prob: 0.30,
            prompt_len: 64,
        }
    }

    pub fn livecodebench() -> DatasetProfile {
        DatasetProfile {
            name: "LiveCodeBench",
            mean_gen_len: 14166,
            mix: [0.34, 0.46, 0.20],
            seg_len_mean: 190.0,
            base_acc: 0.48,
            t_anchor_prob: 0.25,
            prompt_len: 64,
        }
    }

    pub fn math500() -> DatasetProfile {
        DatasetProfile {
            name: "MATH-500",
            mean_gen_len: 2468,
            mix: [0.42, 0.45, 0.13], // simpler: few transitions (Fig 10f)
            seg_len_mean: 150.0,
            base_acc: 0.90,
            t_anchor_prob: 0.20,
            prompt_len: 64,
        }
    }

    pub fn gsm8k() -> DatasetProfile {
        DatasetProfile {
            name: "GSM8K",
            mean_gen_len: 1500,
            mix: [0.40, 0.48, 0.12],
            seg_len_mean: 120.0,
            base_acc: 0.675,
            t_anchor_prob: 0.18,
            prompt_len: 48,
        }
    }

    pub fn longwriter() -> DatasetProfile {
        // LLM long-response generalization (§E.10): |T| = 1 — uniform
        // "reasoning" statistics, no transitions.
        DatasetProfile {
            name: "LongWriter",
            mean_gen_len: 6000,
            mix: [1.0, 0.0, 0.0],
            seg_len_mean: 200.0,
            base_acc: 0.665,
            t_anchor_prob: 0.0,
            prompt_len: 64,
        }
    }

    pub fn by_name(name: &str) -> Option<DatasetProfile> {
        match name.to_ascii_lowercase().as_str() {
            "aime" => Some(Self::aime()),
            "livecodebench" | "lcb" => Some(Self::livecodebench()),
            "math500" | "math-500" => Some(Self::math500()),
            "gsm8k" => Some(Self::gsm8k()),
            "longwriter" => Some(Self::longwriter()),
            _ => None,
        }
    }
}

/// Sparsity emission parameters per thought (Obs 1b regimes).
pub fn sparsity_mean(t: Thought) -> f64 {
    match t {
        Thought::Execution => 0.25,
        Thought::Reasoning => 0.55,
        Thought::Transition => 0.85,
    }
}

/// One simulated thought segment.
#[derive(Debug, Clone)]
pub struct TraceSegment {
    pub id: usize,
    pub thought: Thought,
    pub start: usize,
    pub len: usize,
    /// Counterfactual importance weight (Obs 2 hierarchy).
    pub importance: f64,
    /// High-importance transition anchor (backtracking, §E.17).
    pub anchor: bool,
    /// Per-token info weights (sum 1): a few tokens carry most information.
    pub token_info: Vec<f64>,
}

impl TraceSegment {
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// A full simulated CoT generation.
#[derive(Debug, Clone)]
pub struct Trace {
    pub dataset: DatasetProfile,
    pub segments: Vec<TraceSegment>,
    pub gen_len: usize,
    pub prompt_len: usize,
    /// Per-token thought labels (prompt tokens = Reasoning per §6.1).
    pub token_thought: Vec<Thought>,
    /// Per-token, per-layer-band sparsity emissions for the classifier.
    pub sparsity: Vec<f64>,
    pub seed: u64,
}

impl Trace {
    /// Generate a trace. `len_scale` shrinks generation lengths for cheap
    /// benching (documented: budgets stay absolute, shapes preserved).
    pub fn generate(dataset: &DatasetProfile, seed: u64, len_scale: f64) -> Trace {
        let mut rng = Rng::new(seed);
        let target: usize =
            ((dataset.mean_gen_len as f64 * len_scale * rng.uniform(0.75, 1.3)) as usize).max(256);
        let mut segments = Vec::new();
        let mut token_thought = vec![Thought::Reasoning; dataset.prompt_len];
        let mut sparsity = Vec::with_capacity(dataset.prompt_len + target);
        for _ in 0..dataset.prompt_len {
            sparsity.push(rng.normal_with(sparsity_mean(Thought::Reasoning), 0.05).clamp(0.0, 1.0));
        }

        // prompt pseudo-segment
        segments.push(TraceSegment {
            id: 0,
            thought: Thought::Reasoning,
            start: 0,
            len: dataset.prompt_len,
            importance: 0.9,
            anchor: false,
            token_info: dirichlet_like(&mut rng, dataset.prompt_len),
        });

        let mut pos = dataset.prompt_len;
        let mut prev = Thought::Reasoning;
        while pos < dataset.prompt_len + target {
            let thought = sample_thought(&mut rng, dataset, prev);
            let len = rng.seg_len(dataset.seg_len_mean, 48, 320)
                .min(dataset.prompt_len + target - pos)
                .max(16);
            let anchor = thought == Thought::Transition && rng.chance(dataset.t_anchor_prob);
            let importance = match thought {
                // Obs 2: R > E > T, anchors override
                Thought::Reasoning => rng.uniform(0.55, 0.95),
                Thought::Execution => rng.uniform(0.3, 0.65),
                Thought::Transition => {
                    if anchor {
                        rng.uniform(0.75, 1.0)
                    } else {
                        rng.uniform(0.02, 0.2)
                    }
                }
            };
            segments.push(TraceSegment {
                id: segments.len(),
                thought,
                start: pos,
                len,
                importance,
                anchor,
                token_info: dirichlet_like(&mut rng, len),
            });
            for _ in 0..len {
                token_thought.push(thought);
                sparsity.push(
                    rng.normal_with(sparsity_mean(thought), 0.045).clamp(0.0, 1.0),
                );
            }
            pos += len;
            prev = thought;
        }
        let gen_len = pos - dataset.prompt_len;
        Trace {
            dataset: dataset.clone(),
            segments,
            gen_len,
            prompt_len: dataset.prompt_len,
            token_thought,
            sparsity,
            seed,
        }
    }

    pub fn total_len(&self) -> usize {
        self.prompt_len + self.gen_len
    }

    /// Segment containing token `pos`.
    pub fn segment_of(&self, pos: usize) -> &TraceSegment {
        let i = self
            .segments
            .partition_point(|s| s.end() <= pos)
            .min(self.segments.len() - 1);
        &self.segments[i]
    }

    /// Transitions between segment `i` and the segment active at `pos`.
    pub fn transitions_between(&self, seg: usize, pos: usize) -> usize {
        self.segments
            .iter()
            .filter(|s| {
                s.thought == Thought::Transition && s.start >= self.segments[seg].end() && s.end() <= pos
            })
            .count()
    }

    /// Ground-truth attention weight of token `j` for the query at `pos`
    /// (un-normalized): token info × segment importance × association decay
    /// across transitions (Obs 3), with locality bonus inside the current
    /// segment.
    pub fn attn_weight(&self, pos: usize, j: usize) -> f64 {
        debug_assert!(j < pos);
        let sj = self.segment_of(j);
        let cur = self.segment_of(pos);
        let info = sj.token_info[j - sj.start] * sj.len as f64; // ~O(1) scale
        if sj.id == cur.id {
            // strong local attention within the active segment
            return info * 1.2 + 0.4;
        }
        let hops = self.transitions_between(sj.id, pos) as f64;
        let decay = 0.55_f64.powf(hops);
        let anchor_boost = if sj.anchor { 2.5 } else { 1.0 };
        (info * sj.importance * anchor_boost) * decay + 0.01
    }

    /// Ground-truth top-k important positions for the query at `pos`
    /// (recall-rate experiments, Fig 10a).
    pub fn top_k_positions(&self, pos: usize, k: usize) -> Vec<usize> {
        let mut w: Vec<(f64, usize)> =
            (0..pos).map(|j| (self.attn_weight(pos, j), j)).collect();
        w.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        w.truncate(k);
        w.into_iter().map(|(_, j)| j).collect()
    }

    /// Percentage thought breakdown over generated tokens (Fig 10f).
    pub fn thought_breakdown(&self) -> [f64; 3] {
        let mut counts = [0usize; 3];
        for &t in &self.token_thought[self.prompt_len..] {
            counts[t as usize] += 1;
        }
        let n = self.gen_len.max(1) as f64;
        // order: R, E, T for reporting
        [
            counts[Thought::Reasoning as usize] as f64 / n * 100.0,
            counts[Thought::Execution as usize] as f64 / n * 100.0,
            counts[Thought::Transition as usize] as f64 / n * 100.0,
        ]
    }
}

/// Thought transition kernel: segments tend to alternate R->E, transitions
/// arrive per the dataset mix, and a transition is followed by reasoning
/// (backtracking re-plans) more often than execution.
fn sample_thought(rng: &mut Rng, d: &DatasetProfile, prev: Thought) -> Thought {
    if d.mix[2] == 0.0 && d.mix[1] == 0.0 {
        return Thought::Reasoning; // LLM mode (|T| = 1)
    }
    let w = match prev {
        Thought::Reasoning => [d.mix[0] * 0.5, d.mix[1] * 1.8, d.mix[2]],
        Thought::Execution => [d.mix[0] * 1.5, d.mix[1] * 0.6, d.mix[2] * 1.3],
        Thought::Transition => [d.mix[0] * 2.2, d.mix[1] * 0.7, d.mix[2] * 0.2],
    };
    match rng.weighted(&w) {
        0 => Thought::Reasoning,
        1 => Thought::Execution,
        _ => Thought::Transition,
    }
}

/// Heavy-tailed per-token info weights summing to 1 (a few tokens carry
/// most of a segment's information).
fn dirichlet_like(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut w: Vec<f64> = (0..n)
        .map(|_| {
            let u = rng.f64().max(1e-9);
            // ~Pareto tail
            if rng.chance(0.1) {
                3.0 + 8.0 * u
            } else {
                u
            }
        })
        .collect();
    let total: f64 = w.iter().sum();
    for x in &mut w {
        *x /= total;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_covers_target_length() {
        let t = Trace::generate(&DatasetProfile::aime(), 1, 0.25);
        assert!(t.gen_len >= 256);
        assert_eq!(t.token_thought.len(), t.total_len());
        assert_eq!(t.sparsity.len(), t.total_len());
        assert_eq!(
            t.segments.iter().map(|s| s.len).sum::<usize>(),
            t.total_len()
        );
    }

    #[test]
    fn segments_are_contiguous() {
        let t = Trace::generate(&DatasetProfile::livecodebench(), 2, 0.1);
        for w in t.segments.windows(2) {
            assert_eq!(w[0].end(), w[1].start);
        }
        // segment_of agrees
        for pos in [0, t.prompt_len, t.total_len() / 2, t.total_len() - 1] {
            let s = t.segment_of(pos);
            assert!(s.start <= pos && pos < s.end());
        }
    }

    #[test]
    fn sparsity_is_trimodal_by_thought() {
        let t = Trace::generate(&DatasetProfile::aime(), 3, 0.3);
        let mut by = std::collections::BTreeMap::new();
        for (i, &th) in t.token_thought.iter().enumerate() {
            by.entry(th as usize).or_insert_with(Vec::new).push(t.sparsity[i]);
        }
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let e = mean(&by[&(Thought::Execution as usize)]);
        let r = mean(&by[&(Thought::Reasoning as usize)]);
        let tt = mean(&by[&(Thought::Transition as usize)]);
        assert!(e < r && r < tt, "E={e} R={r} T={tt}");
    }

    #[test]
    fn importance_hierarchy_holds_in_expectation() {
        let t = Trace::generate(&DatasetProfile::aime(), 4, 0.5);
        let avg = |th: Thought| {
            let v: Vec<f64> = t
                .segments
                .iter()
                .filter(|s| s.thought == th && !s.anchor)
                .map(|s| s.importance)
                .collect();
            v.iter().sum::<f64>() / v.len().max(1) as f64
        };
        assert!(avg(Thought::Reasoning) > avg(Thought::Execution));
        assert!(avg(Thought::Execution) > avg(Thought::Transition));
    }

    #[test]
    fn association_decays_across_transitions() {
        let t = Trace::generate(&DatasetProfile::aime(), 5, 0.4);
        // find a segment with >= 2 transitions after it
        let pos = t.total_len() - 1;
        let early = &t.segments[1];
        let late = t.segment_of(pos.saturating_sub(40));
        if t.transitions_between(early.id, pos) >= 2 && late.id != t.segment_of(pos).id {
            let w_early: f64 = (early.start..early.end()).map(|j| t.attn_weight(pos, j)).sum();
            let w_late: f64 = (late.start..late.end().min(pos))
                .map(|j| t.attn_weight(pos, j))
                .sum();
            assert!(
                w_late > w_early * 0.8,
                "older-with-transitions should not dominate: early={w_early} late={w_late}"
            );
        }
    }

    #[test]
    fn aime_has_more_transitions_than_math() {
        let a: f64 = (0..5)
            .map(|s| Trace::generate(&DatasetProfile::aime(), s, 0.3).thought_breakdown()[2])
            .sum::<f64>()
            / 5.0;
        let m: f64 = (0..5)
            .map(|s| Trace::generate(&DatasetProfile::math500(), s, 0.3).thought_breakdown()[2])
            .sum::<f64>()
            / 5.0;
        assert!(a > m, "AIME T% {a} vs MATH T% {m}");
    }

    #[test]
    fn token_info_sums_to_one() {
        let t = Trace::generate(&DatasetProfile::math500(), 6, 0.2);
        for s in &t.segments {
            let total: f64 = s.token_info.iter().sum();
            assert!((total - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn llm_mode_is_single_thought() {
        let t = Trace::generate(&DatasetProfile::longwriter(), 7, 0.2);
        assert!(t.token_thought.iter().all(|&x| x == Thought::Reasoning));
    }
}
