//! LRM reasoning-trace simulator (data substitution, DESIGN §1).
//!
//! We cannot run R1-Llama-70B on AIME, but the paper tells us exactly which
//! statistics of those runs its method depends on:
//!
//! * CoT = thought segments of ~100–300 tokens (§4.1) with dataset-specific
//!   R/E/T mixes (Fig 10f) and mean generation lengths (§6.2).
//! * Attention sparsity per thought is tri-modal: T ≈ 0.85 > R ≈ 0.55 >
//!   E ≈ 0.25 (Fig 3 / Obs 1b).
//! * Counterfactual importance: R > E > T, with ~10% outlier T anchors
//!   (backtracking) of very high importance (Fig 4 / Obs 2, §E.17).
//! * Association decays with every transition between segments (Fig 5 /
//!   Obs 3); E thoughts depend strongly on the context bounded by
//!   transitions.
//!
//! The generator reproduces those statistics; everything downstream
//! (classifier, TBE, baselines, oracle) consumes only such statistics, so
//! curve *shapes* transfer.

use crate::coordinator::SloTarget;
use crate::kvcache::Thought;
use crate::util::rng::Rng;

/// Dataset workload profile.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub mean_gen_len: usize,
    /// (R, E, T) segment-type probabilities after the current segment.
    pub mix: [f64; 3],
    /// Mean segment length in tokens.
    pub seg_len_mean: f64,
    /// Base pass@1 accuracy of the uncompressed model (per-model scaling is
    /// applied by the harness).
    pub base_acc: f64,
    /// Probability a transition segment is a high-importance anchor.
    pub t_anchor_prob: f64,
    pub prompt_len: usize,
}

impl DatasetProfile {
    pub fn aime() -> DatasetProfile {
        DatasetProfile {
            name: "AIME",
            mean_gen_len: 9020,
            mix: [0.40, 0.33, 0.27], // R, E, T — complex: many transitions
            seg_len_mean: 160.0,
            base_acc: 0.50,
            t_anchor_prob: 0.30,
            prompt_len: 64,
        }
    }

    pub fn livecodebench() -> DatasetProfile {
        DatasetProfile {
            name: "LiveCodeBench",
            mean_gen_len: 14166,
            mix: [0.34, 0.46, 0.20],
            seg_len_mean: 190.0,
            base_acc: 0.48,
            t_anchor_prob: 0.25,
            prompt_len: 64,
        }
    }

    pub fn math500() -> DatasetProfile {
        DatasetProfile {
            name: "MATH-500",
            mean_gen_len: 2468,
            mix: [0.42, 0.45, 0.13], // simpler: few transitions (Fig 10f)
            seg_len_mean: 150.0,
            base_acc: 0.90,
            t_anchor_prob: 0.20,
            prompt_len: 64,
        }
    }

    pub fn gsm8k() -> DatasetProfile {
        DatasetProfile {
            name: "GSM8K",
            mean_gen_len: 1500,
            mix: [0.40, 0.48, 0.12],
            seg_len_mean: 120.0,
            base_acc: 0.675,
            t_anchor_prob: 0.18,
            prompt_len: 48,
        }
    }

    pub fn longwriter() -> DatasetProfile {
        // LLM long-response generalization (§E.10): |T| = 1 — uniform
        // "reasoning" statistics, no transitions.
        DatasetProfile {
            name: "LongWriter",
            mean_gen_len: 6000,
            mix: [1.0, 0.0, 0.0],
            seg_len_mean: 200.0,
            base_acc: 0.665,
            t_anchor_prob: 0.0,
            prompt_len: 64,
        }
    }

    pub fn by_name(name: &str) -> Option<DatasetProfile> {
        match name.to_ascii_lowercase().as_str() {
            "aime" => Some(Self::aime()),
            "livecodebench" | "lcb" => Some(Self::livecodebench()),
            "math500" | "math-500" => Some(Self::math500()),
            "gsm8k" => Some(Self::gsm8k()),
            "longwriter" => Some(Self::longwriter()),
            _ => None,
        }
    }
}

/// Sparsity emission parameters per thought (Obs 1b regimes).
pub fn sparsity_mean(t: Thought) -> f64 {
    match t {
        Thought::Execution => 0.25,
        Thought::Reasoning => 0.55,
        Thought::Transition => 0.85,
    }
}

/// One simulated thought segment.
#[derive(Debug, Clone)]
pub struct TraceSegment {
    pub id: usize,
    pub thought: Thought,
    pub start: usize,
    pub len: usize,
    /// Counterfactual importance weight (Obs 2 hierarchy).
    pub importance: f64,
    /// High-importance transition anchor (backtracking, §E.17).
    pub anchor: bool,
    /// Per-token info weights (sum 1): a few tokens carry most information.
    pub token_info: Vec<f64>,
}

impl TraceSegment {
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// A full simulated CoT generation.
#[derive(Debug, Clone)]
pub struct Trace {
    pub dataset: DatasetProfile,
    pub segments: Vec<TraceSegment>,
    pub gen_len: usize,
    pub prompt_len: usize,
    /// Per-token thought labels (prompt tokens = Reasoning per §6.1).
    pub token_thought: Vec<Thought>,
    /// Per-token, per-layer-band sparsity emissions for the classifier.
    pub sparsity: Vec<f64>,
    pub seed: u64,
}

impl Trace {
    /// Generate a trace. `len_scale` shrinks generation lengths for cheap
    /// benching (documented: budgets stay absolute, shapes preserved).
    pub fn generate(dataset: &DatasetProfile, seed: u64, len_scale: f64) -> Trace {
        let mut rng = Rng::new(seed);
        let target: usize =
            ((dataset.mean_gen_len as f64 * len_scale * rng.uniform(0.75, 1.3)) as usize).max(256);
        let mut segments = Vec::new();
        let mut token_thought = vec![Thought::Reasoning; dataset.prompt_len];
        let mut sparsity = Vec::with_capacity(dataset.prompt_len + target);
        for _ in 0..dataset.prompt_len {
            sparsity.push(rng.normal_with(sparsity_mean(Thought::Reasoning), 0.05).clamp(0.0, 1.0));
        }

        // prompt pseudo-segment
        segments.push(TraceSegment {
            id: 0,
            thought: Thought::Reasoning,
            start: 0,
            len: dataset.prompt_len,
            importance: 0.9,
            anchor: false,
            token_info: dirichlet_like(&mut rng, dataset.prompt_len),
        });

        let mut pos = dataset.prompt_len;
        let mut prev = Thought::Reasoning;
        while pos < dataset.prompt_len + target {
            let thought = sample_thought(&mut rng, dataset, prev);
            let len = rng.seg_len(dataset.seg_len_mean, 48, 320)
                .min(dataset.prompt_len + target - pos)
                .max(16);
            let anchor = thought == Thought::Transition && rng.chance(dataset.t_anchor_prob);
            let importance = match thought {
                // Obs 2: R > E > T, anchors override
                Thought::Reasoning => rng.uniform(0.55, 0.95),
                Thought::Execution => rng.uniform(0.3, 0.65),
                Thought::Transition => {
                    if anchor {
                        rng.uniform(0.75, 1.0)
                    } else {
                        rng.uniform(0.02, 0.2)
                    }
                }
            };
            segments.push(TraceSegment {
                id: segments.len(),
                thought,
                start: pos,
                len,
                importance,
                anchor,
                token_info: dirichlet_like(&mut rng, len),
            });
            for _ in 0..len {
                token_thought.push(thought);
                sparsity.push(
                    rng.normal_with(sparsity_mean(thought), 0.045).clamp(0.0, 1.0),
                );
            }
            pos += len;
            prev = thought;
        }
        let gen_len = pos - dataset.prompt_len;
        Trace {
            dataset: dataset.clone(),
            segments,
            gen_len,
            prompt_len: dataset.prompt_len,
            token_thought,
            sparsity,
            seed,
        }
    }

    pub fn total_len(&self) -> usize {
        self.prompt_len + self.gen_len
    }

    /// Segment containing token `pos`.
    pub fn segment_of(&self, pos: usize) -> &TraceSegment {
        let i = self
            .segments
            .partition_point(|s| s.end() <= pos)
            .min(self.segments.len() - 1);
        &self.segments[i]
    }

    /// Transitions between segment `i` and the segment active at `pos`.
    pub fn transitions_between(&self, seg: usize, pos: usize) -> usize {
        self.segments
            .iter()
            .filter(|s| {
                s.thought == Thought::Transition && s.start >= self.segments[seg].end() && s.end() <= pos
            })
            .count()
    }

    /// Ground-truth attention weight of token `j` for the query at `pos`
    /// (un-normalized): token info × segment importance × association decay
    /// across transitions (Obs 3), with locality bonus inside the current
    /// segment.
    pub fn attn_weight(&self, pos: usize, j: usize) -> f64 {
        debug_assert!(j < pos);
        let sj = self.segment_of(j);
        let cur = self.segment_of(pos);
        let info = sj.token_info[j - sj.start] * sj.len as f64; // ~O(1) scale
        if sj.id == cur.id {
            // strong local attention within the active segment
            return info * 1.2 + 0.4;
        }
        let hops = self.transitions_between(sj.id, pos) as f64;
        let decay = 0.55_f64.powf(hops);
        let anchor_boost = if sj.anchor { 2.5 } else { 1.0 };
        (info * sj.importance * anchor_boost) * decay + 0.01
    }

    /// Ground-truth top-k important positions for the query at `pos`
    /// (recall-rate experiments, Fig 10a).
    pub fn top_k_positions(&self, pos: usize, k: usize) -> Vec<usize> {
        let mut w: Vec<(f64, usize)> =
            (0..pos).map(|j| (self.attn_weight(pos, j), j)).collect();
        w.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        w.truncate(k);
        w.into_iter().map(|(_, j)| j).collect()
    }

    /// Percentage thought breakdown over generated tokens (Fig 10f).
    pub fn thought_breakdown(&self) -> [f64; 3] {
        let mut counts = [0usize; 3];
        for &t in &self.token_thought[self.prompt_len..] {
            counts[t as usize] += 1;
        }
        let n = self.gen_len.max(1) as f64;
        // order: R, E, T for reporting
        [
            counts[Thought::Reasoning as usize] as f64 / n * 100.0,
            counts[Thought::Execution as usize] as f64 / n * 100.0,
            counts[Thought::Transition as usize] as f64 / n * 100.0,
        ]
    }
}

/// Thought transition kernel: segments tend to alternate R->E, transitions
/// arrive per the dataset mix, and a transition is followed by reasoning
/// (backtracking re-plans) more often than execution.
fn sample_thought(rng: &mut Rng, d: &DatasetProfile, prev: Thought) -> Thought {
    if d.mix[2] == 0.0 && d.mix[1] == 0.0 {
        return Thought::Reasoning; // LLM mode (|T| = 1)
    }
    let w = match prev {
        Thought::Reasoning => [d.mix[0] * 0.5, d.mix[1] * 1.8, d.mix[2]],
        Thought::Execution => [d.mix[0] * 1.5, d.mix[1] * 0.6, d.mix[2] * 1.3],
        Thought::Transition => [d.mix[0] * 2.2, d.mix[1] * 0.7, d.mix[2] * 0.2],
    };
    match rng.weighted(&w) {
        0 => Thought::Reasoning,
        1 => Thought::Execution,
        _ => Thought::Transition,
    }
}

/// Heavy-tailed per-token info weights summing to 1 (a few tokens carry
/// most of a segment's information).
fn dirichlet_like(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut w: Vec<f64> = (0..n)
        .map(|_| {
            let u = rng.f64().max(1e-9);
            // ~Pareto tail
            if rng.chance(0.1) {
                3.0 + 8.0 * u
            } else {
                u
            }
        })
        .collect();
    let total: f64 = w.iter().sum();
    for x in &mut w {
        *x /= total;
    }
    w
}

// ---------------------------------------------------------------------------
// Multi-tenant arrival traces (ISSUE 7)
// ---------------------------------------------------------------------------

/// One tenant class in a multi-tenant arrival trace: a [`DatasetProfile`]
/// (the session *shape* — long-CoT math, coding, short chat), a shared
/// system prompt every session in the class opens with, an arrival
/// process (seeded Poisson plus optional periodic bursts), and the
/// per-class [`SloTarget`] the scheduler scores completions against.
#[derive(Debug, Clone)]
pub struct TenantClass {
    pub name: &'static str,
    /// Workload shape this class draws from (R-KV / ThinKV eval mixes).
    pub dataset: DatasetProfile,
    /// Shared system-prompt length in tokens: every session in the
    /// class starts with the same class-specific token prefix (the
    /// prefix-sharing workload shape).
    pub system_prompt_len: usize,
    /// Per-session private prompt tail length in tokens.
    pub tail_len: usize,
    pub max_new_tokens: usize,
    /// Mean Poisson arrival rate, arrivals per tick (0 = bursts only).
    pub rate: f64,
    /// Every `burst_every` ticks, `burst_size` extra arrivals land on
    /// the same tick (0 = no bursts; the Poisson process alone).
    pub burst_every: u64,
    pub burst_size: usize,
    /// TTFT/TPOT target for the class (ticks; 0 halves disabled).
    pub slo: SloTarget,
}

impl TenantClass {
    /// Short interactive chat: tiny prompts, short generations, tight
    /// TTFT — the latency-sensitive tenant.
    pub fn chat() -> TenantClass {
        TenantClass {
            name: "chat",
            dataset: DatasetProfile::gsm8k(),
            system_prompt_len: 16,
            tail_len: 8,
            max_new_tokens: 8,
            rate: 0.004,
            burst_every: 400,
            burst_size: 3,
            slo: SloTarget::new(250, 100_000),
        }
    }

    /// Long-CoT math reasoning: long prompts and very long generations,
    /// throughput-oriented (generous TTFT, bounded TPOT).
    pub fn math() -> TenantClass {
        TenantClass {
            name: "math",
            dataset: DatasetProfile::aime(),
            system_prompt_len: 48,
            tail_len: 16,
            max_new_tokens: 64,
            rate: 0.002,
            burst_every: 0,
            burst_size: 0,
            slo: SloTarget::new(4_000, 400_000),
        }
    }

    /// Coding: long prompts, medium generations, intermediate targets.
    pub fn coding() -> TenantClass {
        TenantClass {
            name: "coding",
            dataset: DatasetProfile::livecodebench(),
            system_prompt_len: 32,
            tail_len: 16,
            max_new_tokens: 32,
            rate: 0.003,
            burst_every: 0,
            burst_size: 0,
            slo: SloTarget::new(2_000, 250_000),
        }
    }

    /// Resolve a builtin class by name (the `--slo-class` CLI values).
    pub fn by_name(name: &str) -> Option<TenantClass> {
        match name.to_ascii_lowercase().as_str() {
            "chat" => Some(Self::chat()),
            "math" => Some(Self::math()),
            "coding" | "code" => Some(Self::coding()),
            _ => None,
        }
    }
}

/// One arrival in the merged multi-tenant stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrivalEvent {
    /// Arrival tick (deterministic logical time).
    pub at: u64,
    /// Index into the class list the trace was generated from.
    pub class_id: usize,
    pub class_name: &'static str,
    /// Session id, assigned in merged arrival order (1-based).
    pub id: u64,
    /// Prompt tokens: the class's shared system prefix + a private tail.
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub slo: SloTarget,
}

/// A deterministic multi-tenant arrival trace: the merged, time-sorted
/// stream of [`ArrivalEvent`]s drawn from a set of [`TenantClass`]es.
/// Same `(classes, seed, horizon, vocab)` → byte-identical trace; each
/// class draws from its own forked PRNG stream, so adding a class never
/// perturbs the arrivals of the others.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrivalTrace {
    pub seed: u64,
    pub horizon: u64,
    pub events: Vec<ArrivalEvent>,
    /// Sessions generated per class (index-aligned with the class list).
    pub per_class: Vec<usize>,
}

impl ArrivalTrace {
    /// Generate the merged arrival stream over `[0, horizon)` ticks.
    /// Poisson gaps are sampled as `-ln(U)/rate` per class; bursts land
    /// `burst_size` arrivals on every `burst_every` tick boundary. All
    /// prompt tokens are drawn below `vocab`.
    pub fn generate(
        classes: &[TenantClass],
        seed: u64,
        horizon: u64,
        vocab: usize,
    ) -> ArrivalTrace {
        let mut root = Rng::new(seed);
        // (at, class_id, prompt, class) in per-class generation order;
        // the stable sort below keeps that order inside a tick.
        let mut raw: Vec<(u64, usize, Vec<i32>)> = Vec::new();
        let mut per_class = vec![0usize; classes.len()];
        for (ci, c) in classes.iter().enumerate() {
            let mut rng = root.fork(ci as u64 + 1);
            let system: Vec<i32> =
                (0..c.system_prompt_len).map(|_| rng.below(vocab.max(1)) as i32).collect();
            let mut mk_prompt = |rng: &mut Rng| -> Vec<i32> {
                let mut p = system.clone();
                p.extend((0..c.tail_len).map(|_| rng.below(vocab.max(1)) as i32));
                p
            };
            // Poisson process: exponential inter-arrival gaps
            if c.rate > 0.0 {
                let mut t = 0.0f64;
                loop {
                    let u = rng.f64().max(1e-12);
                    t += -u.ln() / c.rate;
                    if t >= horizon as f64 {
                        break;
                    }
                    let prompt = mk_prompt(&mut rng);
                    raw.push((t as u64, ci, prompt));
                    per_class[ci] += 1;
                }
            }
            // periodic bursts: a cluster on the same tick
            if c.burst_every > 0 && c.burst_size > 0 {
                let mut bt = c.burst_every;
                while bt < horizon {
                    for _ in 0..c.burst_size {
                        let prompt = mk_prompt(&mut rng);
                        raw.push((bt, ci, prompt));
                        per_class[ci] += 1;
                    }
                    bt += c.burst_every;
                }
            }
        }
        raw.sort_by_key(|(at, ci, _)| (*at, *ci));
        let events = raw
            .into_iter()
            .enumerate()
            .map(|(i, (at, ci, prompt))| ArrivalEvent {
                at,
                class_id: ci,
                class_name: classes[ci].name,
                id: i as u64 + 1,
                prompt,
                max_new_tokens: classes[ci].max_new_tokens,
                slo: classes[ci].slo,
            })
            .collect();
        ArrivalTrace { seed, horizon, events, per_class }
    }

    /// FNV-1a digest over the full arrival stream (ticks, class ids,
    /// prompt bytes, budgets, targets) — a one-number determinism
    /// witness for golden tests and bench output.
    pub fn digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |h: &mut u64, x: u64| {
            for b in x.to_le_bytes() {
                *h ^= u64::from(b);
                *h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        for e in &self.events {
            eat(&mut h, e.at);
            eat(&mut h, e.class_id as u64);
            eat(&mut h, e.id);
            eat(&mut h, e.max_new_tokens as u64);
            eat(&mut h, e.slo.ttft_ticks);
            eat(&mut h, e.slo.tpot_milli_ticks);
            for &t in &e.prompt {
                eat(&mut h, t as u64);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_covers_target_length() {
        let t = Trace::generate(&DatasetProfile::aime(), 1, 0.25);
        assert!(t.gen_len >= 256);
        assert_eq!(t.token_thought.len(), t.total_len());
        assert_eq!(t.sparsity.len(), t.total_len());
        assert_eq!(
            t.segments.iter().map(|s| s.len).sum::<usize>(),
            t.total_len()
        );
    }

    #[test]
    fn segments_are_contiguous() {
        let t = Trace::generate(&DatasetProfile::livecodebench(), 2, 0.1);
        for w in t.segments.windows(2) {
            assert_eq!(w[0].end(), w[1].start);
        }
        // segment_of agrees
        for pos in [0, t.prompt_len, t.total_len() / 2, t.total_len() - 1] {
            let s = t.segment_of(pos);
            assert!(s.start <= pos && pos < s.end());
        }
    }

    #[test]
    fn sparsity_is_trimodal_by_thought() {
        let t = Trace::generate(&DatasetProfile::aime(), 3, 0.3);
        let mut by = std::collections::BTreeMap::new();
        for (i, &th) in t.token_thought.iter().enumerate() {
            by.entry(th as usize).or_insert_with(Vec::new).push(t.sparsity[i]);
        }
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let e = mean(&by[&(Thought::Execution as usize)]);
        let r = mean(&by[&(Thought::Reasoning as usize)]);
        let tt = mean(&by[&(Thought::Transition as usize)]);
        assert!(e < r && r < tt, "E={e} R={r} T={tt}");
    }

    #[test]
    fn importance_hierarchy_holds_in_expectation() {
        let t = Trace::generate(&DatasetProfile::aime(), 4, 0.5);
        let avg = |th: Thought| {
            let v: Vec<f64> = t
                .segments
                .iter()
                .filter(|s| s.thought == th && !s.anchor)
                .map(|s| s.importance)
                .collect();
            v.iter().sum::<f64>() / v.len().max(1) as f64
        };
        assert!(avg(Thought::Reasoning) > avg(Thought::Execution));
        assert!(avg(Thought::Execution) > avg(Thought::Transition));
    }

    #[test]
    fn association_decays_across_transitions() {
        let t = Trace::generate(&DatasetProfile::aime(), 5, 0.4);
        // find a segment with >= 2 transitions after it
        let pos = t.total_len() - 1;
        let early = &t.segments[1];
        let late = t.segment_of(pos.saturating_sub(40));
        if t.transitions_between(early.id, pos) >= 2 && late.id != t.segment_of(pos).id {
            let w_early: f64 = (early.start..early.end()).map(|j| t.attn_weight(pos, j)).sum();
            let w_late: f64 = (late.start..late.end().min(pos))
                .map(|j| t.attn_weight(pos, j))
                .sum();
            assert!(
                w_late > w_early * 0.8,
                "older-with-transitions should not dominate: early={w_early} late={w_late}"
            );
        }
    }

    #[test]
    fn aime_has_more_transitions_than_math() {
        let a: f64 = (0..5)
            .map(|s| Trace::generate(&DatasetProfile::aime(), s, 0.3).thought_breakdown()[2])
            .sum::<f64>()
            / 5.0;
        let m: f64 = (0..5)
            .map(|s| Trace::generate(&DatasetProfile::math500(), s, 0.3).thought_breakdown()[2])
            .sum::<f64>()
            / 5.0;
        assert!(a > m, "AIME T% {a} vs MATH T% {m}");
    }

    #[test]
    fn token_info_sums_to_one() {
        let t = Trace::generate(&DatasetProfile::math500(), 6, 0.2);
        for s in &t.segments {
            let total: f64 = s.token_info.iter().sum();
            assert!((total - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn llm_mode_is_single_thought() {
        let t = Trace::generate(&DatasetProfile::longwriter(), 7, 0.2);
        assert!(t.token_thought.iter().all(|&x| x == Thought::Reasoning));
    }

    fn mix() -> Vec<TenantClass> {
        vec![TenantClass::chat(), TenantClass::math(), TenantClass::coding()]
    }

    #[test]
    fn arrival_trace_is_seed_deterministic() {
        let a = ArrivalTrace::generate(&mix(), 11, 4_000, 64);
        let b = ArrivalTrace::generate(&mix(), 11, 4_000, 64);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        let c = ArrivalTrace::generate(&mix(), 12, 4_000, 64);
        assert_ne!(a.digest(), c.digest(), "different seeds must diverge");
    }

    #[test]
    fn arrival_trace_is_sorted_and_counted() {
        let t = ArrivalTrace::generate(&mix(), 3, 6_000, 64);
        assert!(!t.events.is_empty());
        for w in t.events.windows(2) {
            assert!(w[0].at <= w[1].at, "events must be time-sorted");
            assert!(w[0].id < w[1].id, "ids assigned in merged order");
        }
        assert_eq!(t.per_class.iter().sum::<usize>(), t.events.len());
        // every class produced at least one arrival over this horizon
        assert!(t.per_class.iter().all(|&n| n > 0), "{:?}", t.per_class);
    }

    #[test]
    fn arrival_trace_shares_system_prompts_within_class() {
        let classes = mix();
        let t = ArrivalTrace::generate(&classes, 5, 6_000, 64);
        for (ci, c) in classes.iter().enumerate() {
            let prompts: Vec<&Vec<i32>> = t
                .events
                .iter()
                .filter(|e| e.class_id == ci)
                .map(|e| &e.prompt)
                .collect();
            assert!(prompts.len() > 1, "class {ci} too sparse to check sharing");
            let prefix = &prompts[0][..c.system_prompt_len];
            for p in &prompts {
                assert_eq!(p.len(), c.system_prompt_len + c.tail_len);
                assert_eq!(&p[..c.system_prompt_len], prefix, "shared prefix drifted");
            }
            // SLO + budget carried per event
            for e in t.events.iter().filter(|e| e.class_id == ci) {
                assert_eq!(e.slo, c.slo);
                assert_eq!(e.max_new_tokens, c.max_new_tokens);
                assert_eq!(e.class_name, c.name);
            }
        }
    }

    #[test]
    fn arrival_trace_bursts_cluster() {
        // bursts only: every arrival sits exactly on a burst boundary
        let c = TenantClass {
            rate: 0.0,
            burst_every: 500,
            burst_size: 4,
            ..TenantClass::chat()
        };
        let t = ArrivalTrace::generate(&[c], 9, 2_000, 64);
        assert_eq!(t.events.len(), 3 * 4, "3 boundaries x 4 arrivals");
        for e in &t.events {
            assert_eq!(e.at % 500, 0, "burst arrival off the boundary: {}", e.at);
        }
    }

    #[test]
    fn builtin_classes_resolve_by_name() {
        for name in ["chat", "math", "coding", "code"] {
            let c = TenantClass::by_name(name).expect(name);
            assert!(!c.slo.is_none(), "{name} must carry a real SLO target");
        }
        assert!(TenantClass::by_name("nope").is_none());
    }
}
