//! Line-delimited-JSON TCP serving front end + client.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": [1, 2, 3], "id": 7}
//!   <- {"id": 7, "tokens": [...], "ttft_ms": 1.2, "tpot_ms": 2.3,
//!       "total_ms": 450.0, "avg_bits": 4.4}
//! plus {"cmd": "stats"} / {"cmd": "shutdown"} control lines.
//!
//! Adding `"stream": true` to a request switches it to streaming: the
//! server emits one `{"id": ..., "stream": true, "tokens": [...]}`
//! frame per decode chunk (the tokens generated since the previous
//! frame), then the usual full reply with `"done": true`. Frames ride a
//! bounded per-request channel, so a slow TCP peer backpressures only
//! its own session's decode worker at chunk granularity.
//!
//! With `--replicas N` the coordinator runs a replica fleet behind a
//! router; `stats` then reports the fleet-merged snapshot — per-lane
//! occupancy (`lanes`/`lane_peak`/`lane_switches`), proactive
//! `idle_swapouts`, and the live-migration ledger
//! (`replicas`/`migrations`/`migration_bytes`/`migration_ms`).
//!
//! Malformed request lines never kill the connection: the server replies
//! `{"id": ..., "error": "..."}` (id `null` when the line did not parse)
//! and keeps reading. `stats` reports the scheduler/pool counters
//! (admissions, preemptions, queue depth, pool used/peak/free), the
//! suspend-to-host swap counters (`swap_outs`/`swap_ins`, bytes moved
//! each way, `swap_restore_ms`, `swap_fallbacks`), the batched
//! decode counters (`fused_steps`, `fused_sessions`, `batch_hist`),
//! the cross-session prefix-sharing counters (`prefix_hits`,
//! `prefix_misses`, `prefix_inserts`, `prefix_cow_faults`,
//! `prefix_cow_denied`, `prefix_reclaims`, `prefix_resident_bytes`,
//! `prefix_resident_entries`, plus the zero-copy attach counters
//! `prefix_alias_hits`/`prefix_alias_bytes`), the chunked-prefill lane
//! counters (`prefill_chunk_tokens`, `prefill_chunks`,
//! `prefill_interleaved_steps`, `prefill_queue_depth`), and the
//! PJRT-execute ledger (`pjrt_decode_executes` — one per fused batch,
//! one per counted fallback member `pjrt_fallback_executes` —
//! `pjrt_prefill_executes`, and the engine prefill-memo
//! `prefill_memo_hits`/`prefill_memo_evictions`), and the SLO-goodput
//! ledger (`sched_policy` — `"goodput"`/`"throughput"` — global
//! `goodput`/`slo_violations`, plus a `slo_classes` array with
//! per-tenant-class goodput, violations, and TTFT/TPOT p50/p99 in
//! scheduler ticks) alongside the serving totals.
//! Per-request replies carry `preemptions` (recompute resets),
//! `swap_ins` (zero-replay resumes), and the TTFT decomposition
//! (`prefill_ms` engine time + `prefill_chunks`; `ttft_ms -
//! prefill_ms` is scheduling wait) so clients can tell the two
//! preemption flavors apart and see where first-token latency went.
//! Retention-arena provenance rides along too: `policy` (the live
//! eviction policy's display name) with its `evicted` / `skipped` /
//! `retained_bytes` counters per request, and the aggregate
//! `policy`/`policy_evictions`/`policy_skips`/`policy_retained_bytes`
//! rows in `stats`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, ServeConfig};
use crate::util::json::{parse, Json};

pub struct Server {
    pub addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving on `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    /// Returns once the listener is bound; serving runs on a background
    /// thread with its own coordinator.
    pub fn start(addr: &str, cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let bound = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("thinkv-server".into())
            .spawn(move || {
                let coordinator = match Coordinator::start(cfg) {
                    Ok(c) => Arc::new(c),
                    Err(e) => {
                        eprintln!("server: coordinator failed: {e:#}");
                        return;
                    }
                };
                let served = Arc::new(AtomicU64::new(0));
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let c = Arc::clone(&coordinator);
                            let stop3 = Arc::clone(&stop2);
                            let served = Arc::clone(&served);
                            conns.push(std::thread::spawn(move || {
                                if let Err(e) = handle_conn(stream, &c, &stop3, &served) {
                                    eprintln!("conn error: {e:#}");
                                }
                            }));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(e) => {
                            eprintln!("accept error: {e}");
                            break;
                        }
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(Server { addr: bound, stop, handle: Some(handle) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    coordinator: &Coordinator,
    stop: &AtomicBool,
    served: &AtomicU64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // read timeout so connection threads notice shutdown even while idle
    stream.set_read_timeout(Some(std::time::Duration::from_millis(300))).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break, // peer closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let line = line.trim_end().to_string();
        let req = match parse(&line) {
            Ok(j) => j,
            Err(e) => {
                // malformed line: reply with an error object (id unknown)
                // and keep the connection alive
                let mut err = Json::obj();
                err.set("id", Json::Null);
                err.set("error", Json::Str(format!("bad json: {e}")));
                writeln!(writer, "{}", err.to_string())?;
                continue;
            }
        };
        let req_id = req.get("id").cloned().unwrap_or(Json::Num(0.0));
        if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
            match cmd {
                "stats" => {
                    let mut out = coordinator.sched_stats().to_json();
                    out.set("inflight", Json::Num(coordinator.inflight() as f64));
                    out.set("served", Json::Num(served.load(Ordering::SeqCst) as f64));
                    out.set("mode", Json::Str(coordinator.config().mode.label()));
                    writeln!(writer, "{}", out.to_string())?;
                }
                "shutdown" => {
                    stop.store(true, Ordering::SeqCst);
                    writeln!(writer, "{{\"ok\":true}}")?;
                    break;
                }
                other => {
                    let mut err = Json::obj();
                    err.set("id", req_id.clone());
                    err.set("error", Json::Str(format!("unknown cmd {other}")));
                    writeln!(writer, "{}", err.to_string())?;
                }
            }
            continue;
        }
        let prompt: Option<Vec<i32>> = req
            .get("prompt")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|x| x.as_f64().map(|v| v as i32)).collect());
        let prompt = match prompt {
            Some(p) if !p.is_empty() => p,
            _ => {
                let mut err = Json::obj();
                err.set("id", req_id.clone());
                err.set("error", Json::Str("missing or empty 'prompt' array".into()));
                writeln!(writer, "{}", err.to_string())?;
                continue;
            }
        };
        let streaming = req.get("stream").and_then(Json::as_bool).unwrap_or(false);
        // a failed submit (e.g. demand exceeds the pool) or a dropped
        // session is a per-request error, not a connection error
        let result = if streaming {
            // streaming mode: one line-JSON frame per decode chunk. The
            // bounded channel is the per-connection backpressure — a
            // slow TCP peer fills it and stalls only this session's
            // decode worker, never the accept loop or other batches.
            let (ftx, frx) = std::sync::mpsc::sync_channel::<Vec<i32>>(8);
            match coordinator.submit_with_stream(prompt, ftx) {
                Ok(handle) => {
                    // forward frames until the session drops its sender
                    // (finish or failure), then the final reply follows
                    for frame in frx.iter() {
                        let mut f = Json::obj();
                        f.set("id", req_id.clone());
                        f.set("stream", Json::Bool(true));
                        f.set(
                            "tokens",
                            Json::Arr(frame.iter().map(|&t| Json::Num(f64::from(t))).collect()),
                        );
                        writeln!(writer, "{}", f.to_string())?;
                    }
                    handle.wait()
                }
                Err(e) => Err(e),
            }
        } else {
            coordinator.submit(prompt).and_then(|h| h.wait())
        };
        let result = match result {
            Ok(r) => r,
            Err(e) => {
                let mut err = Json::obj();
                err.set("id", req_id.clone());
                err.set("error", Json::Str(format!("{e:#}")));
                writeln!(writer, "{}", err.to_string())?;
                continue;
            }
        };
        served.fetch_add(1, Ordering::SeqCst);
        let mut out = Json::obj();
        out.set("id", req_id);
        if streaming {
            // lets a streaming client tell the final reply from frames
            out.set("done", Json::Bool(true));
        }
        out.set(
            "tokens",
            Json::Arr(result.tokens.iter().map(|&t| Json::Num(f64::from(t))).collect()),
        );
        out.set("ttft_ms", Json::Num(result.ttft_ms));
        // ttft decomposition: engine prefill time vs scheduling wait
        out.set(
            "prefill_ms",
            Json::Num(result.breakdown.prefill_exec_ns as f64 / 1e6),
        );
        out.set(
            "prefill_chunks",
            Json::Num(result.breakdown.prefill_chunks as f64),
        );
        out.set("tpot_ms", Json::Num(result.tpot_ms));
        out.set("total_ms", Json::Num(result.total_ms));
        out.set("avg_bits", Json::Num(result.avg_bits));
        out.set("live_tokens", Json::Num(result.live_tokens as f64));
        // retention-arena provenance: which policy served this request
        // and what it evicted / never materialized / still held
        out.set("policy", Json::Str(result.policy.into()));
        out.set("evicted", Json::Num(result.evicted as f64));
        out.set("skipped", Json::Num(result.skipped as f64));
        out.set("retained_bytes", Json::Num(result.retained_bytes as f64));
        // actual PJRT executes this request caused (0 under fake
        // engines; decode executes are only attributable on the
        // single-session path — fused batches land in `stats`)
        out.set(
            "pjrt_decode_executes",
            Json::Num(result.breakdown.pjrt_decode_executes as f64),
        );
        out.set(
            "pjrt_prefill_executes",
            Json::Num(result.breakdown.pjrt_prefill_executes as f64),
        );
        out.set(
            "pjrt_fallback_executes",
            Json::Num(result.breakdown.pjrt_fallback_executes as f64),
        );
        out.set("preemptions", Json::Num(result.preemptions as f64));
        out.set("swap_ins", Json::Num(result.swap_ins as f64));
        if let Some(e) = &result.error {
            out.set("error", Json::Str(e.clone()));
        }
        writeln!(writer, "{}", out.to_string())?;
    }
    Ok(())
}

/// Minimal blocking client for examples/benches.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, prompt: &[i32], id: u64) -> Result<Json> {
        let mut req = Json::obj();
        req.set(
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::Num(f64::from(t))).collect()),
        );
        req.set("id", Json::Num(id as f64));
        writeln!(self.writer, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    /// Streaming request: returns the per-chunk token frames in arrival
    /// order plus the final reply object (`"done": true`). The
    /// concatenated frames equal the final reply's `tokens` array.
    pub fn request_stream(&mut self, prompt: &[i32], id: u64) -> Result<(Vec<Vec<i32>>, Json)> {
        let mut req = Json::obj();
        req.set(
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::Num(f64::from(t))).collect()),
        );
        req.set("id", Json::Num(id as f64));
        req.set("stream", Json::Bool(true));
        writeln!(self.writer, "{}", req.to_string())?;
        let mut frames = Vec::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("server closed mid-stream");
            }
            let j = parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
            if j.get("stream").and_then(Json::as_bool).unwrap_or(false) {
                let frame = j
                    .get("tokens")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|x| x.as_f64().map(|v| v as i32)).collect())
                    .unwrap_or_default();
                frames.push(frame);
            } else {
                return Ok((frames, j));
            }
        }
    }

    pub fn stats(&mut self) -> Result<Json> {
        writeln!(self.writer, "{{\"cmd\":\"stats\"}}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }
}
