//! Instrumented synchronization facade: lock wrappers that carry a
//! static **lock rank** and enforce the crate-wide lock hierarchy at
//! run time in debug builds.
//!
//! The serving tier holds at most two locks at once, but *which* two is
//! a correctness contract: `Scheduler::try_admit` calls into the prefix
//! trie with the scheduler inner lock held, and `Session::release_pool`
//! drains CoW reservations (a per-attachment cell) from `fail`/`finish`
//! paths that also hold the inner lock. Instead of relying on reviewer
//! vigilance, every `Mutex`/`RwLock` on those paths is a
//! [`RankedMutex`]/[`RankedRwLock`] carrying one of the [`rank`]
//! constants; debug builds keep a thread-local stack of held ranks and
//! panic — with **both** acquisition sites — whenever a thread acquires
//! a lock whose rank is not strictly greater than every rank it already
//! holds. Release builds compile the checks out entirely (the wrappers
//! are zero-cost shims over `std::sync`).
//!
//! The hierarchy (must acquire in strictly increasing rank order):
//!
//! | rank | constant | protects |
//! |-----:|----------|----------|
//! | 20 | [`rank::SCHED_INNER`]      | scheduler queues + admission state |
//! | 30 | [`rank::SLO_BOOK`]         | per-class SLO attainment ledger |
//! | 40 | [`rank::PREFIX_ROOT`]      | prefix-index trie root |
//! | 50 | [`rank::PREFIX_RESIDENCY`] | a resident prefix's pool lease |
//! | 60 | [`rank::PREFIX_COW`]       | an attachment's CoW lease cell |
//!
//! Poisoning is treated as fatal inside the facade (`lock()` unwraps),
//! matching the crate's existing `.lock().unwrap()` convention — a
//! panic while holding a scheduler lock is unrecoverable anyway.
//!
//! The [`model`] submodule hosts the deterministic interleaving
//! explorer (`make loom`) that model-checks the three hand-rolled lock
//! dances; see `rust/tests/loom_models.rs`.
//!
//! Under `--cfg loom` the facade would re-export the `loom` crate's
//! permutation-testing lock types instead; the container image does not
//! ship the `loom` crate, so that path is gated off and the in-repo
//! explorer in [`model`] fills the role with zero dependencies.

pub mod model;

#[cfg(loom)]
pub use loom::sync::{Mutex as RankedMutexInner, RwLock as RankedRwLockInner};
#[cfg(not(loom))]
use std::sync::{Mutex as RankedMutexInner, RwLock as RankedRwLockInner};

use std::panic::Location;
use std::sync::{Condvar, MutexGuard, RwLockReadGuard, RwLockWriteGuard};

/// A static lock rank: a level in the crate-wide lock hierarchy plus a
/// human-readable name for diagnostics. Declare one `static` per lock
/// family (see [`rank`]); the pointer doubles as the lock's identity in
/// panic messages.
#[derive(Debug)]
pub struct LockRank {
    /// Diagnostic name, printed on violation.
    pub name: &'static str,
    /// Hierarchy level. A thread may only acquire a lock whose order is
    /// **strictly greater** than the maximum order it currently holds
    /// (strict, so two locks of the same family can never nest).
    pub order: u32,
}

/// The crate's lock hierarchy. Gaps between levels are deliberate:
/// future locks slot in without renumbering.
pub mod rank {
    use super::LockRank;

    /// Scheduler queues + admission state (`Scheduler.inner`).
    pub static SCHED_INNER: LockRank = LockRank { name: "sched.inner", order: 20 };
    /// Per-class SLO attainment ledger (`Scheduler.slo_book`), taken
    /// from `finish`/`fail` with the inner lock held.
    pub static SLO_BOOK: LockRank = LockRank { name: "sched.slo_book", order: 30 };
    /// Prefix-index trie root (`PrefixIndex.root`), taken from
    /// `try_admit` reclamation with the inner lock held.
    pub static PREFIX_ROOT: LockRank = LockRank { name: "prefix.root", order: 40 };
    /// A resident `SharedPrefix`'s pool-lease cell; taken only after
    /// the trie root is released (reclaim) or during publish.
    pub static PREFIX_RESIDENCY: LockRank = LockRank { name: "prefix.residency", order: 50 };
    /// An `AttachedPrefix`'s CoW-lease cell, drained by
    /// `Session::release_pool` under the inner lock.
    pub static PREFIX_COW: LockRank = LockRank { name: "prefix.cow", order: 60 };
}

#[cfg(debug_assertions)]
mod held {
    //! Thread-local stack of (rank, acquisition site) for every ranked
    //! lock the current thread holds. Entries carry a unique id so
    //! guards dropped out of LIFO order unwind correctly.

    use super::LockRank;
    use std::cell::RefCell;
    use std::panic::Location;

    struct Held {
        rank: &'static LockRank,
        site: &'static Location<'static>,
        id: u64,
    }

    thread_local! {
        static STACK: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
        static NEXT_ID: RefCell<u64> = const { RefCell::new(0) };
    }

    /// Check `rank` against every held rank, then push it. Panics with
    /// both acquisition sites on an out-of-rank acquire. Returns the
    /// entry id [`released`] pops by.
    pub fn acquired(rank: &'static LockRank, site: &'static Location<'static>) -> u64 {
        STACK.with(|stack| {
            let stack = stack.borrow();
            if let Some(worst) = stack.iter().max_by_key(|h| h.rank.order) {
                assert!(
                    rank.order > worst.rank.order,
                    "lock-rank violation: acquiring `{}` (rank {}) at {} \
                     while holding `{}` (rank {}) acquired at {}",
                    rank.name,
                    rank.order,
                    site,
                    worst.rank.name,
                    worst.rank.order,
                    worst.site,
                );
            }
        });
        let id = NEXT_ID.with(|n| {
            let mut n = n.borrow_mut();
            *n += 1;
            *n
        });
        STACK.with(|stack| stack.borrow_mut().push(Held { rank, site, id }));
        id
    }

    /// Pop the entry pushed by [`acquired`]; by id, not position —
    /// guards may drop in any order.
    pub fn released(id: u64) {
        STACK.with(|stack| {
            let mut stack = stack.borrow_mut();
            if let Some(pos) = stack.iter().position(|h| h.id == id) {
                stack.remove(pos);
            }
        });
    }
}

/// A [`std::sync::Mutex`] that participates in the lock hierarchy.
#[derive(Debug)]
pub struct RankedMutex<T> {
    rank: &'static LockRank,
    inner: RankedMutexInner<T>,
}

impl<T> RankedMutex<T> {
    pub fn new(rank: &'static LockRank, value: T) -> RankedMutex<T> {
        RankedMutex { rank, inner: RankedMutexInner::new(value) }
    }

    /// Acquire the lock, enforcing the rank discipline in debug builds.
    /// Poisoning is fatal (unwrapped), per crate convention.
    #[track_caller]
    pub fn lock(&self) -> RankedGuard<'_, T> {
        let site = Location::caller();
        RankedGuard {
            inner: Some(self.inner.lock().unwrap()),
            token: HeldToken::acquire(self.rank, site),
        }
    }

    /// Consume the mutex and return its value (no rank check: nothing
    /// is acquired — exclusive access is proven by ownership).
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap()
    }
}

/// Debug-only record of a held ranked lock; release builds are a ZST.
#[derive(Debug)]
struct HeldToken {
    #[cfg(debug_assertions)]
    id: u64,
}

impl HeldToken {
    #[allow(unused_variables)]
    fn acquire(rank: &'static LockRank, site: &'static Location<'static>) -> HeldToken {
        HeldToken {
            #[cfg(debug_assertions)]
            id: held::acquired(rank, site),
        }
    }
}

impl Drop for HeldToken {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        held::released(self.id);
    }
}

/// Guard for a [`RankedMutex`]; unregisters its rank on drop.
///
/// The inner guard lives in an `Option` only so [`RankedGuard::wait_on`]
/// can move it out while the struct's `Drop` glue still runs; it is
/// `Some` at every other moment.
#[derive(Debug)]
pub struct RankedGuard<'a, T> {
    inner: Option<MutexGuard<'a, T>>,
    token: HeldToken,
}

impl<'a, T> RankedGuard<'a, T> {
    /// Block on `cv`, releasing and re-acquiring the underlying mutex.
    /// The rank entry is kept across the wait: the thread holds no
    /// *other* lock while blocked (the hierarchy already guaranteed the
    /// waited-on lock is its maximum), and keeping the entry means the
    /// re-acquire needs no re-check.
    pub fn wait_on(mut self, cv: &Condvar) -> RankedGuard<'a, T> {
        let guard = self.inner.take().expect("guard present outside wait_on");
        self.inner = Some(cv.wait(guard).unwrap());
        self
    }
}

impl<T> std::ops::Deref for RankedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard present outside wait_on")
    }
}

impl<T> std::ops::DerefMut for RankedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard present outside wait_on")
    }
}

/// A [`std::sync::RwLock`] that participates in the lock hierarchy.
/// Readers and writers are ranked identically: a read lock still
/// excludes writers, so holding one while acquiring a lower rank can
/// deadlock all the same.
#[derive(Debug)]
pub struct RankedRwLock<T> {
    rank: &'static LockRank,
    inner: RankedRwLockInner<T>,
}

impl<T> RankedRwLock<T> {
    pub fn new(rank: &'static LockRank, value: T) -> RankedRwLock<T> {
        RankedRwLock { rank, inner: RankedRwLockInner::new(value) }
    }

    #[track_caller]
    pub fn read(&self) -> RankedReadGuard<'_, T> {
        let site = Location::caller();
        RankedReadGuard {
            inner: self.inner.read().unwrap(),
            _token: HeldToken::acquire(self.rank, site),
        }
    }

    #[track_caller]
    pub fn write(&self) -> RankedWriteGuard<'_, T> {
        let site = Location::caller();
        RankedWriteGuard {
            inner: self.inner.write().unwrap(),
            _token: HeldToken::acquire(self.rank, site),
        }
    }
}

/// Shared guard for a [`RankedRwLock`].
#[derive(Debug)]
pub struct RankedReadGuard<'a, T> {
    inner: RwLockReadGuard<'a, T>,
    _token: HeldToken,
}

impl<T> std::ops::Deref for RankedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

/// Exclusive guard for a [`RankedRwLock`].
#[derive(Debug)]
pub struct RankedWriteGuard<'a, T> {
    inner: RwLockWriteGuard<'a, T>,
    _token: HeldToken,
}

impl<T> std::ops::Deref for RankedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for RankedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    static LOW: LockRank = LockRank { name: "test.low", order: 10 };
    static HIGH: LockRank = LockRank { name: "test.high", order: 99 };

    #[test]
    fn in_order_nesting_is_fine() {
        let a = RankedMutex::new(&LOW, 1u32);
        let b = RankedMutex::new(&HIGH, 2u32);
        let ga = a.lock();
        let gb = b.lock();
        assert_eq!(*ga + *gb, 3);
    }

    #[test]
    fn reacquire_after_release_is_fine() {
        let a = RankedMutex::new(&HIGH, 0u32);
        for _ in 0..3 {
            let mut g = a.lock();
            *g += 1;
        }
        assert_eq!(a.into_inner(), 3);
    }

    #[test]
    fn out_of_order_drop_unwinds_correctly() {
        // drop the *outer* (lower-rank) guard first; the held stack
        // must still unwind by id, leaving HIGH registered so that a
        // subsequent LOW acquire is (correctly) rejected.
        let a = RankedMutex::new(&LOW, ());
        let b = RankedMutex::new(&HIGH, ());
        let ga = a.lock();
        let gb = b.lock();
        drop(ga);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = a.lock(); // HIGH still held: out of rank
        }));
        assert!(err.is_err(), "acquire below a held rank must panic");
        drop(gb);
        let _ga = a.lock(); // all released: fine again
    }

    /// Seeded violation: the detector itself is regression-tested.
    #[test]
    fn out_of_rank_acquire_panics_with_both_sites() {
        let hi = RankedMutex::new(&HIGH, ());
        let lo = RankedMutex::new(&LOW, ());
        let _g = hi.lock();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _bad = lo.lock();
        }))
        .expect_err("out-of-rank acquire must panic in debug builds");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| (*err.downcast_ref::<&str>().unwrap_or(&"")).to_string());
        assert!(msg.contains("lock-rank violation"), "got: {msg}");
        assert!(msg.contains("test.low") && msg.contains("test.high"), "got: {msg}");
        // both acquisition sites: this file appears twice
        assert!(msg.matches("syncx.rs").count() >= 2, "got: {msg}");
    }

    #[test]
    fn same_rank_nesting_panics() {
        let a = RankedMutex::new(&HIGH, ());
        let b = RankedMutex::new(&HIGH, ());
        let _ga = a.lock();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _gb = b.lock();
        }));
        assert!(err.is_err(), "same-rank nesting must panic (strict order)");
    }

    #[test]
    fn rwlock_ranks_apply_to_readers_and_writers() {
        let rw = RankedRwLock::new(&HIGH, 5u32);
        {
            let r = rw.read();
            assert_eq!(*r, 5);
        }
        {
            let mut w = rw.write();
            *w += 1;
        }
        let lo = RankedMutex::new(&LOW, ());
        let _r = rw.read();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _bad = lo.lock();
        }));
        assert!(err.is_err(), "read guards must enforce rank too");
    }

    #[test]
    fn condvar_wait_keeps_rank_registered() {
        use std::sync::{Arc, Condvar};
        let m = Arc::new(RankedMutex::new(&LOW, false));
        let cv = Arc::new(Condvar::new());
        let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
        let waiter = std::thread::spawn(move || {
            let mut g = m2.lock();
            while !*g {
                g = g.wait_on(&cv2);
            }
            *g
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        {
            let mut g = m.lock();
            *g = true;
        }
        cv.notify_all();
        assert!(waiter.join().unwrap());
    }
}
