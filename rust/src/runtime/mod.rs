//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the only module that touches the `xla` crate. Pattern follows
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One [`engine::Engine`] per worker thread (the PJRT handles are not Sync);
//! weights are uploaded to device buffers once per engine and reused by
//! every step (`execute_b`), so the per-step traffic is only the cache
//! tensors + scalars.

pub mod engine;
pub mod weights;

pub use engine::{
    BatchDecodeReq, CacheView, DecodeEngine, DecodeOut, Engine, ExecStats, PrefillChunkOut,
    PrefillOut, QuantCache, SharedFp32Rows, SharedQuantRows,
};
pub use weights::{load_weights, Tensor};
