//! Reader for `artifacts/weights.bin` (TKVW format, written by aot.py):
//! magic "TKVW", u32 version, u32 count, then per tensor:
//! u32 name_len, name bytes, u32 ndim, u32 dims[], f32 data (LE).

use anyhow::{bail, Context, Result};

/// A named host tensor (f32).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

pub fn load_weights(path: &str) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    if bytes.len() < 12 || &bytes[..4] != b"TKVW" {
        bail!("bad TKVW magic in {path}");
    }
    let mut off = 4usize;
    let mut u32_at = |off: &mut usize| -> Result<u32> {
        if *off + 4 > bytes.len() {
            bail!("truncated TKVW file");
        }
        let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
        *off += 4;
        Ok(v)
    };
    let version = u32_at(&mut off)?;
    if version != 1 {
        bail!("unsupported TKVW version {version}");
    }
    let count = u32_at(&mut off)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = u32_at(&mut off)? as usize;
        if off + name_len > bytes.len() {
            bail!("truncated tensor name");
        }
        let name = String::from_utf8(bytes[off..off + name_len].to_vec())?;
        off += name_len;
        let ndim = u32_at(&mut off)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32_at(&mut off)? as usize);
        }
        let n: usize = shape.iter().product();
        if off + 4 * n > bytes.len() {
            bail!("truncated tensor data for {name}");
        }
        let data: Vec<f32> = bytes[off..off + 4 * n]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        off += 4 * n;
        out.push(Tensor { name, shape, data });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::default_artifacts_dir;

    #[test]
    fn loads_weights_if_built() {
        let path = format!("{}/weights.bin", default_artifacts_dir());
        if !std::path::Path::new(&path).exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ws = load_weights(&path).unwrap();
        assert!(ws.len() > 10);
        assert_eq!(ws[0].name, "embed");
        assert_eq!(ws[0].data.len(), ws[0].elem_count());
        // weights are finite
        for w in &ws {
            assert!(w.data.iter().all(|x| x.is_finite()), "{}", w.name);
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("thinkv_bad_weights.bin");
        std::fs::write(&dir, b"NOPE....").unwrap();
        assert!(load_weights(dir.to_str().unwrap()).is_err());
    }
}
