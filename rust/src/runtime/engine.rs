//! The PJRT execution engine: compiles HLO-text artifacts once and runs
//! prefill / decode steps against caller-owned cache state.
//!
//! One `Engine` per worker thread. Weights are uploaded to device buffers at
//! construction and shared by every call (`execute_b`), so a decode step
//! only transfers the per-request cache tensors and scalars.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::model::{default_artifacts_dir, Manifest};
use crate::runtime::weights::load_weights;

/// Borrowed view of a request's quantized paged cache (layouts: DESIGN §1).
pub struct QuantCache<'a> {
    pub capacity: usize,
    pub k_codes: &'a [u8],   // [L, C, Hkv, Dh]
    pub k_scales: &'a [f32], // [L, C, Hkv, G]
    pub v_codes: &'a [u8],
    pub v_scales: &'a [f32],
    pub tags: &'a [u8],  // [L, C]
    pub mask: &'a [f32], // [L, C]
    pub buf_k: &'a [f32],    // [L, BUF, Hkv, Dh]
    pub buf_v: &'a [f32],
    pub buf_mask: &'a [f32], // [L, BUF]
    /// Shared-prefix payload rows this cache aliases instead of owning:
    /// slab rows `0..shared.len` are placeholders and the true K/V rows
    /// live in one resident copy referenced here. `None` for caches that
    /// own (or have materialized) every row.
    pub shared: Option<SharedQuantRows<'a>>,
}

/// Borrowed rows of a shared prompt-prefix payload a quantized cache
/// aliases instead of copying. Payload layout is `[L, full_len, ...]`
/// (row stride `full_len` per layer); the aliasing cache maps payload
/// row `s < len` to its own slot `s`. `id` identifies the physical copy
/// so a fused batch stages each resident prefix at most once.
#[derive(Clone, Copy)]
pub struct SharedQuantRows<'a> {
    pub id: u64,
    /// Rows of the payload live in the aliasing cache (the attach length).
    pub len: usize,
    /// Payload row stride per layer (the published prefix length).
    pub full_len: usize,
    pub k_codes: &'a [u8],   // [L, full_len, Hkv, Dh]
    pub k_scales: &'a [f32], // [L, full_len, Hkv, G]
    pub v_codes: &'a [u8],
    pub v_scales: &'a [f32],
}

/// F32 twin of [`SharedQuantRows`] for the FullKV / eviction families.
#[derive(Clone, Copy)]
pub struct SharedFp32Rows<'a> {
    pub id: u64,
    pub len: usize,
    pub full_len: usize,
    pub k: &'a [f32], // [L, full_len, Hkv, Dh]
    pub v: &'a [f32],
}

/// Borrowed view of a request's cache in whichever family it lives —
/// what [`crate::kvcache::KvBackend::view`] hands the engine so the
/// session decode loop stays generic over compression modes.
pub enum CacheView<'a> {
    /// Quantized paged cache (ThinKV / KIVI / PM-KVQ).
    Quant(QuantCache<'a>),
    /// F32 paged cache (FullKV / eviction baselines).
    Fp32 {
        capacity: usize,
        k: &'a [f32],
        v: &'a [f32],
        mask: &'a [f32],
        buf_k: &'a [f32],
        buf_v: &'a [f32],
        buf_mask: &'a [f32],
        /// Aliased shared-prefix rows (see [`QuantCache::shared`]).
        shared: Option<SharedFp32Rows<'a>>,
    },
}

/// One member of a fused cross-session decode step: the scalars plus the
/// borrowed cache view [`DecodeEngine::decode_batch`] advances together.
pub struct BatchDecodeReq<'a> {
    /// Last sampled token (the decode-step input).
    pub token: i32,
    /// Current CoT position.
    pub pos: i32,
    /// Ring-buffer fill (next free buffer slot).
    pub buf_idx: i32,
    /// Borrowed view of this member's cache slabs.
    pub view: CacheView<'a>,
}

/// Cumulative PJRT-execute ledger an engine exposes for the serving
/// metrics ([`DecodeEngine::exec_stats`]): how many device launches the
/// decode and prefill paths actually issued, how many batch members had
/// to fall back to per-member executes, and how the chunked-prefill
/// memo behaved. Monotone counters; callers diff around a call to
/// attribute executes to it.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ExecStats {
    /// Decode-step executes: one per fused `decode_batch` launch and one
    /// per single-request `decode` (a fused step over a covered batch
    /// contributes exactly 1).
    pub decode_executes: u64,
    /// Prefill executes: whole-prompt modules plus one per chunk-artifact
    /// launch.
    pub prefill_executes: u64,
    /// Batch members advanced by per-member fallback executes (no batched
    /// artifact covered them); these members also count in
    /// `decode_executes`.
    pub fallback_executes: u64,
    /// Chunked-prefill requests served by a memoized whole-prompt image.
    pub prefill_memo_hits: u64,
    /// Memo entries evicted by the LRU bound (the evicted prompt pays a
    /// re-execute if it resumes).
    pub prefill_memo_evictions: u64,
}

/// The engine surface the serving session/worker loop drives — one
/// prefill plus single and fused (cross-session batched) decode steps.
///
/// [`Engine`] implements this over the AOT PJRT artifacts; tests
/// implement it with deterministic synthetic engines so scheduler and
/// session behavior (including batched-vs-sequential stream invariance)
/// can be verified without artifacts.
///
/// # Example
///
/// A deterministic fake engine: `decode_batch` (the fused entry point
/// workers call once per batch per step) advances every member and
/// returns their outputs in order:
///
/// ```
/// use anyhow::Result;
/// use thinkv::kvcache::{CacheConfig, CtCache};
/// use thinkv::model::ModelConfig;
/// use thinkv::runtime::{BatchDecodeReq, CacheView, DecodeEngine, DecodeOut, PrefillOut};
///
/// struct FixedEngine {
///     m: ModelConfig,
/// }
///
/// impl DecodeEngine for FixedEngine {
///     fn model(&self) -> &ModelConfig {
///         &self.m
///     }
///     fn prefill(&self, _tokens: &[i32]) -> Result<PrefillOut> {
///         unimplemented!("not exercised here")
///     }
///     fn decode(&self, token: i32, pos: i32, _buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
///         let span = match view {
///             CacheView::Quant(q) => q.capacity,
///             CacheView::Fp32 { capacity, .. } => *capacity,
///         } + self.m.buf_slots;
///         let kvd = self.m.n_kv_heads * self.m.d_head;
///         Ok(DecodeOut {
///             logits: vec![(token + pos) as f32; self.m.vocab],
///             new_k: vec![0.0; self.m.n_layers * kvd],
///             new_v: vec![0.0; self.m.n_layers * kvd],
///             probs: vec![0.0; self.m.n_layers * self.m.n_heads * span],
///         })
///     }
/// }
///
/// let m = ModelConfig {
///     vocab: 8, d_model: 8, n_layers: 1, n_heads: 1, n_kv_heads: 1, d_head: 16,
///     d_ffn: 8, rope_base: 10000.0, buf_slots: 4, prefill_len: 4, obs_window: 2,
///     group_size: 16,
/// };
/// let eng = FixedEngine { m };
/// let cache = CtCache::new(CacheConfig {
///     layers: 1, capacity: 16, block_size: 8, hkv: 1, dh: 16, buf_slots: 4,
/// });
/// let reqs = [
///     BatchDecodeReq { token: 1, pos: 4, buf_idx: 0, view: CacheView::Quant(cache.view()) },
///     BatchDecodeReq { token: 2, pos: 4, buf_idx: 0, view: CacheView::Quant(cache.view()) },
/// ];
/// let outs = eng.decode_batch(&reqs).unwrap(); // one fused step, two streams
/// assert_eq!(outs.len(), 2);
/// assert_eq!(outs[0].logits[0], 5.0);
/// assert_eq!(outs[1].logits[0], 6.0);
/// ```
pub trait DecodeEngine {
    /// The model dimensions every step is shaped by.
    fn model(&self) -> &crate::model::ModelConfig;

    /// Run prompt prefill (tokens padded/truncated to the exported length).
    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut>;

    /// Run one **chunk** of prompt prefill: K/V for positions
    /// `[start, start + len)` only, so the scheduler can interleave a
    /// long prompt's prefill with ongoing fused decode steps instead of
    /// head-of-line-blocking a whole decode batch on one inline prefill.
    /// `view` is the caller's cache already holding positions
    /// `0..start` — what a true chunked-prefill kernel attends to.
    ///
    /// `logits` in the returned chunk are the last-position logits of
    /// the whole prompt and are meaningful only on the **final** chunk
    /// (`start + len == prefill_len`), where the caller bootstraps the
    /// first generated token from them. `len == 0` is allowed for a
    /// logits-only final chunk (a shared prefix covered every prompt
    /// position).
    ///
    /// Chunking must be **bit-invariant**: any chunking of `0..p_len`
    /// must produce the exact K/V (and final logits) of one
    /// [`DecodeEngine::prefill`] call. The default implementation runs
    /// the whole prefill and slices, so it satisfies the invariant by
    /// construction (a whole-prompt "chunk" moves the prefill buffers
    /// straight through, copy-free); engines with a real chunked kernel
    /// may override.
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
        _view: &CacheView,
    ) -> Result<PrefillChunkOut> {
        let pf = self.prefill(tokens)?;
        if start == 0 && len == self.model().prefill_len {
            // the single-chunk case IS a whole prefill: same layout,
            // no slice copy
            let PrefillOut { logits, k, v, obs } = pf;
            return Ok(PrefillChunkOut { logits, k, v, obs });
        }
        slice_prefill_chunk(self.model(), &pf, start, len)
    }

    /// Run one decode step for a single session over either cache family.
    fn decode(&self, token: i32, pos: i32, buf_idx: i32, view: &CacheView) -> Result<DecodeOut>;

    /// One **fused** decode step over a batch of compatible sessions
    /// (same [`crate::kvcache::BatchKey`]: cache family + compiled
    /// capacity): the scheduler forms the batch, the worker makes one
    /// `decode_batch` call per step, and every member advances by one
    /// token. Outputs are returned in request order. Must be
    /// semantically identical to calling [`DecodeEngine::decode`] per
    /// member — batching is a launch-amortization strategy, never a
    /// numerics change (stream invariance).
    fn decode_batch(&self, reqs: &[BatchDecodeReq<'_>]) -> Result<Vec<DecodeOut>> {
        reqs.iter()
            .map(|r| self.decode(r.token, r.pos, r.buf_idx, &r.view))
            .collect()
    }

    /// Cumulative device-launch ledger (see [`ExecStats`]). Engines that
    /// do not issue real executes report zeros.
    fn exec_stats(&self) -> ExecStats {
        ExecStats::default()
    }

    /// Deterministic logical time, for engines that meter their own
    /// work (one unit per prefill token / decode member-step). Workers
    /// feed this into the scheduler's logical clock so SLO accounting
    /// is bit-reproducible in trace replays; `None` (the default, real
    /// PJRT engines) leaves the scheduler on wall-clock time.
    fn logical_now(&self) -> Option<u64> {
        None
    }
}

/// Outputs of one decode step.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>, // [V]
    pub new_k: Vec<f32>,  // [L, Hkv, Dh] (post-RoPE)
    pub new_v: Vec<f32>,  // [L, Hkv, Dh]
    pub probs: Vec<f32>,  // [L, H, C+BUF]
}

/// Outputs of prompt prefill.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub logits: Vec<f32>, // [V] (last position)
    pub k: Vec<f32>,      // [L, P, Hkv, Dh] post-RoPE
    pub v: Vec<f32>,      // [L, P, Hkv, Dh]
    pub obs: Vec<f32>,    // [L, P] SnapKV observation stats
}

/// Outputs of one prefill chunk ([`DecodeEngine::prefill_chunk`]):
/// prompt positions `[start, start + len)` in chunk-local layout.
#[derive(Debug, Clone)]
pub struct PrefillChunkOut {
    /// Last-position logits of the **whole** prompt — populated (and
    /// meaningful) only on the final chunk, where the first generated
    /// token is sampled; may be empty on earlier chunks.
    pub logits: Vec<f32>, // [V]
    pub k: Vec<f32>,      // [L, len, Hkv, Dh] post-RoPE
    pub v: Vec<f32>,      // [L, len, Hkv, Dh]
    pub obs: Vec<f32>,    // [L, len]
}

/// Slice positions `[start, start + len)` out of a full prefill — the
/// shared body of the default [`DecodeEngine::prefill_chunk`] and the
/// memo-fallback path of the [`Engine`] override. Logits are copied only
/// for the final chunk (the only one whose logits a caller may read).
fn slice_prefill_chunk(
    m: &crate::model::ModelConfig,
    pf: &PrefillOut,
    start: usize,
    len: usize,
) -> Result<PrefillChunkOut> {
    let p = m.prefill_len;
    if start + len > p {
        bail!("prefill chunk [{start}, {}) exceeds prefill_len {p}", start + len);
    }
    let kvd = m.n_kv_heads * m.d_head;
    let mut k = Vec::with_capacity(m.n_layers * len * kvd);
    let mut v = Vec::with_capacity(m.n_layers * len * kvd);
    let mut obs = Vec::with_capacity(m.n_layers * len);
    for l in 0..m.n_layers {
        let base = (l * p + start) * kvd;
        k.extend_from_slice(&pf.k[base..base + len * kvd]);
        v.extend_from_slice(&pf.v[base..base + len * kvd]);
        obs.extend_from_slice(&pf.obs[l * p + start..l * p + start + len]);
    }
    let logits = if start + len == p { pf.logits.clone() } else { Vec::new() };
    Ok(PrefillChunkOut { logits, k, v, obs })
}

/// Default cap on prompts whose full-prefill image the memo-fallback
/// path keeps warm at once (overridable via `THINKV_PREFILL_MEMO_CAP`).
/// Each entry is a whole-prompt fp32 [`PrefillOut`] — the largest host
/// allocation in the process at real model dims — so the cap is
/// deliberately tight: the scheduler runs **one** prefill lane per
/// batch, so 2 covers the active lane plus one rotation. A worker
/// alternating more than two mid-prefill prompts (or a session
/// abandoned mid-prefill, whose entry is only reclaimed by the LRU
/// bound) pays a bounded re-execute instead of pinning unbounded host
/// memory. The same cap bounds the chunk-artifact past-row states,
/// which are the same shape but have no fallback cost beyond re-running
/// earlier chunks.
const PREFILL_MEMO_CAP: usize = 2;

/// Per-prompt accumulator for the chunked-prefill artifacts: the exact
/// post-RoPE K/V rows earlier chunks produced, kept in whole-prompt
/// layout (`[L, P, Hkv, Dh]`) — what the next chunk execute attends
/// against. Rows at or past the running chunk's start are ignored by
/// the artifact, so stale tails are harmless.
struct ChunkState {
    /// Positions `0..filled` hold real rows (monotone high-water mark).
    filled: usize,
    past_k: Vec<f32>,
    past_v: Vec<f32>,
}

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    weight_bufs: Vec<xla::PjRtBuffer>,
    exes: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Memoized full-prompt prefills, keyed by token vector, kept in LRU
    /// order (back = most recent) and bounded by [`Engine::memo_cap`].
    /// This is the **fallback** chunked-prefill path for builds without
    /// `prefill_chunk_*` artifacts (or chunk geometries that are not a
    /// compiled multiple): the whole-prompt artifact runs once and
    /// successive chunks slice the memoized image. Entries retire at
    /// their final chunk; hits and evictions are counted in
    /// [`ExecStats`].
    prefill_memo: RefCell<Vec<(Vec<i32>, PrefillOut)>>,
    /// LRU bound for [`Engine::prefill_memo`] and the chunk-artifact
    /// states (`THINKV_PREFILL_MEMO_CAP`, default [`PREFILL_MEMO_CAP`]).
    memo_cap: usize,
    /// Past-row accumulators for the chunk-artifact prefill path, keyed
    /// by token vector (same LRU discipline as the memo). An evicted
    /// mid-prefill prompt re-runs its earlier chunks on resume.
    chunk_states: RefCell<Vec<(Vec<i32>, ChunkState)>>,
    /// Cumulative PJRT execute wall-time, for the Table-5 style breakdown.
    pub exec_nanos: Cell<u64>,
    pub exec_calls: Cell<u64>,
    decode_execs: Cell<u64>,
    prefill_execs: Cell<u64>,
    fallback_execs: Cell<u64>,
    memo_hits: Cell<u64>,
    memo_evicts: Cell<u64>,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        Engine::with_dir(&default_artifacts_dir())
    }

    pub fn with_dir(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        let weights = load_weights(&format!("{artifacts_dir}/weights.bin"))?;
        // sanity: weight order must match the manifest (HLO parameter order)
        if weights.len() != manifest.weights.len() {
            bail!(
                "weights.bin has {} tensors, manifest lists {}",
                weights.len(),
                manifest.weights.len()
            );
        }
        for (t, (name, shape)) in weights.iter().zip(&manifest.weights) {
            if &t.name != name || &t.shape != shape {
                bail!("weight mismatch: {} vs manifest {}", t.name, name);
            }
        }
        let weight_bufs = weights
            .iter()
            .map(|t| {
                client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .map_err(to_anyhow)
            })
            .collect::<Result<Vec<_>>>()?;
        let memo_cap = std::env::var("THINKV_PREFILL_MEMO_CAP")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(PREFILL_MEMO_CAP);
        Ok(Engine {
            client,
            manifest,
            weight_bufs,
            exes: RefCell::new(HashMap::new()),
            prefill_memo: RefCell::new(Vec::new()),
            memo_cap,
            chunk_states: RefCell::new(Vec::new()),
            exec_nanos: Cell::new(0),
            exec_calls: Cell::new(0),
            decode_execs: Cell::new(0),
            prefill_execs: Cell::new(0),
            fallback_execs: Cell::new(0),
            memo_hits: Cell::new(0),
            memo_evicts: Cell::new(0),
        })
    }

    pub fn model(&self) -> &crate::model::ModelConfig {
        &self.manifest.model
    }

    /// Raw client access (perf instrumentation / microbenches).
    pub fn client_ref(&self) -> &xla::PjRtClient {
        &self.client
    }

    fn exe(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(to_anyhow)
            .with_context(|| format!("loading {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp).map_err(to_anyhow)?);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Precompile an artifact (so later timing excludes compilation).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.exe(name).map(|_| ())
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(to_anyhow)
    }

    fn buf_u8(&self, data: &[u8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<u8>(data, dims, None)
            .map_err(to_anyhow)
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(to_anyhow)
    }

    fn run_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = std::time::Instant::now();
        let res = exe.execute_b(args).map_err(to_anyhow)?;
        let lit = res[0][0].to_literal_sync().map_err(to_anyhow)?;
        self.exec_nanos
            .set(self.exec_nanos.get() + t0.elapsed().as_nanos() as u64);
        self.exec_calls.set(self.exec_calls.get() + 1);
        lit.to_tuple().map_err(to_anyhow)
    }

    /// Run one decode step over either cache family — the single decode
    /// entry point the generic session path uses.
    pub fn decode(
        &self,
        token: i32,
        pos: i32,
        buf_idx: i32,
        view: &CacheView,
    ) -> Result<DecodeOut> {
        match view {
            CacheView::Quant(q) => self.decode_quant(token, pos, buf_idx, q),
            CacheView::Fp32 { capacity, k, v, mask, buf_k, buf_v, buf_mask, shared } => {
                self.decode_fp32(
                    *capacity,
                    token,
                    pos,
                    buf_idx,
                    k,
                    v,
                    mask,
                    buf_k,
                    buf_v,
                    buf_mask,
                    shared.as_ref(),
                )
            }
        }
    }

    /// Run one decode step over the quantized paged cache. When the view
    /// aliases a shared prefix, the single-request artifact (which has no
    /// block table) gets an overlaid copy of the payload rows — the fused
    /// batched path avoids this copy via the arena's prefix segment.
    pub fn decode_quant(
        &self,
        token: i32,
        pos: i32,
        buf_idx: i32,
        cache: &QuantCache,
    ) -> Result<DecodeOut> {
        let m = self.model().clone();
        let (l, c, hkv, dh, g, b) = (
            m.n_layers,
            cache.capacity,
            m.n_kv_heads,
            m.d_head,
            m.groups(),
            m.buf_slots,
        );
        let (kvd, sc) = (hkv * dh, hkv * g);
        let owned;
        let (kc, ks, vc, vs): (&[u8], &[f32], &[u8], &[f32]) = match &cache.shared {
            Some(sh) => {
                let mut kc = cache.k_codes.to_vec();
                let mut ks = cache.k_scales.to_vec();
                let mut vc = cache.v_codes.to_vec();
                let mut vs = cache.v_scales.to_vec();
                for li in 0..l {
                    let (dst, src) = ((li * c) * kvd, (li * sh.full_len) * kvd);
                    kc[dst..dst + sh.len * kvd]
                        .copy_from_slice(&sh.k_codes[src..src + sh.len * kvd]);
                    vc[dst..dst + sh.len * kvd]
                        .copy_from_slice(&sh.v_codes[src..src + sh.len * kvd]);
                    let (dsts, srcs) = ((li * c) * sc, (li * sh.full_len) * sc);
                    ks[dsts..dsts + sh.len * sc]
                        .copy_from_slice(&sh.k_scales[srcs..srcs + sh.len * sc]);
                    vs[dsts..dsts + sh.len * sc]
                        .copy_from_slice(&sh.v_scales[srcs..srcs + sh.len * sc]);
                }
                owned = (kc, ks, vc, vs);
                (&owned.0, &owned.1, &owned.2, &owned.3)
            }
            None => (cache.k_codes, cache.k_scales, cache.v_codes, cache.v_scales),
        };
        let name = self.manifest.decode_quant_name(c);
        let exe = self.exe(&name)?;
        let dyn_bufs = [
            self.buf_i32(&[token], &[1])?,
            self.buf_i32(&[pos], &[1])?,
            self.buf_i32(&[buf_idx], &[1])?,
            self.buf_u8(kc, &[l, c, hkv, dh])?,
            self.buf_f32(ks, &[l, c, hkv, g])?,
            self.buf_u8(vc, &[l, c, hkv, dh])?,
            self.buf_f32(vs, &[l, c, hkv, g])?,
            self.buf_u8(cache.tags, &[l, c])?,
            self.buf_f32(cache.mask, &[l, c])?,
            self.buf_f32(cache.buf_k, &[l, b, hkv, dh])?,
            self.buf_f32(cache.buf_v, &[l, b, hkv, dh])?,
            self.buf_f32(cache.buf_mask, &[l, b])?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(dyn_bufs.iter());
        let outs = self.run_tuple(&exe, &args)?;
        self.decode_execs.set(self.decode_execs.get() + 1);
        decode_out(&outs)
    }

    /// Run one decode step over an f32 paged cache (FullKV / eviction-only).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_fp32(
        &self,
        capacity: usize,
        token: i32,
        pos: i32,
        buf_idx: i32,
        k_cache: &[f32],
        v_cache: &[f32],
        mask: &[f32],
        buf_k: &[f32],
        buf_v: &[f32],
        buf_mask: &[f32],
        shared: Option<&SharedFp32Rows>,
    ) -> Result<DecodeOut> {
        let m = self.model().clone();
        let (l, c, hkv, dh, b) = (m.n_layers, capacity, m.n_kv_heads, m.d_head, m.buf_slots);
        let kvd = hkv * dh;
        let owned;
        let (kc, vc): (&[f32], &[f32]) = match shared {
            Some(sh) => {
                let mut k = k_cache.to_vec();
                let mut v = v_cache.to_vec();
                for li in 0..l {
                    let (dst, src) = ((li * c) * kvd, (li * sh.full_len) * kvd);
                    k[dst..dst + sh.len * kvd].copy_from_slice(&sh.k[src..src + sh.len * kvd]);
                    v[dst..dst + sh.len * kvd].copy_from_slice(&sh.v[src..src + sh.len * kvd]);
                }
                owned = (k, v);
                (&owned.0, &owned.1)
            }
            None => (k_cache, v_cache),
        };
        let name = self.manifest.decode_fp32_name(c);
        let exe = self.exe(&name)?;
        let dyn_bufs = [
            self.buf_i32(&[token], &[1])?,
            self.buf_i32(&[pos], &[1])?,
            self.buf_i32(&[buf_idx], &[1])?,
            self.buf_f32(kc, &[l, c, hkv, dh])?,
            self.buf_f32(vc, &[l, c, hkv, dh])?,
            self.buf_f32(mask, &[l, c])?,
            self.buf_f32(buf_k, &[l, b, hkv, dh])?,
            self.buf_f32(buf_v, &[l, b, hkv, dh])?,
            self.buf_f32(buf_mask, &[l, b])?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(dyn_bufs.iter());
        let outs = self.run_tuple(&exe, &args)?;
        self.decode_execs.set(self.decode_execs.get() + 1);
        decode_out(&outs)
    }

    /// Run prompt prefill (tokens padded/truncated to the exported length).
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let m = self.model().clone();
        let p = m.prefill_len;
        let mut toks = vec![0i32; p];
        for (i, t) in tokens.iter().take(p).enumerate() {
            toks[i] = *t;
        }
        let exe = self.exe(&self.manifest.prefill_name())?;
        let tok_buf = self.buf_i32(&toks, &[p])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        let outs = self.run_tuple(&exe, &args)?;
        self.prefill_execs.set(self.prefill_execs.get() + 1);
        if outs.len() != 4 {
            bail!("prefill returned {} outputs", outs.len());
        }
        Ok(PrefillOut {
            logits: outs[0].to_vec::<f32>().map_err(to_anyhow)?,
            k: outs[1].to_vec::<f32>().map_err(to_anyhow)?,
            v: outs[2].to_vec::<f32>().map_err(to_anyhow)?,
            obs: outs[3].to_vec::<f32>().map_err(to_anyhow)?,
        })
    }

    /// Standalone fused attention (microbench / golden validation).
    #[allow(clippy::too_many_arguments)]
    pub fn attn_micro(
        &self,
        q: &[f32],
        k_codes: &[u8],
        k_scales: &[f32],
        v_codes: &[u8],
        v_scales: &[f32],
        tags: &[u8],
        mask: &[f32],
        buf_k: &[f32],
        buf_v: &[f32],
        buf_mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = self.model().clone();
        let c = self.manifest.micro_c;
        let (h, hkv, dh, g, b) = (m.n_heads, m.n_kv_heads, m.d_head, m.groups(), m.buf_slots);
        let exe = self.exe(&format!("attn_micro_c{c}"))?;
        let bufs = [
            self.buf_f32(q, &[h, dh])?,
            self.buf_u8(k_codes, &[c, hkv, dh])?,
            self.buf_f32(k_scales, &[c, hkv, g])?,
            self.buf_u8(v_codes, &[c, hkv, dh])?,
            self.buf_f32(v_scales, &[c, hkv, g])?,
            self.buf_u8(tags, &[c])?,
            self.buf_f32(mask, &[c])?,
            self.buf_f32(buf_k, &[b, hkv, dh])?,
            self.buf_f32(buf_v, &[b, hkv, dh])?,
            self.buf_f32(buf_mask, &[b])?,
        ];
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = self.run_tuple(&exe, &args)?;
        if outs.len() != 2 {
            bail!("attn_micro returned {} outputs", outs.len());
        }
        Ok((
            outs[0].to_vec::<f32>().map_err(to_anyhow)?,
            outs[1].to_vec::<f32>().map_err(to_anyhow)?,
        ))
    }

    /// Per-member fallback for batches no batched artifact covers: the
    /// pre-tentpole behavior, kept countable so the serving metrics can
    /// show when launch amortization is actually happening.
    fn decode_batch_fallback(&self, reqs: &[BatchDecodeReq<'_>]) -> Result<Vec<DecodeOut>> {
        self.fallback_execs
            .set(self.fallback_execs.get() + reqs.len() as u64);
        reqs.iter()
            .map(|r| Engine::decode(self, r.token, r.pos, r.buf_idx, &r.view))
            .collect()
    }

    /// One fused execute of `decode_quant_c{c}_b{bw}` over `reqs.len()`
    /// live lanes (ragged lanes padded and masked out by `member`).
    ///
    /// Arena layout (matches `decode_quant_batch_shapes`): lane `i`'s
    /// slot `s` lives at arena row `i*C + s`; one shared prompt prefix
    /// is staged exactly once at rows `bw*C ..`, and aliasing lanes'
    /// block tables redirect their prefix slots there. Tags, the CT
    /// eviction mask, and the ring buffers stay per-lane (they diverge
    /// per session even over aliased payload rows).
    fn run_quant_batch(
        &self,
        reqs: &[BatchDecodeReq<'_>],
        bw: usize,
        c: usize,
    ) -> Result<Vec<DecodeOut>> {
        let m = self.model().clone();
        let (l, hkv, dh, g, bufs, p) =
            (m.n_layers, m.n_kv_heads, m.d_head, m.groups(), m.buf_slots, m.prefill_len);
        let (kvd, sc) = (hkv * dh, hkv * g);
        let a = bw * c + p;
        let n = reqs.len();
        debug_assert!(n <= bw, "batch of {n} exceeds compiled width {bw}");

        let mut token = vec![0i32; bw];
        let mut pos = vec![0i32; bw];
        let mut buf_idx = vec![0i32; bw];
        let mut member = vec![0f32; bw];
        let mut bt = vec![0i32; bw * l * c];
        let mut k_codes = vec![0u8; l * a * kvd];
        let mut k_scales = vec![0f32; l * a * sc];
        let mut v_codes = vec![0u8; l * a * kvd];
        let mut v_scales = vec![0f32; l * a * sc];
        let mut tags = vec![0u8; bw * l * c];
        let mut mask = vec![0f32; bw * l * c];
        let mut buf_k = vec![0f32; bw * l * bufs * kvd];
        let mut buf_v = vec![0f32; bw * l * bufs * kvd];
        let mut buf_mask = vec![0f32; bw * l * bufs];

        // one shared-prefix segment per fused call: the first aliasing
        // lane elects the resident copy; lanes aliasing a *different*
        // prefix get their rows composed into their private segment
        let chosen = reqs.iter().find_map(|r| match &r.view {
            CacheView::Quant(q) => q.shared.as_ref(),
            _ => None,
        });
        if let Some(sh) = chosen {
            for li in 0..l {
                let (dst, src) = ((li * a + bw * c) * kvd, (li * sh.full_len) * kvd);
                let rows = sh.full_len * kvd;
                k_codes[dst..dst + rows].copy_from_slice(&sh.k_codes[src..src + rows]);
                v_codes[dst..dst + rows].copy_from_slice(&sh.v_codes[src..src + rows]);
                let (dsts, srcs) = ((li * a + bw * c) * sc, (li * sh.full_len) * sc);
                let srows = sh.full_len * sc;
                k_scales[dsts..dsts + srows].copy_from_slice(&sh.k_scales[srcs..srcs + srows]);
                v_scales[dsts..dsts + srows].copy_from_slice(&sh.v_scales[srcs..srcs + srows]);
            }
        }

        for (i, r) in reqs.iter().enumerate() {
            let q = match &r.view {
                CacheView::Quant(q) => q,
                _ => bail!("mixed cache families in one fused quant batch"),
            };
            token[i] = r.token;
            pos[i] = r.pos;
            buf_idx[i] = r.buf_idx;
            member[i] = 1.0;
            for li in 0..l {
                let (dst, src) = ((li * a + i * c) * kvd, (li * c) * kvd);
                k_codes[dst..dst + c * kvd].copy_from_slice(&q.k_codes[src..src + c * kvd]);
                v_codes[dst..dst + c * kvd].copy_from_slice(&q.v_codes[src..src + c * kvd]);
                let (dsts, srcs) = ((li * a + i * c) * sc, (li * c) * sc);
                k_scales[dsts..dsts + c * sc].copy_from_slice(&q.k_scales[srcs..srcs + c * sc]);
                v_scales[dsts..dsts + c * sc].copy_from_slice(&q.v_scales[srcs..srcs + c * sc]);
            }
            tags[i * l * c..(i + 1) * l * c].copy_from_slice(q.tags);
            mask[i * l * c..(i + 1) * l * c].copy_from_slice(q.mask);
            buf_k[i * l * bufs * kvd..(i + 1) * l * bufs * kvd].copy_from_slice(q.buf_k);
            buf_v[i * l * bufs * kvd..(i + 1) * l * bufs * kvd].copy_from_slice(q.buf_v);
            buf_mask[i * l * bufs..(i + 1) * l * bufs].copy_from_slice(q.buf_mask);
            for li in 0..l {
                let row = (i * l + li) * c;
                for s in 0..c {
                    bt[row + s] = (i * c + s) as i32;
                }
            }
            if let Some(sh) = &q.shared {
                if chosen.map(|e| e.id) == Some(sh.id) {
                    for li in 0..l {
                        let row = (i * l + li) * c;
                        for s in 0..sh.len {
                            bt[row + s] = (bw * c + s) as i32;
                        }
                    }
                } else {
                    for li in 0..l {
                        let (dst, src) = ((li * a + i * c) * kvd, (li * sh.full_len) * kvd);
                        let rows = sh.len * kvd;
                        k_codes[dst..dst + rows].copy_from_slice(&sh.k_codes[src..src + rows]);
                        v_codes[dst..dst + rows].copy_from_slice(&sh.v_codes[src..src + rows]);
                        let (dsts, srcs) = ((li * a + i * c) * sc, (li * sh.full_len) * sc);
                        let srows = sh.len * sc;
                        k_scales[dsts..dsts + srows]
                            .copy_from_slice(&sh.k_scales[srcs..srcs + srows]);
                        v_scales[dsts..dsts + srows]
                            .copy_from_slice(&sh.v_scales[srcs..srcs + srows]);
                    }
                }
            }
        }

        let exe = self.exe(&self.manifest.decode_quant_batch_name(c, bw))?;
        let dyn_bufs = [
            self.buf_i32(&token, &[bw])?,
            self.buf_i32(&pos, &[bw])?,
            self.buf_i32(&buf_idx, &[bw])?,
            self.buf_f32(&member, &[bw])?,
            self.buf_i32(&bt, &[bw, l, c])?,
            self.buf_u8(&k_codes, &[l, a, hkv, dh])?,
            self.buf_f32(&k_scales, &[l, a, hkv, g])?,
            self.buf_u8(&v_codes, &[l, a, hkv, dh])?,
            self.buf_f32(&v_scales, &[l, a, hkv, g])?,
            self.buf_u8(&tags, &[bw, l, c])?,
            self.buf_f32(&mask, &[bw, l, c])?,
            self.buf_f32(&buf_k, &[bw, l, bufs, hkv, dh])?,
            self.buf_f32(&buf_v, &[bw, l, bufs, hkv, dh])?,
            self.buf_f32(&buf_mask, &[bw, l, bufs])?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(dyn_bufs.iter());
        let outs = self.run_tuple(&exe, &args)?;
        self.decode_execs.set(self.decode_execs.get() + 1);
        split_batch_out(&m, &outs, n, c)
    }

    /// One fused execute of `decode_fp32_c{c}_b{bw}` — the f32-arena twin
    /// of [`Engine::run_quant_batch`] (same block-table contract).
    fn run_fp32_batch(
        &self,
        reqs: &[BatchDecodeReq<'_>],
        bw: usize,
        c: usize,
    ) -> Result<Vec<DecodeOut>> {
        let m = self.model().clone();
        let (l, hkv, dh, bufs, p) =
            (m.n_layers, m.n_kv_heads, m.d_head, m.buf_slots, m.prefill_len);
        let kvd = hkv * dh;
        let a = bw * c + p;
        let n = reqs.len();
        debug_assert!(n <= bw, "batch of {n} exceeds compiled width {bw}");

        let mut token = vec![0i32; bw];
        let mut pos = vec![0i32; bw];
        let mut buf_idx = vec![0i32; bw];
        let mut member = vec![0f32; bw];
        let mut bt = vec![0i32; bw * l * c];
        let mut k_cache = vec![0f32; l * a * kvd];
        let mut v_cache = vec![0f32; l * a * kvd];
        let mut mask_all = vec![0f32; bw * l * c];
        let mut buf_k = vec![0f32; bw * l * bufs * kvd];
        let mut buf_v = vec![0f32; bw * l * bufs * kvd];
        let mut buf_mask = vec![0f32; bw * l * bufs];

        let chosen = reqs.iter().find_map(|r| match &r.view {
            CacheView::Fp32 { shared, .. } => shared.as_ref(),
            _ => None,
        });
        if let Some(sh) = chosen {
            for li in 0..l {
                let (dst, src) = ((li * a + bw * c) * kvd, (li * sh.full_len) * kvd);
                let rows = sh.full_len * kvd;
                k_cache[dst..dst + rows].copy_from_slice(&sh.k[src..src + rows]);
                v_cache[dst..dst + rows].copy_from_slice(&sh.v[src..src + rows]);
            }
        }

        for (i, r) in reqs.iter().enumerate() {
            let (k, v, mask, bk, bv, bm, shared) = match &r.view {
                CacheView::Fp32 { k, v, mask, buf_k, buf_v, buf_mask, shared, .. } => {
                    (*k, *v, *mask, *buf_k, *buf_v, *buf_mask, shared.as_ref())
                }
                _ => bail!("mixed cache families in one fused fp32 batch"),
            };
            token[i] = r.token;
            pos[i] = r.pos;
            buf_idx[i] = r.buf_idx;
            member[i] = 1.0;
            for li in 0..l {
                let (dst, src) = ((li * a + i * c) * kvd, (li * c) * kvd);
                k_cache[dst..dst + c * kvd].copy_from_slice(&k[src..src + c * kvd]);
                v_cache[dst..dst + c * kvd].copy_from_slice(&v[src..src + c * kvd]);
            }
            mask_all[i * l * c..(i + 1) * l * c].copy_from_slice(mask);
            buf_k[i * l * bufs * kvd..(i + 1) * l * bufs * kvd].copy_from_slice(bk);
            buf_v[i * l * bufs * kvd..(i + 1) * l * bufs * kvd].copy_from_slice(bv);
            buf_mask[i * l * bufs..(i + 1) * l * bufs].copy_from_slice(bm);
            for li in 0..l {
                let row = (i * l + li) * c;
                for s in 0..c {
                    bt[row + s] = (i * c + s) as i32;
                }
            }
            if let Some(sh) = shared {
                if chosen.map(|e| e.id) == Some(sh.id) {
                    for li in 0..l {
                        let row = (i * l + li) * c;
                        for s in 0..sh.len {
                            bt[row + s] = (bw * c + s) as i32;
                        }
                    }
                } else {
                    for li in 0..l {
                        let (dst, src) = ((li * a + i * c) * kvd, (li * sh.full_len) * kvd);
                        let rows = sh.len * kvd;
                        k_cache[dst..dst + rows].copy_from_slice(&sh.k[src..src + rows]);
                        v_cache[dst..dst + rows].copy_from_slice(&sh.v[src..src + rows]);
                    }
                }
            }
        }

        let exe = self.exe(&self.manifest.decode_fp32_batch_name(c, bw))?;
        let dyn_bufs = [
            self.buf_i32(&token, &[bw])?,
            self.buf_i32(&pos, &[bw])?,
            self.buf_i32(&buf_idx, &[bw])?,
            self.buf_f32(&member, &[bw])?,
            self.buf_i32(&bt, &[bw, l, c])?,
            self.buf_f32(&k_cache, &[l, a, hkv, dh])?,
            self.buf_f32(&v_cache, &[l, a, hkv, dh])?,
            self.buf_f32(&mask_all, &[bw, l, c])?,
            self.buf_f32(&buf_k, &[bw, l, bufs, hkv, dh])?,
            self.buf_f32(&buf_v, &[bw, l, bufs, hkv, dh])?,
            self.buf_f32(&buf_mask, &[bw, l, bufs])?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(dyn_bufs.iter());
        let outs = self.run_tuple(&exe, &args)?;
        self.decode_execs.set(self.decode_execs.get() + 1);
        split_batch_out(&m, &outs, n, c)
    }

    /// Can `[start, start+len)` be served by the chunk artifacts? Both
    /// ends must sit on the smallest compiled chunk's grid (every larger
    /// compiled length is a multiple of it, so any on-grid span covers
    /// greedily); `len == 0` (logits-only final chunk) needs a whole
    /// prefill and stays on the memo path.
    fn can_chunk(&self, start: usize, len: usize) -> bool {
        match self.manifest.prefill_chunk_lens.iter().min() {
            Some(&g) => len > 0 && start % g == 0 && len % g == 0,
            None => false,
        }
    }

    /// Serve one prefill chunk with the `prefill_chunk_p{P}_n{N}`
    /// artifacts: take (or create) this prompt's past-row state, catch
    /// up rows `[filled, start)` that an attach skipped, then cover
    /// `[start, start+len)` greedily with compiled sub-chunks — one
    /// PJRT execute per sub-chunk, no whole-prompt execute anywhere.
    fn prefill_chunk_hlo(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
    ) -> Result<PrefillChunkOut> {
        let m = self.model().clone();
        let p = m.prefill_len;
        let kvd = m.n_kv_heads * m.d_head;
        let mut st = {
            let mut states = self.chunk_states.borrow_mut();
            match states.iter().position(|(t, _)| t.as_slice() == tokens) {
                Some(i) => states.remove(i).1,
                None => ChunkState {
                    filled: 0,
                    past_k: vec![0f32; m.n_layers * p * kvd],
                    past_v: vec![0f32; m.n_layers * p * kvd],
                },
            }
        };
        if st.filled < start {
            // a shared-prefix attach starts mid-prompt: the skipped rows
            // must exist before this chunk can attend over them
            self.run_chunks(tokens, st.filled, start - st.filled, &mut st)?;
        }
        let out = self.run_chunks(tokens, start, len, &mut st)?;
        if start + len < p {
            // prompt still mid-prefill: keep the state warm (LRU, back =
            // most recent); the final chunk retires it instead
            let mut states = self.chunk_states.borrow_mut();
            if states.len() >= self.memo_cap {
                states.remove(0);
                self.memo_evicts.set(self.memo_evicts.get() + 1);
            }
            states.push((tokens.to_vec(), st));
        }
        Ok(out)
    }

    /// Cover `[start, start+len)` with compiled chunk executes (largest
    /// first), appending each sub-chunk's K/V to `st` so later chunks
    /// attend over it. Logits are captured from the sub-execute that
    /// ends at `prefill_len` — the whole-prompt last-position logits.
    fn run_chunks(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
        st: &mut ChunkState,
    ) -> Result<PrefillChunkOut> {
        let m = self.model().clone();
        let p = m.prefill_len;
        let l = m.n_layers;
        let kvd = m.n_kv_heads * m.d_head;
        let g = *self
            .manifest
            .prefill_chunk_lens
            .iter()
            .min()
            .context("no chunk artifacts")?;
        let mut lens: Vec<usize> = self
            .manifest
            .prefill_chunk_lens
            .iter()
            .copied()
            .filter(|&cl| cl % g == 0)
            .collect();
        lens.sort_unstable_by(|x, y| y.cmp(x));
        let mut k = vec![0f32; l * len * kvd];
        let mut v = vec![0f32; l * len * kvd];
        let mut logits = Vec::new();
        let mut off = 0usize;
        while off < len {
            let rem = len - off;
            let n = lens
                .iter()
                .copied()
                .find(|&cl| cl <= rem)
                .with_context(|| format!("no chunk artifact covers remaining {rem} rows"))?;
            let s0 = start + off;
            let mut toks = vec![0i32; n];
            for (j, t) in toks.iter_mut().enumerate() {
                if s0 + j < tokens.len() && s0 + j < p {
                    *t = tokens[s0 + j];
                }
            }
            let exe = self.exe(&self.manifest.prefill_chunk_name(n))?;
            let dyn_bufs = [
                self.buf_i32(&toks, &[n])?,
                self.buf_i32(&[s0 as i32], &[1])?,
                self.buf_f32(&st.past_k, &[l, p, m.n_kv_heads, m.d_head])?,
                self.buf_f32(&st.past_v, &[l, p, m.n_kv_heads, m.d_head])?,
            ];
            let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
            args.extend(dyn_bufs.iter());
            let outs = self.run_tuple(&exe, &args)?;
            self.prefill_execs.set(self.prefill_execs.get() + 1);
            if outs.len() != 4 {
                bail!("prefill chunk returned {} outputs", outs.len());
            }
            let ck = outs[1].to_vec::<f32>().map_err(to_anyhow)?;
            let cv = outs[2].to_vec::<f32>().map_err(to_anyhow)?;
            for li in 0..l {
                let src = (li * n) * kvd;
                let dst = (li * len + off) * kvd;
                k[dst..dst + n * kvd].copy_from_slice(&ck[src..src + n * kvd]);
                v[dst..dst + n * kvd].copy_from_slice(&cv[src..src + n * kvd]);
                let past = (li * p + s0) * kvd;
                st.past_k[past..past + n * kvd].copy_from_slice(&ck[src..src + n * kvd]);
                st.past_v[past..past + n * kvd].copy_from_slice(&cv[src..src + n * kvd]);
            }
            if s0 + n == p {
                logits = outs[0].to_vec::<f32>().map_err(to_anyhow)?;
            }
            st.filled = st.filled.max(s0 + n);
            off += n;
        }
        // the chunk artifacts do not compute the SnapKV observation
        // statistic (it needs the last obs_window whole-prompt queries);
        // obs-consuming modes take the whole-prompt prefill path
        Ok(PrefillChunkOut { logits, k, v, obs: vec![0f32; l * len] })
    }
}

/// The fused decode surface over the PJRT artifacts. `decode_batch`
/// drives the multi-request `decode_*_c{C}_b{B}` modules: the batch is
/// padded up to the narrowest compiled width that covers it (ragged
/// lanes masked out by `member`), each lane's slabs land in a private
/// segment of one physical arena, a shared prompt prefix is staged in
/// the arena's extra prefix segment exactly once, and per-lane block
/// tables gather every view — **one PJRT execute advances the whole
/// batch**. Batches wider than the widest compiled module split
/// greedily into fused sub-executes; a build without batched artifacts
/// (or a heterogeneous direct call) falls back to per-member executes,
/// counted in [`ExecStats::fallback_executes`]. The launch-amortization
/// effect is priced by [`crate::sim::ServingCost::decode_step`] vs
/// [`crate::sim::ServingCost::decode_step_per_session`] and re-anchored
/// against measured execute times in `bench_scheduler`.
///
/// `prefill_chunk` drives the `prefill_chunk_p{P}_n{N}` modules the
/// same way — one execute per chunk against the accumulated past rows —
/// and falls back to a bounded LRU-memoized whole-prompt prefill when
/// chunk artifacts are absent or the chunk geometry is off the compiled
/// grid.
impl DecodeEngine for Engine {
    fn model(&self) -> &crate::model::ModelConfig {
        Engine::model(self)
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        Engine::prefill(self, tokens)
    }

    fn decode(&self, token: i32, pos: i32, buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
        Engine::decode(self, token, pos, buf_idx, view)
    }

    fn decode_batch(&self, reqs: &[BatchDecodeReq<'_>]) -> Result<Vec<DecodeOut>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        // family/capacity homogeneity: the scheduler's BatchKey grouping
        // guarantees it; a heterogeneous direct call falls back
        let fused_ok = !self.manifest.batch_widths.is_empty()
            && match &reqs[0].view {
                CacheView::Quant(q0) => reqs.iter().all(
                    |r| matches!(&r.view, CacheView::Quant(q) if q.capacity == q0.capacity),
                ),
                CacheView::Fp32 { capacity: c0, .. } => reqs.iter().all(
                    |r| matches!(&r.view, CacheView::Fp32 { capacity, .. } if capacity == c0),
                ),
            };
        if !fused_ok {
            return self.decode_batch_fallback(reqs);
        }
        let mut outs = Vec::with_capacity(reqs.len());
        let mut rest = reqs;
        while !rest.is_empty() {
            let n = rest.len();
            let bw = self
                .manifest
                .pick_batch_width(n)
                .or_else(|| self.manifest.widest_batch_width(n))
                .expect("batch_widths checked nonempty");
            let (chunk, tail) = rest.split_at(n.min(bw));
            outs.extend(match &chunk[0].view {
                CacheView::Quant(q) => self.run_quant_batch(chunk, bw, q.capacity)?,
                CacheView::Fp32 { capacity, .. } => self.run_fp32_batch(chunk, bw, *capacity)?,
            });
            rest = tail;
        }
        Ok(outs)
    }

    fn prefill_chunk(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
        _view: &CacheView,
    ) -> Result<PrefillChunkOut> {
        let p = self.model().prefill_len;
        if start == 0 && len == p {
            // whole-prompt "chunk" (the chunking-disabled path): run the
            // prefill directly and move its buffers through — no memo
            // entry, no slice copy
            let PrefillOut { logits, k, v, obs } = Engine::prefill(self, tokens)?;
            return Ok(PrefillChunkOut { logits, k, v, obs });
        }
        if self.can_chunk(start, len) {
            return self.prefill_chunk_hlo(tokens, start, len);
        }
        // fallback: slice a memoized whole-prompt prefill (LRU, back =
        // most recently used)
        let found = self
            .prefill_memo
            .borrow()
            .iter()
            .position(|(t, _)| t.as_slice() == tokens);
        let out = match found {
            Some(i) => {
                self.memo_hits.set(self.memo_hits.get() + 1);
                let mut memo = self.prefill_memo.borrow_mut();
                let entry = memo.remove(i);
                let out = slice_prefill_chunk(self.model(), &entry.1, start, len)?;
                memo.push(entry);
                out
            }
            None => {
                let pf = Engine::prefill(self, tokens)?;
                let out = slice_prefill_chunk(self.model(), &pf, start, len)?;
                let mut memo = self.prefill_memo.borrow_mut();
                if memo.len() >= self.memo_cap {
                    memo.remove(0); // least-recent prompt pays a re-execute
                    self.memo_evicts.set(self.memo_evicts.get() + 1);
                }
                memo.push((tokens.to_vec(), pf));
                out
            }
        };
        // the final chunk retires the entry: the prompt is fully sliced
        // and a stale image must not outlive its session
        if start + len == p {
            self.prefill_memo
                .borrow_mut()
                .retain(|(t, _)| t.as_slice() != tokens);
        }
        Ok(out)
    }

    fn exec_stats(&self) -> ExecStats {
        ExecStats {
            decode_executes: self.decode_execs.get(),
            prefill_executes: self.prefill_execs.get(),
            fallback_executes: self.fallback_execs.get(),
            prefill_memo_hits: self.memo_hits.get(),
            prefill_memo_evictions: self.memo_evicts.get(),
        }
    }
}

fn decode_out(outs: &[xla::Literal]) -> Result<DecodeOut> {
    if outs.len() != 4 {
        bail!("decode step returned {} outputs, want 4", outs.len());
    }
    Ok(DecodeOut {
        logits: outs[0].to_vec::<f32>().map_err(to_anyhow)?,
        new_k: outs[1].to_vec::<f32>().map_err(to_anyhow)?,
        new_v: outs[2].to_vec::<f32>().map_err(to_anyhow)?,
        probs: outs[3].to_vec::<f32>().map_err(to_anyhow)?,
    })
}

/// Split stacked batched-decode outputs (`logits (B,V)`, `new_k/new_v
/// (B,L,Hkv,Dh)`, `probs (B,L,H,C+BUF)`) back into the first `n` live
/// lanes' per-member [`DecodeOut`]s (padded lanes are dropped).
fn split_batch_out(
    m: &crate::model::ModelConfig,
    outs: &[xla::Literal],
    n: usize,
    c: usize,
) -> Result<Vec<DecodeOut>> {
    if outs.len() != 4 {
        bail!("batched decode returned {} outputs, want 4", outs.len());
    }
    let logits_all = outs[0].to_vec::<f32>().map_err(to_anyhow)?;
    let k_all = outs[1].to_vec::<f32>().map_err(to_anyhow)?;
    let v_all = outs[2].to_vec::<f32>().map_err(to_anyhow)?;
    let probs_all = outs[3].to_vec::<f32>().map_err(to_anyhow)?;
    let kvd = m.n_kv_heads * m.d_head;
    let (sv, sk, sp) = (
        m.vocab,
        m.n_layers * kvd,
        m.n_layers * m.n_heads * (c + m.buf_slots),
    );
    if logits_all.len() < n * sv || k_all.len() < n * sk || probs_all.len() < n * sp {
        bail!("batched decode outputs narrower than {n} lanes");
    }
    Ok((0..n)
        .map(|i| DecodeOut {
            logits: logits_all[i * sv..(i + 1) * sv].to_vec(),
            new_k: k_all[i * sk..(i + 1) * sk].to_vec(),
            new_v: v_all[i * sk..(i + 1) * sk].to_vec(),
            probs: probs_all[i * sp..(i + 1) * sp].to_vec(),
        })
        .collect())
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
