//! The PJRT execution engine: compiles HLO-text artifacts once and runs
//! prefill / decode steps against caller-owned cache state.
//!
//! One `Engine` per worker thread. Weights are uploaded to device buffers at
//! construction and shared by every call (`execute_b`), so a decode step
//! only transfers the per-request cache tensors and scalars.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::model::{default_artifacts_dir, Manifest};
use crate::runtime::weights::load_weights;

/// Borrowed view of a request's quantized paged cache (layouts: DESIGN §1).
pub struct QuantCache<'a> {
    pub capacity: usize,
    pub k_codes: &'a [u8],   // [L, C, Hkv, Dh]
    pub k_scales: &'a [f32], // [L, C, Hkv, G]
    pub v_codes: &'a [u8],
    pub v_scales: &'a [f32],
    pub tags: &'a [u8],  // [L, C]
    pub mask: &'a [f32], // [L, C]
    pub buf_k: &'a [f32],    // [L, BUF, Hkv, Dh]
    pub buf_v: &'a [f32],
    pub buf_mask: &'a [f32], // [L, BUF]
}

/// Borrowed view of a request's cache in whichever family it lives —
/// what [`crate::kvcache::KvBackend::view`] hands the engine so the
/// session decode loop stays generic over compression modes.
pub enum CacheView<'a> {
    /// Quantized paged cache (ThinKV / KIVI / PM-KVQ).
    Quant(QuantCache<'a>),
    /// F32 paged cache (FullKV / eviction baselines).
    Fp32 {
        capacity: usize,
        k: &'a [f32],
        v: &'a [f32],
        mask: &'a [f32],
        buf_k: &'a [f32],
        buf_v: &'a [f32],
        buf_mask: &'a [f32],
    },
}

/// One member of a fused cross-session decode step: the scalars plus the
/// borrowed cache view [`DecodeEngine::decode_batch`] advances together.
pub struct BatchDecodeReq<'a> {
    /// Last sampled token (the decode-step input).
    pub token: i32,
    /// Current CoT position.
    pub pos: i32,
    /// Ring-buffer fill (next free buffer slot).
    pub buf_idx: i32,
    /// Borrowed view of this member's cache slabs.
    pub view: CacheView<'a>,
}

/// The engine surface the serving session/worker loop drives — one
/// prefill plus single and fused (cross-session batched) decode steps.
///
/// [`Engine`] implements this over the AOT PJRT artifacts; tests
/// implement it with deterministic synthetic engines so scheduler and
/// session behavior (including batched-vs-sequential stream invariance)
/// can be verified without artifacts.
///
/// # Example
///
/// A deterministic fake engine: `decode_batch` (the fused entry point
/// workers call once per batch per step) advances every member and
/// returns their outputs in order:
///
/// ```
/// use anyhow::Result;
/// use thinkv::kvcache::{CacheConfig, CtCache};
/// use thinkv::model::ModelConfig;
/// use thinkv::runtime::{BatchDecodeReq, CacheView, DecodeEngine, DecodeOut, PrefillOut};
///
/// struct FixedEngine {
///     m: ModelConfig,
/// }
///
/// impl DecodeEngine for FixedEngine {
///     fn model(&self) -> &ModelConfig {
///         &self.m
///     }
///     fn prefill(&self, _tokens: &[i32]) -> Result<PrefillOut> {
///         unimplemented!("not exercised here")
///     }
///     fn decode(&self, token: i32, pos: i32, _buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
///         let span = match view {
///             CacheView::Quant(q) => q.capacity,
///             CacheView::Fp32 { capacity, .. } => *capacity,
///         } + self.m.buf_slots;
///         let kvd = self.m.n_kv_heads * self.m.d_head;
///         Ok(DecodeOut {
///             logits: vec![(token + pos) as f32; self.m.vocab],
///             new_k: vec![0.0; self.m.n_layers * kvd],
///             new_v: vec![0.0; self.m.n_layers * kvd],
///             probs: vec![0.0; self.m.n_layers * self.m.n_heads * span],
///         })
///     }
/// }
///
/// let m = ModelConfig {
///     vocab: 8, d_model: 8, n_layers: 1, n_heads: 1, n_kv_heads: 1, d_head: 16,
///     d_ffn: 8, rope_base: 10000.0, buf_slots: 4, prefill_len: 4, obs_window: 2,
///     group_size: 16,
/// };
/// let eng = FixedEngine { m };
/// let cache = CtCache::new(CacheConfig {
///     layers: 1, capacity: 16, block_size: 8, hkv: 1, dh: 16, buf_slots: 4,
/// });
/// let reqs = [
///     BatchDecodeReq { token: 1, pos: 4, buf_idx: 0, view: CacheView::Quant(cache.view()) },
///     BatchDecodeReq { token: 2, pos: 4, buf_idx: 0, view: CacheView::Quant(cache.view()) },
/// ];
/// let outs = eng.decode_batch(&reqs).unwrap(); // one fused step, two streams
/// assert_eq!(outs.len(), 2);
/// assert_eq!(outs[0].logits[0], 5.0);
/// assert_eq!(outs[1].logits[0], 6.0);
/// ```
pub trait DecodeEngine {
    /// The model dimensions every step is shaped by.
    fn model(&self) -> &crate::model::ModelConfig;

    /// Run prompt prefill (tokens padded/truncated to the exported length).
    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut>;

    /// Run one **chunk** of prompt prefill: K/V for positions
    /// `[start, start + len)` only, so the scheduler can interleave a
    /// long prompt's prefill with ongoing fused decode steps instead of
    /// head-of-line-blocking a whole decode batch on one inline prefill.
    /// `view` is the caller's cache already holding positions
    /// `0..start` — what a true chunked-prefill kernel attends to.
    ///
    /// `logits` in the returned chunk are the last-position logits of
    /// the whole prompt and are meaningful only on the **final** chunk
    /// (`start + len == prefill_len`), where the caller bootstraps the
    /// first generated token from them. `len == 0` is allowed for a
    /// logits-only final chunk (a shared prefix covered every prompt
    /// position).
    ///
    /// Chunking must be **bit-invariant**: any chunking of `0..p_len`
    /// must produce the exact K/V (and final logits) of one
    /// [`DecodeEngine::prefill`] call. The default implementation runs
    /// the whole prefill and slices, so it satisfies the invariant by
    /// construction (a whole-prompt "chunk" moves the prefill buffers
    /// straight through, copy-free); engines with a real chunked kernel
    /// may override.
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
        _view: &CacheView,
    ) -> Result<PrefillChunkOut> {
        let pf = self.prefill(tokens)?;
        if start == 0 && len == self.model().prefill_len {
            // the single-chunk case IS a whole prefill: same layout,
            // no slice copy
            let PrefillOut { logits, k, v, obs } = pf;
            return Ok(PrefillChunkOut { logits, k, v, obs });
        }
        slice_prefill_chunk(self.model(), &pf, start, len)
    }

    /// Run one decode step for a single session over either cache family.
    fn decode(&self, token: i32, pos: i32, buf_idx: i32, view: &CacheView) -> Result<DecodeOut>;

    /// One **fused** decode step over a batch of compatible sessions
    /// (same [`crate::kvcache::BatchKey`]: cache family + compiled
    /// capacity): the scheduler forms the batch, the worker makes one
    /// `decode_batch` call per step, and every member advances by one
    /// token. Outputs are returned in request order. Must be
    /// semantically identical to calling [`DecodeEngine::decode`] per
    /// member — batching is a launch-amortization strategy, never a
    /// numerics change (stream invariance).
    fn decode_batch(&self, reqs: &[BatchDecodeReq<'_>]) -> Result<Vec<DecodeOut>> {
        reqs.iter()
            .map(|r| self.decode(r.token, r.pos, r.buf_idx, &r.view))
            .collect()
    }
}

/// Outputs of one decode step.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>, // [V]
    pub new_k: Vec<f32>,  // [L, Hkv, Dh] (post-RoPE)
    pub new_v: Vec<f32>,  // [L, Hkv, Dh]
    pub probs: Vec<f32>,  // [L, H, C+BUF]
}

/// Outputs of prompt prefill.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub logits: Vec<f32>, // [V] (last position)
    pub k: Vec<f32>,      // [L, P, Hkv, Dh] post-RoPE
    pub v: Vec<f32>,      // [L, P, Hkv, Dh]
    pub obs: Vec<f32>,    // [L, P] SnapKV observation stats
}

/// Outputs of one prefill chunk ([`DecodeEngine::prefill_chunk`]):
/// prompt positions `[start, start + len)` in chunk-local layout.
#[derive(Debug, Clone)]
pub struct PrefillChunkOut {
    /// Last-position logits of the **whole** prompt — populated (and
    /// meaningful) only on the final chunk, where the first generated
    /// token is sampled; may be empty on earlier chunks.
    pub logits: Vec<f32>, // [V]
    pub k: Vec<f32>,      // [L, len, Hkv, Dh] post-RoPE
    pub v: Vec<f32>,      // [L, len, Hkv, Dh]
    pub obs: Vec<f32>,    // [L, len]
}

/// Slice positions `[start, start + len)` out of a full prefill — the
/// shared body of the default [`DecodeEngine::prefill_chunk`] and the
/// memoizing [`Engine`] override. Logits are copied only for the final
/// chunk (the only one whose logits a caller may read).
fn slice_prefill_chunk(
    m: &crate::model::ModelConfig,
    pf: &PrefillOut,
    start: usize,
    len: usize,
) -> Result<PrefillChunkOut> {
    let p = m.prefill_len;
    if start + len > p {
        bail!("prefill chunk [{start}, {}) exceeds prefill_len {p}", start + len);
    }
    let kvd = m.n_kv_heads * m.d_head;
    let mut k = Vec::with_capacity(m.n_layers * len * kvd);
    let mut v = Vec::with_capacity(m.n_layers * len * kvd);
    let mut obs = Vec::with_capacity(m.n_layers * len);
    for l in 0..m.n_layers {
        let base = (l * p + start) * kvd;
        k.extend_from_slice(&pf.k[base..base + len * kvd]);
        v.extend_from_slice(&pf.v[base..base + len * kvd]);
        obs.extend_from_slice(&pf.obs[l * p + start..l * p + start + len]);
    }
    let logits = if start + len == p { pf.logits.clone() } else { Vec::new() };
    Ok(PrefillChunkOut { logits, k, v, obs })
}

/// Prompts whose full-prefill image the chunked-prefill memo keeps warm
/// at once. Each entry is a whole-prompt fp32 [`PrefillOut`] — the
/// largest host allocation in the process at real model dims — so the
/// cap is deliberately tight: the scheduler runs **one** prefill lane
/// per batch, so 2 covers the active lane plus one rotation. A worker
/// alternating more than two mid-prefill prompts (or a session
/// abandoned mid-prefill, whose entry is only reclaimed by this FIFO)
/// pays a bounded re-execute instead of pinning unbounded host memory.
const PREFILL_MEMO_CAP: usize = 2;

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    weight_bufs: Vec<xla::PjRtBuffer>,
    exes: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Memoized full-prompt prefills, keyed by token vector (FIFO,
    /// bounded by [`PREFILL_MEMO_CAP`]). The chunked-prefill entry
    /// point slices the single-request prefill artifact per chunk;
    /// this keeps every in-flight prompt's successive chunks from
    /// re-executing it (one PJRT execute per prompt, not per chunk),
    /// even when the scheduler alternates prefill lanes between
    /// sessions mid-prefill. Entries retire at their final chunk. A
    /// true chunked-prefill artifact slots in behind
    /// [`DecodeEngine::prefill_chunk`] without touching any caller.
    prefill_memo: RefCell<Vec<(Vec<i32>, PrefillOut)>>,
    /// Cumulative PJRT execute wall-time, for the Table-5 style breakdown.
    pub exec_nanos: std::cell::Cell<u64>,
    pub exec_calls: std::cell::Cell<u64>,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        Engine::with_dir(&default_artifacts_dir())
    }

    pub fn with_dir(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        let weights = load_weights(&format!("{artifacts_dir}/weights.bin"))?;
        // sanity: weight order must match the manifest (HLO parameter order)
        if weights.len() != manifest.weights.len() {
            bail!(
                "weights.bin has {} tensors, manifest lists {}",
                weights.len(),
                manifest.weights.len()
            );
        }
        for (t, (name, shape)) in weights.iter().zip(&manifest.weights) {
            if &t.name != name || &t.shape != shape {
                bail!("weight mismatch: {} vs manifest {}", t.name, name);
            }
        }
        let weight_bufs = weights
            .iter()
            .map(|t| {
                client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .map_err(to_anyhow)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Engine {
            client,
            manifest,
            weight_bufs,
            exes: RefCell::new(HashMap::new()),
            prefill_memo: RefCell::new(Vec::new()),
            exec_nanos: std::cell::Cell::new(0),
            exec_calls: std::cell::Cell::new(0),
        })
    }

    pub fn model(&self) -> &crate::model::ModelConfig {
        &self.manifest.model
    }

    /// Raw client access (perf instrumentation / microbenches).
    pub fn client_ref(&self) -> &xla::PjRtClient {
        &self.client
    }

    fn exe(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(to_anyhow)
            .with_context(|| format!("loading {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp).map_err(to_anyhow)?);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Precompile an artifact (so later timing excludes compilation).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.exe(name).map(|_| ())
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(to_anyhow)
    }

    fn buf_u8(&self, data: &[u8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<u8>(data, dims, None)
            .map_err(to_anyhow)
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(to_anyhow)
    }

    fn run_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = std::time::Instant::now();
        let res = exe.execute_b(args).map_err(to_anyhow)?;
        let lit = res[0][0].to_literal_sync().map_err(to_anyhow)?;
        self.exec_nanos
            .set(self.exec_nanos.get() + t0.elapsed().as_nanos() as u64);
        self.exec_calls.set(self.exec_calls.get() + 1);
        lit.to_tuple().map_err(to_anyhow)
    }

    /// Run one decode step over either cache family — the single decode
    /// entry point the generic session path uses.
    pub fn decode(
        &self,
        token: i32,
        pos: i32,
        buf_idx: i32,
        view: &CacheView,
    ) -> Result<DecodeOut> {
        match view {
            CacheView::Quant(q) => self.decode_quant(token, pos, buf_idx, q),
            CacheView::Fp32 { capacity, k, v, mask, buf_k, buf_v, buf_mask } => self
                .decode_fp32(*capacity, token, pos, buf_idx, k, v, mask, buf_k, buf_v, buf_mask),
        }
    }

    /// Run one decode step over the quantized paged cache.
    pub fn decode_quant(
        &self,
        token: i32,
        pos: i32,
        buf_idx: i32,
        cache: &QuantCache,
    ) -> Result<DecodeOut> {
        let m = self.model().clone();
        let (l, c, hkv, dh, g, b) = (
            m.n_layers,
            cache.capacity,
            m.n_kv_heads,
            m.d_head,
            m.groups(),
            m.buf_slots,
        );
        let name = self.manifest.decode_quant_name(c);
        let exe = self.exe(&name)?;
        let dyn_bufs = [
            self.buf_i32(&[token], &[1])?,
            self.buf_i32(&[pos], &[1])?,
            self.buf_i32(&[buf_idx], &[1])?,
            self.buf_u8(cache.k_codes, &[l, c, hkv, dh])?,
            self.buf_f32(cache.k_scales, &[l, c, hkv, g])?,
            self.buf_u8(cache.v_codes, &[l, c, hkv, dh])?,
            self.buf_f32(cache.v_scales, &[l, c, hkv, g])?,
            self.buf_u8(cache.tags, &[l, c])?,
            self.buf_f32(cache.mask, &[l, c])?,
            self.buf_f32(cache.buf_k, &[l, b, hkv, dh])?,
            self.buf_f32(cache.buf_v, &[l, b, hkv, dh])?,
            self.buf_f32(cache.buf_mask, &[l, b])?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(dyn_bufs.iter());
        let outs = self.run_tuple(&exe, &args)?;
        decode_out(outs)
    }

    /// Run one decode step over an f32 paged cache (FullKV / eviction-only).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_fp32(
        &self,
        capacity: usize,
        token: i32,
        pos: i32,
        buf_idx: i32,
        k_cache: &[f32],
        v_cache: &[f32],
        mask: &[f32],
        buf_k: &[f32],
        buf_v: &[f32],
        buf_mask: &[f32],
    ) -> Result<DecodeOut> {
        let m = self.model().clone();
        let (l, c, hkv, dh, b) = (m.n_layers, capacity, m.n_kv_heads, m.d_head, m.buf_slots);
        let name = self.manifest.decode_fp32_name(c);
        let exe = self.exe(&name)?;
        let dyn_bufs = [
            self.buf_i32(&[token], &[1])?,
            self.buf_i32(&[pos], &[1])?,
            self.buf_i32(&[buf_idx], &[1])?,
            self.buf_f32(k_cache, &[l, c, hkv, dh])?,
            self.buf_f32(v_cache, &[l, c, hkv, dh])?,
            self.buf_f32(mask, &[l, c])?,
            self.buf_f32(buf_k, &[l, b, hkv, dh])?,
            self.buf_f32(buf_v, &[l, b, hkv, dh])?,
            self.buf_f32(buf_mask, &[l, b])?,
        ];
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(dyn_bufs.iter());
        let outs = self.run_tuple(&exe, &args)?;
        decode_out(outs)
    }

    /// Run prompt prefill (tokens padded/truncated to the exported length).
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let m = self.model().clone();
        let p = m.prefill_len;
        let mut toks = vec![0i32; p];
        for (i, t) in tokens.iter().take(p).enumerate() {
            toks[i] = *t;
        }
        let exe = self.exe(&self.manifest.prefill_name())?;
        let tok_buf = self.buf_i32(&toks, &[p])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        let outs = self.run_tuple(&exe, &args)?;
        if outs.len() != 4 {
            bail!("prefill returned {} outputs", outs.len());
        }
        Ok(PrefillOut {
            logits: outs[0].to_vec::<f32>().map_err(to_anyhow)?,
            k: outs[1].to_vec::<f32>().map_err(to_anyhow)?,
            v: outs[2].to_vec::<f32>().map_err(to_anyhow)?,
            obs: outs[3].to_vec::<f32>().map_err(to_anyhow)?,
        })
    }

    /// Standalone fused attention (microbench / golden validation).
    #[allow(clippy::too_many_arguments)]
    pub fn attn_micro(
        &self,
        q: &[f32],
        k_codes: &[u8],
        k_scales: &[f32],
        v_codes: &[u8],
        v_scales: &[f32],
        tags: &[u8],
        mask: &[f32],
        buf_k: &[f32],
        buf_v: &[f32],
        buf_mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = self.model().clone();
        let c = self.manifest.micro_c;
        let (h, hkv, dh, g, b) = (m.n_heads, m.n_kv_heads, m.d_head, m.groups(), m.buf_slots);
        let exe = self.exe(&format!("attn_micro_c{c}"))?;
        let bufs = [
            self.buf_f32(q, &[h, dh])?,
            self.buf_u8(k_codes, &[c, hkv, dh])?,
            self.buf_f32(k_scales, &[c, hkv, g])?,
            self.buf_u8(v_codes, &[c, hkv, dh])?,
            self.buf_f32(v_scales, &[c, hkv, g])?,
            self.buf_u8(tags, &[c])?,
            self.buf_f32(mask, &[c])?,
            self.buf_f32(buf_k, &[b, hkv, dh])?,
            self.buf_f32(buf_v, &[b, hkv, dh])?,
            self.buf_f32(buf_mask, &[b])?,
        ];
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = self.run_tuple(&exe, &args)?;
        if outs.len() != 2 {
            bail!("attn_micro returned {} outputs", outs.len());
        }
        Ok((
            outs[0].to_vec::<f32>().map_err(to_anyhow)?,
            outs[1].to_vec::<f32>().map_err(to_anyhow)?,
        ))
    }
}

/// The fused decode surface over the PJRT artifacts. `decode_batch`
/// uses the trait default (map over [`Engine::decode`]): a compatible
/// batch shares one compiled module, which the executable cache
/// resolves/compiles on the first member and serves warm to the rest.
/// The current artifacts are single-request HLO, so the per-member
/// execute remains — a multi-request decode artifact slots in behind
/// `decode_batch` without touching any caller; the launch-amortization
/// effect on real hardware is priced by
/// [`crate::sim::ServingCost::decode_step_per_session`] vs
/// [`crate::sim::ServingCost::decode_step`]. `prefill_chunk` likewise
/// slices the single-request prefill artifact (memoized per prompt so
/// a chunked prefill still costs one execute, paid on the first chunk);
/// a chunked-prefill artifact replaces the memo the same way.
impl DecodeEngine for Engine {
    fn model(&self) -> &crate::model::ModelConfig {
        Engine::model(self)
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        Engine::prefill(self, tokens)
    }

    fn decode(&self, token: i32, pos: i32, buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
        Engine::decode(self, token, pos, buf_idx, view)
    }

    fn prefill_chunk(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
        _view: &CacheView,
    ) -> Result<PrefillChunkOut> {
        if start == 0 && len == self.model().prefill_len {
            // whole-prompt "chunk" (the chunking-disabled path): run the
            // prefill directly and move its buffers through — no memo
            // entry, no slice copy
            let PrefillOut { logits, k, v, obs } = Engine::prefill(self, tokens)?;
            return Ok(PrefillChunkOut { logits, k, v, obs });
        }
        let hit = self
            .prefill_memo
            .borrow()
            .iter()
            .any(|(t, _)| t.as_slice() == tokens);
        if !hit {
            let pf = Engine::prefill(self, tokens)?;
            let mut memo = self.prefill_memo.borrow_mut();
            if memo.len() >= PREFILL_MEMO_CAP {
                memo.remove(0); // oldest prompt pays a re-execute if resumed
            }
            memo.push((tokens.to_vec(), pf));
        }
        let out = {
            let memo = self.prefill_memo.borrow();
            let (_, pf) = memo
                .iter()
                .find(|(t, _)| t.as_slice() == tokens)
                .expect("memo filled above");
            slice_prefill_chunk(self.model(), pf, start, len)?
        };
        // the final chunk retires the entry: the prompt is fully sliced
        // and a stale image must not outlive its session
        if start + len == self.model().prefill_len {
            self.prefill_memo.borrow_mut().retain(|(t, _)| t.as_slice() != tokens);
        }
        Ok(out)
    }
}

fn decode_out(outs: Vec<xla::Literal>) -> Result<DecodeOut> {
    if outs.len() != 4 {
        bail!("decode step returned {} outputs, want 4", outs.len());
    }
    Ok(DecodeOut {
        logits: outs[0].to_vec::<f32>().map_err(to_anyhow)?,
        new_k: outs[1].to_vec::<f32>().map_err(to_anyhow)?,
        new_v: outs[2].to_vec::<f32>().map_err(to_anyhow)?,
        probs: outs[3].to_vec::<f32>().map_err(to_anyhow)?,
    })
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
