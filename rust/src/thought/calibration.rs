//! Offline calibration (paper §4.1, Algorithm 1 in §D.1).
//!
//! Input: per-prompt, per-layer sparsity series collected while generating
//! on a calibration set (the paper samples 100 prompts from s1K; we use the
//! LRM trace simulator and/or the real tiny model).
//!
//! Output: the optimal layer subset L* (layers whose sparsity KDE exhibits
//! |T| modes, intersected across prompts with a tolerance vote) and the
//! averaged thresholds Θ = {θ_1, ..., θ_{|T|-1}}.

use super::kde::Kde;

#[derive(Debug, Clone)]
pub struct CalibrationResult {
    /// Selected layer subset L* (indices).
    pub layers: Vec<usize>,
    /// Thresholds θ (ascending), |T|-1 of them.
    pub thresholds: Vec<f64>,
    /// Per-layer vote counts (how many prompts showed |T| modes).
    pub votes: Vec<usize>,
}

/// `series[prompt][layer]` = sparsity samples (one per decode step).
/// `n_thoughts` = |T| (3 for LRMs, 1 for plain LLMs — then no thresholds).
/// `max_layers` = |L*| cap (paper: 4).
pub fn calibrate(
    series: &[Vec<Vec<f64>>],
    n_thoughts: usize,
    max_layers: usize,
    min_rel_height: f64,
) -> CalibrationResult {
    assert!(!series.is_empty());
    let n_layers = series[0].len();
    if n_thoughts <= 1 {
        return CalibrationResult {
            layers: (0..n_layers.min(max_layers)).collect(),
            thresholds: Vec::new(),
            votes: vec![series.len(); n_layers],
        };
    }
    // Vote: per layer, count prompts whose KDE has exactly |T| modes,
    // remembering each (layer, prompt) threshold set.
    let mut votes = vec![0usize; n_layers];
    let mut per_layer_thresholds: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n_layers];
    for prompt in series {
        for (l, samples) in prompt.iter().enumerate() {
            if samples.len() < 8 {
                continue;
            }
            let kde = Kde::fit(samples, 256, 1e-3);
            let modes = kde.modes(min_rel_height);
            if modes.len() == n_thoughts {
                votes[l] += 1;
                per_layer_thresholds[l].push(kde.thresholds(min_rel_height));
            }
        }
    }
    // The paper intersects across all prompts (Algorithm 1 line 24); with
    // small calibration sets we rank by votes and keep the top max_layers
    // with at least a majority (documented relaxation, same selection
    // criterion in the limit).
    let majority = series.len().div_ceil(2);
    let mut ranked: Vec<usize> = (0..n_layers).filter(|&l| votes[l] >= majority).collect();
    ranked.sort_by(|&a, &b| votes[b].cmp(&votes[a]).then(a.cmp(&b)));
    ranked.truncate(max_layers);
    if ranked.is_empty() {
        // degenerate fallback: best-voted layer
        let best = (0..n_layers).max_by_key(|&l| votes[l]).unwrap_or(0);
        ranked.push(best);
    }

    // Average thresholds over selected layers and their prompt fits.
    let mut thresholds = vec![0.0; n_thoughts - 1];
    let mut count = 0usize;
    for &l in &ranked {
        for t in &per_layer_thresholds[l] {
            if t.len() == n_thoughts - 1 {
                for (i, &x) in t.iter().enumerate() {
                    thresholds[i] += x;
                }
                count += 1;
            }
        }
    }
    if count > 0 {
        for t in &mut thresholds {
            *t /= count as f64;
        }
    } else {
        // fallback to reasonable priors from the paper's Figure 3 regimes
        thresholds = default_thresholds(n_thoughts);
    }
    CalibrationResult { layers: ranked, thresholds, votes }
}

/// Fallback thresholds matching the sparsity regimes in Figure 3.
pub fn default_thresholds(n_thoughts: usize) -> Vec<f64> {
    match n_thoughts {
        3 => vec![0.42, 0.7],
        2 => vec![0.55],
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Build synthetic per-prompt series where `good` layers are tri-modal
    /// and others unimodal.
    fn synth(prompts: usize, layers: usize, good: &[usize], seed: u64) -> Vec<Vec<Vec<f64>>> {
        let mut rng = Rng::new(seed);
        (0..prompts)
            .map(|_| {
                (0..layers)
                    .map(|l| {
                        (0..300)
                            .map(|i| {
                                if good.contains(&l) {
                                    let mean = [0.25, 0.55, 0.85][i % 3];
                                    rng.normal_with(mean, 0.04).clamp(0.0, 1.0)
                                } else {
                                    rng.normal_with(0.5, 0.05).clamp(0.0, 1.0)
                                }
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn selects_trimodal_layers() {
        let series = synth(6, 8, &[1, 3, 5, 6], 7);
        let r = calibrate(&series, 3, 4, 0.12);
        let mut got = r.layers.clone();
        got.sort_unstable();
        assert_eq!(got, vec![1, 3, 5, 6]);
        assert_eq!(r.thresholds.len(), 2);
        assert!(r.thresholds[0] > 0.3 && r.thresholds[0] < 0.5, "{:?}", r.thresholds);
        assert!(r.thresholds[1] > 0.62 && r.thresholds[1] < 0.8, "{:?}", r.thresholds);
    }

    #[test]
    fn caps_at_max_layers() {
        let series = synth(4, 8, &[0, 1, 2, 3, 4, 5], 8);
        let r = calibrate(&series, 3, 4, 0.12);
        assert_eq!(r.layers.len(), 4);
    }

    #[test]
    fn single_thought_type_short_circuits() {
        let series = synth(2, 4, &[], 9);
        let r = calibrate(&series, 1, 4, 0.12);
        assert!(r.thresholds.is_empty());
        assert!(!r.layers.is_empty());
    }

    #[test]
    fn no_trimodal_layers_falls_back() {
        let series = synth(4, 4, &[], 10);
        let r = calibrate(&series, 3, 4, 0.12);
        assert!(!r.layers.is_empty());
        assert_eq!(r.thresholds.len(), 2); // default priors
    }
}
