//! Attention-sparsity measurement (paper §3.1 footnote 2): sparsity of a
//! normalized attention row = fraction of entries below 1% of the row max,
//! measured over *valid* cache slots, averaged across heads.

/// Sparsity of one head's softmax row restricted to valid slots.
/// `probs` and `valid` have the same length; `valid[i] > 0` marks live slots.
pub fn row_sparsity(probs: &[f32], valid: &[f32], rel_threshold: f32) -> f64 {
    debug_assert_eq!(probs.len(), valid.len());
    let mut max = 0f32;
    let mut n = 0usize;
    for (p, v) in probs.iter().zip(valid) {
        if *v > 0.0 {
            max = max.max(*p);
            n += 1;
        }
    }
    if n == 0 || max <= 0.0 {
        return 0.0;
    }
    let thr = rel_threshold * max;
    let sparse = probs
        .iter()
        .zip(valid)
        .filter(|(p, v)| **v > 0.0 && **p < thr)
        .count();
    sparse as f64 / n as f64
}

/// Per-layer sparsity, averaged over heads, from a decode step's probs
/// tensor `[L, H, S]` and validity `[L, S]` (S = cache slots + buffer).
pub fn sparsity_per_layer(
    probs: &[f32],
    valid: &[f32],
    layers: usize,
    heads: usize,
    span: usize,
    rel_threshold: f32,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(layers);
    for l in 0..layers {
        let v = &valid[l * span..(l + 1) * span];
        let mut acc = 0.0;
        for h in 0..heads {
            let base = (l * heads + h) * span;
            acc += row_sparsity(&probs[base..base + span], v, rel_threshold);
        }
        out.push(acc / heads as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_row_has_zero_sparsity() {
        let probs = vec![0.25f32; 4];
        let valid = vec![1f32; 4];
        assert_eq!(row_sparsity(&probs, &valid, 0.01), 0.0);
    }

    #[test]
    fn peaked_row_is_sparse() {
        let mut probs = vec![1e-6f32; 100];
        probs[7] = 0.9;
        let valid = vec![1f32; 100];
        let s = row_sparsity(&probs, &valid, 0.01);
        assert!(s > 0.95, "{s}");
    }

    #[test]
    fn invalid_slots_ignored() {
        // huge prob on an invalid slot must not distort the max
        let probs = vec![0.5f32, 0.5, 0.0, 0.9];
        let valid = vec![1f32, 1.0, 0.0, 0.0];
        assert_eq!(row_sparsity(&probs, &valid, 0.01), 0.0);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(row_sparsity(&[], &[], 0.01), 0.0);
        assert_eq!(row_sparsity(&[0.1], &[0.0], 0.01), 0.0);
    }

    #[test]
    fn per_layer_shapes() {
        let layers = 2;
        let heads = 2;
        let span = 4;
        let mut probs = vec![0.25f32; layers * heads * span];
        // layer 1: peaked rows
        for h in 0..heads {
            let base = (1 * heads + h) * span;
            probs[base..base + span].copy_from_slice(&[0.999, 1e-6, 1e-6, 1e-6]);
        }
        let valid = vec![1f32; layers * span];
        let s = sparsity_per_layer(&probs, &valid, layers, heads, span, 0.01);
        assert_eq!(s.len(), 2);
        assert!(s[0] < 0.01);
        assert!(s[1] > 0.7);
    }
}
