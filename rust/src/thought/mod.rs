//! Thought decomposition (paper §3.1, §4.1): attention-sparsity tracking,
//! KDE-based offline calibration of the sparsity thresholds Θ and the
//! optimal layer subset L*, and the decode-time classifier φ with refresh
//! interval τ.

pub mod calibration;
pub mod classifier;
pub mod kde;
pub mod sparsity;

pub use calibration::{calibrate, CalibrationResult};
pub use classifier::{Classifier, ClassifierConfig, ClassifierState};
pub use kde::Kde;
pub use sparsity::{row_sparsity, sparsity_per_layer};
