//! Gaussian kernel density estimation (Parzen 1962) with Silverman's
//! bandwidth — the statistical core of Algorithm 1 (§D.1): per-layer
//! sparsity distributions are KDE'd, their **modes** counted to select L*,
//! and the **local minima between modes** become the thresholds Θ.

/// A 1-D Gaussian KDE evaluated on a fixed grid.
#[derive(Debug, Clone)]
pub struct Kde {
    pub grid: Vec<f64>,
    pub density: Vec<f64>,
    pub bandwidth: f64,
}

impl Kde {
    /// Fit on samples with Silverman's rule-of-thumb bandwidth
    /// h = 0.9 * min(sigma, IQR/1.34) * n^(-1/5), clamped to `min_bw`.
    pub fn fit(samples: &[f64], grid_points: usize, min_bw: f64) -> Kde {
        assert!(!samples.is_empty());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let sigma = var.sqrt();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let pos = p * (sorted.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            if lo == hi {
                sorted[lo]
            } else {
                sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
            }
        };
        let iqr = q(0.75) - q(0.25);
        let spread = if iqr > 0.0 { sigma.min(iqr / 1.34) } else { sigma };
        let bw = (0.9 * spread * n.powf(-0.2)).max(min_bw);

        let lo = sorted[0] - 3.0 * bw;
        let hi = sorted[sorted.len() - 1] + 3.0 * bw;
        let grid: Vec<f64> = (0..grid_points)
            .map(|i| lo + (hi - lo) * i as f64 / (grid_points - 1) as f64)
            .collect();
        let inv = 1.0 / (bw * (2.0 * std::f64::consts::PI).sqrt() * n);
        let density: Vec<f64> = grid
            .iter()
            .map(|&x| {
                samples
                    .iter()
                    .map(|&s| (-(x - s) * (x - s) / (2.0 * bw * bw)).exp())
                    .sum::<f64>()
                    * inv
            })
            .collect();
        Kde { grid, density, bandwidth: bw }
    }

    /// Indices of local maxima (modes), filtered to peaks at least
    /// `min_rel_height` of the global max, with peaks closer than two
    /// bandwidths merged (keeps the taller) to suppress sampling ripples.
    pub fn modes(&self, min_rel_height: f64) -> Vec<usize> {
        let d = &self.density;
        let peak = d.iter().cloned().fold(0f64, f64::max);
        let mut raw = Vec::new();
        for i in 1..d.len() - 1 {
            if d[i] > d[i - 1] && d[i] >= d[i + 1] && d[i] >= peak * min_rel_height {
                raw.push(i);
            }
        }
        // merge near-duplicates (< 2 bandwidths apart, and no deep valley
        // between them)
        let mut out: Vec<usize> = Vec::new();
        for i in raw {
            match out.last().copied() {
                Some(prev)
                    if (self.grid[i] - self.grid[prev]).abs() < 2.0 * self.bandwidth
                        || self.density_at_min_between(prev, i)
                            > 0.8 * d[prev].min(d[i]) =>
                {
                    if d[i] > d[prev] {
                        *out.last_mut().unwrap() = i;
                    }
                }
                _ => out.push(i),
            }
        }
        out
    }

    fn density_at_min_between(&self, a: usize, b: usize) -> f64 {
        let (lo, hi) = (a.min(b), a.max(b));
        self.density[lo..=hi]
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
    }

    /// Grid x-positions of the modes.
    pub fn mode_positions(&self, min_rel_height: f64) -> Vec<f64> {
        self.modes(min_rel_height).into_iter().map(|i| self.grid[i]).collect()
    }

    /// The minimum-density grid position between two grid indices
    /// (the paper's inter-mode threshold).
    pub fn min_between(&self, a: usize, b: usize) -> f64 {
        let (lo, hi) = (a.min(b), a.max(b));
        let mut best = lo;
        for i in lo..=hi {
            if self.density[i] < self.density[best] {
                best = i;
            }
        }
        self.grid[best]
    }

    /// Thresholds between consecutive modes (len = modes-1).
    pub fn thresholds(&self, min_rel_height: f64) -> Vec<f64> {
        let m = self.modes(min_rel_height);
        m.windows(2).map(|w| self.min_between(w[0], w[1])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn trimodal_samples(n: usize, seed: u64) -> Vec<f64> {
        // the paper's tri-modal sparsity: E ~0.25, R ~0.55, T ~0.85
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let c = i % 3;
                let mean = [0.25, 0.55, 0.85][c];
                (rng.normal_with(mean, 0.04)).clamp(0.0, 1.0)
            })
            .collect()
    }

    #[test]
    fn finds_three_modes_on_trimodal_data() {
        let kde = Kde::fit(&trimodal_samples(600, 3), 256, 1e-3);
        let modes = kde.mode_positions(0.12);
        assert_eq!(modes.len(), 3, "modes at {modes:?}");
        assert!((modes[0] - 0.25).abs() < 0.08);
        assert!((modes[1] - 0.55).abs() < 0.08);
        assert!((modes[2] - 0.85).abs() < 0.08);
    }

    #[test]
    fn thresholds_fall_between_modes() {
        let kde = Kde::fit(&trimodal_samples(600, 4), 256, 1e-3);
        let th = kde.thresholds(0.12);
        assert_eq!(th.len(), 2);
        assert!(th[0] > 0.3 && th[0] < 0.5, "{th:?}");
        assert!(th[1] > 0.62 && th[1] < 0.8, "{th:?}");
    }

    #[test]
    fn unimodal_data_has_one_mode() {
        let mut rng = Rng::new(5);
        let samples: Vec<f64> = (0..400).map(|_| rng.normal_with(0.5, 0.05)).collect();
        let kde = Kde::fit(&samples, 256, 1e-3);
        assert_eq!(kde.modes(0.12).len(), 1);
        assert!(kde.thresholds(0.12).is_empty());
    }

    #[test]
    fn density_integrates_to_one() {
        let samples = trimodal_samples(300, 6);
        let kde = Kde::fit(&samples, 512, 1e-3);
        let dx = kde.grid[1] - kde.grid[0];
        let total: f64 = kde.density.iter().sum::<f64>() * dx;
        assert!((total - 1.0).abs() < 0.02, "{total}");
    }

    #[test]
    fn bandwidth_clamped() {
        let samples = vec![0.5; 64]; // zero spread
        let kde = Kde::fit(&samples, 64, 1e-3);
        assert!(kde.bandwidth >= 1e-3);
    }
}
