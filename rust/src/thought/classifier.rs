//! Decode-time thought classifier φ (paper §4.1 "Decode-Time Behavior"):
//! average the per-step sparsity over the calibrated layer subset L*,
//! accumulate over the refresh window τ, and compare against Θ at each
//! refresh boundary to label the *next* segment's thought type.

use crate::kvcache::Thought;

#[derive(Debug, Clone)]
pub struct ClassifierConfig {
    /// Calibrated layer subset L*.
    pub layers: Vec<usize>,
    /// Ascending thresholds Θ (|T|-1 entries; empty => always Reasoning).
    pub thresholds: Vec<f64>,
    /// Refresh interval τ (tokens per thought segment), paper default 128.
    pub refresh: usize,
}

impl Default for ClassifierConfig {
    fn default() -> Self {
        ClassifierConfig {
            layers: vec![0, 1, 2, 3],
            thresholds: super::calibration::default_thresholds(3),
            refresh: 128,
        }
    }
}

/// Streaming classifier: feed per-layer sparsity each step; ask at refresh
/// boundaries for the window's thought type.
#[derive(Debug, Clone)]
pub struct Classifier {
    pub cfg: ClassifierConfig,
    acc: f64,
    n: usize,
    /// Sparsity trace (window means), for diagnostics/Figure 3 dumps.
    pub window_means: Vec<f64>,
}

/// The classifier's mutable decode-time state, captured for
/// suspend-to-host preemption
/// ([`crate::kvcache::swap::QuantSnapshot`]). The config is rebuilt from
/// the serving config on resume; only the open window must survive.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassifierState {
    /// Sparsity accumulated over the open refresh window.
    pub acc: f64,
    /// Steps accumulated in the open window.
    pub n: usize,
    /// Closed-window means (diagnostics trace).
    pub window_means: Vec<f64>,
}

impl Classifier {
    pub fn new(cfg: ClassifierConfig) -> Classifier {
        Classifier { cfg, acc: 0.0, n: 0, window_means: Vec::new() }
    }

    /// Map an averaged sparsity value to a thought type via Θ.
    /// Sparsity regimes (Obs. 1b): E lowest, R middle, T highest.
    pub fn classify_value(&self, sparsity: f64) -> Thought {
        let th = &self.cfg.thresholds;
        match th.len() {
            0 => Thought::Reasoning,
            1 => {
                if sparsity <= th[0] {
                    Thought::Execution
                } else {
                    Thought::Reasoning
                }
            }
            _ => {
                if sparsity <= th[0] {
                    Thought::Execution
                } else if sparsity <= th[1] {
                    Thought::Reasoning
                } else {
                    Thought::Transition
                }
            }
        }
    }

    /// Feed one decode step's per-layer sparsity (full layer vector; the
    /// classifier selects L* itself).
    pub fn push_step(&mut self, per_layer: &[f64]) {
        let mut s = 0.0;
        let mut n = 0usize;
        for &l in &self.cfg.layers {
            if l < per_layer.len() {
                s += per_layer[l];
                n += 1;
            }
        }
        if n > 0 {
            self.acc += s / n as f64;
            self.n += 1;
        }
    }

    /// Steps accumulated since the last refresh.
    pub fn window_len(&self) -> usize {
        self.n
    }

    /// True when the window reached τ.
    pub fn due(&self) -> bool {
        self.n >= self.cfg.refresh
    }

    /// Capture the open-window state (suspend-to-host preemption).
    pub fn snapshot_state(&self) -> ClassifierState {
        ClassifierState {
            acc: self.acc,
            n: self.n,
            window_means: self.window_means.clone(),
        }
    }

    /// Restore an open-window state captured by
    /// [`Classifier::snapshot_state`].
    pub fn restore_state(&mut self, s: ClassifierState) {
        self.acc = s.acc;
        self.n = s.n;
        self.window_means = s.window_means;
    }

    /// Close the window: return the thought label for the elapsed window
    /// and reset. Returns Reasoning for an empty window.
    pub fn refresh(&mut self) -> Thought {
        let mean = if self.n > 0 { self.acc / self.n as f64 } else { 0.5 };
        self.window_means.push(mean);
        self.acc = 0.0;
        self.n = 0;
        self.classify_value(mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ClassifierConfig {
        ClassifierConfig {
            layers: vec![0, 1],
            thresholds: vec![0.42, 0.7],
            refresh: 4,
        }
    }

    #[test]
    fn classify_regimes() {
        let c = Classifier::new(cfg());
        assert_eq!(c.classify_value(0.2), Thought::Execution);
        assert_eq!(c.classify_value(0.55), Thought::Reasoning);
        assert_eq!(c.classify_value(0.9), Thought::Transition);
    }

    #[test]
    fn window_accumulates_selected_layers_only() {
        let mut c = Classifier::new(cfg());
        for _ in 0..4 {
            // layers 0,1 sparse (T regime); layers 2,3 dense — ignored
            c.push_step(&[0.9, 0.85, 0.1, 0.1]);
        }
        assert!(c.due());
        assert_eq!(c.refresh(), Thought::Transition);
        assert_eq!(c.window_len(), 0);
    }

    #[test]
    fn refresh_resets_window() {
        let mut c = Classifier::new(cfg());
        for _ in 0..4 {
            c.push_step(&[0.2, 0.2]);
        }
        assert_eq!(c.refresh(), Thought::Execution);
        for _ in 0..4 {
            c.push_step(&[0.6, 0.6]);
        }
        assert_eq!(c.refresh(), Thought::Reasoning);
        assert_eq!(c.window_means.len(), 2);
    }

    #[test]
    fn single_threshold_llm_mode() {
        let mut c = Classifier::new(ClassifierConfig {
            layers: vec![0],
            thresholds: vec![],
            refresh: 2,
        });
        c.push_step(&[0.99]);
        c.push_step(&[0.99]);
        assert_eq!(c.refresh(), Thought::Reasoning); // |T|=1: all one class
    }
}
