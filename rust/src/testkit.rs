//! Shared, artifact-free test/bench fixtures (`#[doc(hidden)]`): a
//! hand-built tiny manifest, a prefix-dominated manifest, a
//! deterministic **causal** engine fake, and a **metered** wrapper
//! ([`MeteredEngine`]) that prices engine work on a logical clock for
//! deterministic scheduling-latency assertions.
//!
//! The causal property is load-bearing for prefix sharing: the fake's
//! prefill K/V at position `i` is a pure function of tokens `0..=i`
//! (deterministic pad past the prompt), mirroring a causal transformer,
//! so identical prompt prefixes produce identical prefill blocks. Unit
//! tests, the integration suites, and `bench_scheduler`'s sharing sweep
//! all drive this one implementation so the invariant cannot drift
//! between copies.

use std::cell::{Cell, RefCell};

use anyhow::Result;

use crate::baselines::{PolicyKind, RetentionCounters, RetentionTrace};
use crate::kvcache::{Fp32Backend, Fp32Cache, KvBackend};
use crate::metrics::Breakdown;
use crate::model::{Manifest, ModelConfig};
use crate::runtime::{
    BatchDecodeReq, CacheView, DecodeEngine, DecodeOut, ExecStats, PrefillChunkOut, PrefillOut,
};
use crate::util::rng::Rng;

/// Tiny dims, no artifact files needed (nothing loads HLO).
pub fn tiny_manifest() -> Manifest {
    Manifest {
        model: ModelConfig {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            d_head: 16,
            d_ffn: 64,
            rope_base: 10000.0,
            buf_slots: 16,
            prefill_len: 32,
            obs_window: 8,
            group_size: 16,
        },
        quant_caps: vec![128],
        fp32_caps: vec![256],
        batch_widths: vec![],
        prefill_chunk_lens: vec![],
        micro_c: 128,
        golden_attn_c: 128,
        artifacts_dir: ".".into(),
        weights: vec![],
        seed: 0,
    }
}

/// Like [`tiny_manifest`] but tuned so the prompt prefix dominates a
/// request's admission bytes (long prefill, small ring buffer) — the
/// regime where prefix sharing multiplies the admissible batch.
pub fn share_manifest() -> Manifest {
    let mut man = tiny_manifest();
    man.model.buf_slots = 4;
    man.model.prefill_len = 96;
    man
}

/// Everything one policy-arena drive leaves behind: the retention audit
/// log, the backend's counters, the high-water live-token mark, and the
/// final live position set — the raw material for the conformance
/// battery and the sim-oracle differential replay.
pub struct ArenaRun {
    pub trace: RetentionTrace,
    pub counters: RetentionCounters,
    pub max_live: usize,
    pub live: Vec<usize>,
}

/// Drive a fresh [`Fp32Backend`] built from `kind`'s registry entry
/// through a seeded prefill + `steps` decode absorptions with retention
/// tracing enabled. The synthetic K/V and attention rows follow the same
/// distribution idiom as [`CausalEngine`], so policy decisions exercise
/// realistic (non-degenerate) attention mass while staying bit-
/// reproducible from `seed`.
pub fn drive_arena(kind: PolicyKind, budget: usize, steps: usize, seed: u64) -> ArenaRun {
    let man = tiny_manifest();
    let m = &man.model;
    let kvd = m.n_kv_heads * m.d_head;
    let capacity = man.fp32_caps[0];
    let mut backend = Fp32Backend::new(
        Fp32Cache::new(m.n_layers, capacity, kvd, m.buf_slots),
        kind.build(budget),
        kind.budget_for(budget),
        kind.gather(),
        capacity,
    );
    backend.enable_trace(kind, budget);

    let p_len = m.prefill_len;
    let mut rng = Rng::new(seed ^ 0xA1E7A);
    let mut k = vec![0f32; m.n_layers * p_len * kvd];
    let mut v = vec![0f32; m.n_layers * p_len * kvd];
    rng.fill_normal_f32(&mut k, 0.0, 1.0);
    rng.fill_normal_f32(&mut v, 0.0, 1.0);
    let pf = PrefillOut { logits: vec![0.0; m.vocab], k, v, obs: vec![0.0; m.n_layers * p_len] };
    backend.write_prefill(&pf, p_len);

    let span = capacity + m.buf_slots;
    let mut bd = Breakdown::default();
    let mut max_live = backend.live_tokens();
    for i in 0..steps {
        let pos = p_len + i;
        backend.make_room(pos, &mut bd).expect("arena make_room");
        let mut new_k = vec![0f32; m.n_layers * kvd];
        let mut new_v = vec![0f32; m.n_layers * kvd];
        let mut probs = vec![0f32; m.n_layers * m.n_heads * span];
        rng.fill_normal_f32(&mut new_k, 0.0, 1.0);
        rng.fill_normal_f32(&mut new_v, 0.0, 1.0);
        rng.fill_normal_f32(&mut probs, 0.5, 0.2);
        for p in probs.iter_mut() {
            *p = p.abs();
        }
        let out = DecodeOut { logits: vec![0.0; m.vocab], new_k, new_v, probs };
        backend.absorb(&out, pos, m, &mut bd).expect("arena absorb");
        max_live = max_live.max(backend.live_tokens());
    }
    let counters = backend.retention();
    let live = backend.live_positions();
    let trace = backend.take_trace().expect("trace enabled");
    ArenaRun { trace, counters, max_live, live }
}

/// Deterministic causal engine stand-in (see module docs). Outputs are
/// a pure function of the decode-step inputs (token, position) and, for
/// prefill, of the causal token prefix per position.
pub struct CausalEngine {
    m: ModelConfig,
}

impl CausalEngine {
    pub fn new(m: ModelConfig) -> CausalEngine {
        CausalEngine { m }
    }
}

impl DecodeEngine for CausalEngine {
    fn model(&self) -> &ModelConfig {
        &self.m
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let m = &self.m;
        let kvd = m.n_kv_heads * m.d_head;
        let mut k = vec![0f32; m.n_layers * m.prefill_len * kvd];
        let mut v = vec![0f32; m.n_layers * m.prefill_len * kvd];
        let mut h = 0xABCDu64;
        for pos in 0..m.prefill_len {
            // causal accumulator: position `pos` sees tokens[0..=pos]
            h = h.wrapping_mul(31).wrapping_add(if pos < tokens.len() {
                tokens[pos] as u64
            } else {
                7
            });
            let mut rng = Rng::new(h ^ 0x51AB);
            for l in 0..m.n_layers {
                let base = (l * m.prefill_len + pos) * kvd;
                for d in 0..kvd {
                    k[base + d] = (rng.f32() - 0.5) * 2.0;
                    v[base + d] = (rng.f32() - 0.5) * 2.0;
                }
            }
        }
        // last-position logits: a function of the whole prompt
        let mut lr = Rng::new(h ^ 0x1061_75);
        let mut logits = vec![0f32; m.vocab];
        lr.fill_normal_f32(&mut logits, 0.0, 1.0);
        Ok(PrefillOut { logits, k, v, obs: vec![0.0; m.n_layers * m.prefill_len] })
    }

    /// True chunked compute (unlike the slicing trait default): only the
    /// requested positions generate K/V, the way a chunked-prefill
    /// kernel would, while the causal accumulator still walks the whole
    /// prefix so every chunking is bit-identical to
    /// [`CausalEngine::prefill`].
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
        _view: &CacheView,
    ) -> Result<PrefillChunkOut> {
        let m = &self.m;
        let p = m.prefill_len;
        anyhow::ensure!(start + len <= p, "chunk [{start}, {}) exceeds prefill_len {p}", start + len);
        let kvd = m.n_kv_heads * m.d_head;
        let mut k = vec![0f32; m.n_layers * len * kvd];
        let mut v = vec![0f32; m.n_layers * len * kvd];
        let final_chunk = start + len == p;
        // the accumulator must cover every position whose hash feeds
        // this chunk (or the final logits); later positions are unseen
        let walk = if final_chunk { p } else { start + len };
        let mut h = 0xABCDu64;
        for pos in 0..walk {
            h = h.wrapping_mul(31).wrapping_add(if pos < tokens.len() {
                tokens[pos] as u64
            } else {
                7
            });
            if pos >= start && pos < start + len {
                let mut rng = Rng::new(h ^ 0x51AB);
                for l in 0..m.n_layers {
                    let base = (l * len + (pos - start)) * kvd;
                    for d in 0..kvd {
                        k[base + d] = (rng.f32() - 0.5) * 2.0;
                        v[base + d] = (rng.f32() - 0.5) * 2.0;
                    }
                }
            }
        }
        let mut logits = vec![0f32; m.vocab];
        if final_chunk {
            let mut lr = Rng::new(h ^ 0x1061_75);
            lr.fill_normal_f32(&mut logits, 0.0, 1.0);
        }
        Ok(PrefillChunkOut { logits, k, v, obs: vec![0.0; m.n_layers * len] })
    }

    fn decode(&self, token: i32, pos: i32, _buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
        let capacity = match view {
            CacheView::Quant(q) => q.capacity,
            CacheView::Fp32 { capacity, .. } => *capacity,
        };
        let m = &self.m;
        let span = capacity + m.buf_slots;
        let kvd = m.n_kv_heads * m.d_head;
        let seed = (u64::from(token as u32) << 32) | u64::from(pos as u32);
        let mut rng = Rng::new(seed ^ 0x5EED_CAFE);
        let mut logits = vec![0f32; m.vocab];
        let mut new_k = vec![0f32; m.n_layers * kvd];
        let mut new_v = vec![0f32; m.n_layers * kvd];
        let mut probs = vec![0f32; m.n_layers * m.n_heads * span];
        rng.fill_normal_f32(&mut logits, 0.0, 1.0);
        rng.fill_normal_f32(&mut new_k, 0.0, 1.0);
        rng.fill_normal_f32(&mut new_v, 0.0, 1.0);
        rng.fill_normal_f32(&mut probs, 0.5, 0.2);
        for p in probs.iter_mut() {
            *p = p.abs();
        }
        Ok(DecodeOut { logits, new_k, new_v, probs })
    }
}

/// [`CausalEngine`] wrapper with a deterministic **logical clock**:
/// every prefill token and every decode step costs one unit of engine
/// time. The arrival-burst bench and the head-of-line regression test
/// measure scheduling delay in these units instead of wall clock, so
/// "a long-prompt arrival delays a running session's next step by at
/// most one chunk" is a deterministic assertion, not a flaky timing.
pub struct MeteredEngine {
    inner: CausalEngine,
    clock: Cell<u64>,
    /// Clock value at the start of each fused decode call, in order.
    step_marks: RefCell<Vec<u64>>,
    /// Mirrors the real engine's PJRT ledger: one decode execute per
    /// fused [`DecodeEngine::decode_batch`] call (whatever its width),
    /// one per standalone decode, one prefill execute per prefill /
    /// chunk call — so artifact-free benches can gate on
    /// `fused_executes > 0` against the exact production counters.
    decode_execs: Cell<u64>,
    prefill_execs: Cell<u64>,
}

impl MeteredEngine {
    pub fn new(m: ModelConfig) -> MeteredEngine {
        MeteredEngine {
            inner: CausalEngine::new(m),
            clock: Cell::new(0),
            step_marks: RefCell::new(Vec::new()),
            decode_execs: Cell::new(0),
            prefill_execs: Cell::new(0),
        }
    }

    /// Total engine-time units consumed so far.
    pub fn clock(&self) -> u64 {
        self.clock.get()
    }

    /// Clock readings taken at the start of every fused decode call —
    /// consecutive differences are the inter-step gaps a decode-batch
    /// member observes (its TPOT, in engine-time units).
    pub fn step_marks(&self) -> Vec<u64> {
        self.step_marks.borrow().clone()
    }

    /// Advance the clock without doing engine work — trace-replay
    /// harnesses fast-forward idle gaps between arrivals with this, so
    /// arrival times land on the same deterministic timeline as the
    /// metered engine calls.
    pub fn tick(&self, units: u64) {
        self.clock.set(self.clock.get() + units);
    }
}

impl DecodeEngine for MeteredEngine {
    fn model(&self) -> &ModelConfig {
        self.inner.model()
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        self.tick(self.inner.model().prefill_len as u64);
        self.prefill_execs.set(self.prefill_execs.get() + 1);
        self.inner.prefill(tokens)
    }

    fn prefill_chunk(
        &self,
        tokens: &[i32],
        start: usize,
        len: usize,
        view: &CacheView,
    ) -> Result<PrefillChunkOut> {
        self.tick(len.max(1) as u64);
        self.prefill_execs.set(self.prefill_execs.get() + 1);
        self.inner.prefill_chunk(tokens, start, len, view)
    }

    fn decode(&self, token: i32, pos: i32, buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
        self.tick(1);
        self.decode_execs.set(self.decode_execs.get() + 1);
        self.inner.decode(token, pos, buf_idx, view)
    }

    fn decode_batch(&self, reqs: &[BatchDecodeReq<'_>]) -> Result<Vec<DecodeOut>> {
        self.step_marks.borrow_mut().push(self.clock.get());
        // one fused execute per call, like the batched-artifact engine;
        // members still cost one clock unit each (the fused call's work
        // scales with width even when the launch is amortized)
        self.decode_execs.set(self.decode_execs.get() + 1);
        reqs.iter()
            .map(|r| {
                self.tick(1);
                self.inner.decode(r.token, r.pos, r.buf_idx, &r.view)
            })
            .collect()
    }

    fn exec_stats(&self) -> ExecStats {
        ExecStats {
            decode_executes: self.decode_execs.get(),
            prefill_executes: self.prefill_execs.get(),
            ..ExecStats::default()
        }
    }

    /// The logical clock doubles as the scheduler's deterministic tick
    /// source: workers feed it through `Scheduler::drive_clock`, so SLO
    /// accounting (TTFT/TPOT in ticks) is bit-reproducible.
    fn logical_now(&self) -> Option<u64> {
        Some(self.clock.get())
    }
}
