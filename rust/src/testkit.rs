//! Shared, artifact-free test/bench fixtures (`#[doc(hidden)]`): a
//! hand-built tiny manifest, a prefix-dominated manifest, and a
//! deterministic **causal** engine fake.
//!
//! The causal property is load-bearing for prefix sharing: the fake's
//! prefill K/V at position `i` is a pure function of tokens `0..=i`
//! (deterministic pad past the prompt), mirroring a causal transformer,
//! so identical prompt prefixes produce identical prefill blocks. Unit
//! tests, the integration suites, and `bench_scheduler`'s sharing sweep
//! all drive this one implementation so the invariant cannot drift
//! between copies.

use anyhow::Result;

use crate::model::{Manifest, ModelConfig};
use crate::runtime::{CacheView, DecodeEngine, DecodeOut, PrefillOut};
use crate::util::rng::Rng;

/// Tiny dims, no artifact files needed (nothing loads HLO).
pub fn tiny_manifest() -> Manifest {
    Manifest {
        model: ModelConfig {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            d_head: 16,
            d_ffn: 64,
            rope_base: 10000.0,
            buf_slots: 16,
            prefill_len: 32,
            obs_window: 8,
            group_size: 16,
        },
        quant_caps: vec![128],
        fp32_caps: vec![256],
        micro_c: 128,
        golden_attn_c: 128,
        artifacts_dir: ".".into(),
        weights: vec![],
        seed: 0,
    }
}

/// Like [`tiny_manifest`] but tuned so the prompt prefix dominates a
/// request's admission bytes (long prefill, small ring buffer) — the
/// regime where prefix sharing multiplies the admissible batch.
pub fn share_manifest() -> Manifest {
    let mut man = tiny_manifest();
    man.model.buf_slots = 4;
    man.model.prefill_len = 96;
    man
}

/// Deterministic causal engine stand-in (see module docs). Outputs are
/// a pure function of the decode-step inputs (token, position) and, for
/// prefill, of the causal token prefix per position.
pub struct CausalEngine {
    m: ModelConfig,
}

impl CausalEngine {
    pub fn new(m: ModelConfig) -> CausalEngine {
        CausalEngine { m }
    }
}

impl DecodeEngine for CausalEngine {
    fn model(&self) -> &ModelConfig {
        &self.m
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let m = &self.m;
        let kvd = m.n_kv_heads * m.d_head;
        let mut k = vec![0f32; m.n_layers * m.prefill_len * kvd];
        let mut v = vec![0f32; m.n_layers * m.prefill_len * kvd];
        let mut h = 0xABCDu64;
        for pos in 0..m.prefill_len {
            // causal accumulator: position `pos` sees tokens[0..=pos]
            h = h.wrapping_mul(31).wrapping_add(if pos < tokens.len() {
                tokens[pos] as u64
            } else {
                7
            });
            let mut rng = Rng::new(h ^ 0x51AB);
            for l in 0..m.n_layers {
                let base = (l * m.prefill_len + pos) * kvd;
                for d in 0..kvd {
                    k[base + d] = (rng.f32() - 0.5) * 2.0;
                    v[base + d] = (rng.f32() - 0.5) * 2.0;
                }
            }
        }
        // last-position logits: a function of the whole prompt
        let mut lr = Rng::new(h ^ 0x1061_75);
        let mut logits = vec![0f32; m.vocab];
        lr.fill_normal_f32(&mut logits, 0.0, 1.0);
        Ok(PrefillOut { logits, k, v, obs: vec![0.0; m.n_layers * m.prefill_len] })
    }

    fn decode(&self, token: i32, pos: i32, _buf_idx: i32, view: &CacheView) -> Result<DecodeOut> {
        let capacity = match view {
            CacheView::Quant(q) => q.capacity,
            CacheView::Fp32 { capacity, .. } => *capacity,
        };
        let m = &self.m;
        let span = capacity + m.buf_slots;
        let kvd = m.n_kv_heads * m.d_head;
        let seed = ((token as u32 as u64) << 32) | pos as u32 as u64;
        let mut rng = Rng::new(seed ^ 0x5EED_CAFE);
        let mut logits = vec![0f32; m.vocab];
        let mut new_k = vec![0f32; m.n_layers * kvd];
        let mut new_v = vec![0f32; m.n_layers * kvd];
        let mut probs = vec![0f32; m.n_layers * m.n_heads * span];
        rng.fill_normal_f32(&mut logits, 0.0, 1.0);
        rng.fill_normal_f32(&mut new_k, 0.0, 1.0);
        rng.fill_normal_f32(&mut new_v, 0.0, 1.0);
        rng.fill_normal_f32(&mut probs, 0.5, 0.2);
        for p in probs.iter_mut() {
            *p = p.abs();
        }
        Ok(DecodeOut { logits, new_k, new_v, probs })
    }
}
