//! Shared bench harness: table printing + results JSON (criterion is not
//! in the offline vendor set; benches are `harness = false` binaries).

use std::time::Instant;

use crate::util::json::Json;

/// Pretty fixed-width table printer for paper-style rows.
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        println!("\n== {} ==", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:w$}", h, w = widths[i]))
            .collect();
        println!("{}", hdr.join("  "));
        println!("{}", "-".repeat(hdr.join("  ").len()));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect();
            println!("{}", line.join("  "));
        }
    }

    /// Rows as JSON (array of objects keyed by header).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                for (h, c) in self.headers.iter().zip(r) {
                    match c.parse::<f64>() {
                        Ok(n) => o.set(h, Json::Num(n)),
                        Err(_) => o.set(h, Json::Str(c.clone())),
                    };
                }
                o
            })
            .collect();
        let mut out = Json::obj();
        out.set("title", Json::Str(self.title.clone()));
        out.set("rows", Json::Arr(rows));
        out
    }
}

/// Write a results JSON under results/.
pub fn write_results(name: &str, body: Json) {
    let dir = format!("{}/results", env!("CARGO_MANIFEST_DIR"));
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/{name}.json");
    if std::fs::write(&path, body.to_string_pretty()).is_ok() {
        println!("[results -> {path}]");
    }
}

/// Time a closure `iters` times, returning (mean_ms, min_ms).
pub fn time_ms<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        best = best.min(dt);
        total += dt;
    }
    (total / iters as f64, best)
}

/// Standard env-driven bench scale: THINKV_BENCH_SCALE in (0, 1]; applied
/// to trace lengths so CI runs stay fast while full runs match the paper.
pub fn bench_len_scale() -> f64 {
    std::env::var("THINKV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.35)
}

pub fn bench_seeds() -> Vec<u64> {
    let n: usize = std::env::var("THINKV_BENCH_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    (0..n as u64).map(|i| 1000 + i * 77).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_json_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1.5".into(), "x".into()]);
        let j = t.to_json();
        assert_eq!(j.path(&["rows"]).unwrap().idx(0).unwrap().get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(
            j.path(&["rows"]).unwrap().idx(0).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn time_ms_positive() {
        let (mean, best) = time_ms(3, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(mean >= best && best >= 0.0);
    }
}
