//! TBQ — Think Before you Quantize (paper §4.2, Problem Formulation 1).
//!
//! The importance function rho (R=2 > E=1 > T=0) induces a monotone mapping
//! ψ: thought → precision from the available set B = {2, 4, 8} bits.
//! Default assignment is the paper's production choice **R4E4T2**
//! (R tokens hold accuracy at 4 bits, §6.2); the evaluation sweeps the full
//! RxEyTz grid (Figure 11b).

use crate::kvcache::Thought;
use crate::quant::Precision;

/// A full RxEyTz assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrecisionAssignment {
    pub r: Precision,
    pub e: Precision,
    pub t: Precision,
}

impl PrecisionAssignment {
    /// The paper's default R4E4T2.
    pub fn r4e4t2() -> PrecisionAssignment {
        PrecisionAssignment {
            r: Precision::Nvfp4,
            e: Precision::Nvfp4,
            t: Precision::Ternary,
        }
    }

    /// Highest-fidelity assignment R8E4T2 (the rho-ordered mapping).
    pub fn r8e4t2() -> PrecisionAssignment {
        PrecisionAssignment {
            r: Precision::Fp8,
            e: Precision::Nvfp4,
            t: Precision::Ternary,
        }
    }

    /// Parse "R4E4T2"-style names (Figure 11b sweeps).
    pub fn parse(s: &str) -> Option<PrecisionAssignment> {
        let b = s.as_bytes();
        if b.len() != 6 || b[0] != b'R' || b[2] != b'E' || b[4] != b'T' {
            return None;
        }
        let bit = |c: u8| -> Option<Precision> {
            match c {
                b'2' => Some(Precision::Ternary),
                b'4' => Some(Precision::Nvfp4),
                b'8' => Some(Precision::Fp8),
                _ => None,
            }
        };
        Some(PrecisionAssignment { r: bit(b[1])?, e: bit(b[3])?, t: bit(b[5])? })
    }

    pub fn name(&self) -> String {
        format!(
            "R{}E{}T{}",
            self.r.bits() as usize,
            self.e.bits() as usize,
            self.t.bits() as usize
        )
    }

    /// ψ must be monotone in rho: rho(R) > rho(E) > rho(T) implies
    /// bits(R) >= bits(E) >= bits(T) (Problem Formulation 1).
    pub fn is_monotone(&self) -> bool {
        self.r.bits() >= self.e.bits() && self.e.bits() >= self.t.bits()
    }
}

/// The TBQ policy object handed to the cache flush path.
#[derive(Debug, Clone)]
pub struct Tbq {
    pub assignment: PrecisionAssignment,
    /// Uniform override (KIVI-style baselines reuse the machinery).
    pub uniform: Option<Precision>,
}

impl Tbq {
    pub fn new(assignment: PrecisionAssignment) -> Tbq {
        Tbq { assignment, uniform: None }
    }

    pub fn uniform(p: Precision) -> Tbq {
        Tbq {
            assignment: PrecisionAssignment::r4e4t2(),
            uniform: Some(p),
        }
    }

    /// ψ(thought).
    pub fn psi(&self, t: Thought) -> Precision {
        if let Some(u) = self.uniform {
            return u;
        }
        match t {
            Thought::Reasoning => self.assignment.r,
            Thought::Execution => self.assignment.e,
            Thought::Transition => self.assignment.t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_r4e4t2_and_monotone() {
        let a = PrecisionAssignment::r4e4t2();
        assert_eq!(a.name(), "R4E4T2");
        assert!(a.is_monotone());
        assert!(PrecisionAssignment::r8e4t2().is_monotone());
    }

    #[test]
    fn parse_roundtrip() {
        for name in ["R4E4T2", "R8E4T2", "R2E2T2", "R8E8T8", "R4E2T2"] {
            let a = PrecisionAssignment::parse(name).unwrap();
            assert_eq!(a.name(), name);
        }
        assert!(PrecisionAssignment::parse("X4E4T2").is_none());
        assert!(PrecisionAssignment::parse("R5E4T2").is_none());
    }

    #[test]
    fn psi_respects_assignment() {
        let tbq = Tbq::new(PrecisionAssignment::r8e4t2());
        assert_eq!(tbq.psi(Thought::Reasoning), Precision::Fp8);
        assert_eq!(tbq.psi(Thought::Execution), Precision::Nvfp4);
        assert_eq!(tbq.psi(Thought::Transition), Precision::Ternary);
    }

    #[test]
    fn uniform_override() {
        let tbq = Tbq::uniform(Precision::Ternary);
        for t in crate::kvcache::Thought::ALL {
            assert_eq!(tbq.psi(t), Precision::Ternary);
        }
    }

    #[test]
    fn monotonicity_detects_violation() {
        let bad = PrecisionAssignment::parse("R2E4T8").unwrap();
        assert!(!bad.is_monotone());
    }
}
