//! TBE — Think Before You Evict (paper §4.3, Problem Formulation 2).
//!
//! Proactive, segment-granular eviction with the annealing retention
//! schedule R = {64, 32, 16, 8, 4}:
//!
//! * **Case 1** (`on_transition_end`): when a transition segment closes,
//!   every preceding segment (transitions included) anneals to its next
//!   retention level — Observation 3: each T thought makes all prior
//!   thoughts less influential.
//! * **Case 2** (`ensure_budget`): if no transition fires but the live
//!   cache exceeds the budget k, the oldest least-important segment anneals.
//!
//! Which tokens survive an anneal is decided by the k-means policy π over
//! the segment's post-RoPE keys (per layer — layers may retain different
//! tokens, matching the per-layer caches of the paper's pseudocode §D.5).

use crate::kvcache::{CtCache, Thought};

use super::kmeans::kmeans_select;

#[derive(Debug, Clone)]
pub struct TbeConfig {
    /// Retention schedule R (descending), paper default {64,32,16,8,4}.
    pub retention: Vec<usize>,
    /// Cache budget k (live tokens per layer).
    pub budget: usize,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl TbeConfig {
    pub fn new(budget: usize) -> TbeConfig {
        TbeConfig {
            retention: vec![64, 32, 16, 8, 4],
            budget,
            kmeans_iters: 8,
            seed: 0x7b,
        }
    }

    /// Keep-count after the n-th selection (clamps at the schedule tail —
    /// min retention 4 preserves the reasoning trajectory, Fig 11a).
    pub fn keep_at(&self, n: usize) -> usize {
        *self
            .retention
            .get(n.min(self.retention.len() - 1))
            .expect("non-empty schedule")
    }

    /// The paper's "next lowest retention level in R" relative to a
    /// segment's current live size (handles segments shorter than the
    /// first schedule entry, e.g. a 64-token prompt).
    pub fn next_level_below(&self, live: usize) -> usize {
        self.retention
            .iter()
            .copied()
            .find(|&r| r < live)
            .unwrap_or_else(|| *self.retention.last().expect("non-empty schedule"))
    }

    pub fn min_keep(&self) -> usize {
        *self.retention.last().expect("non-empty schedule")
    }
}

/// Counters for the Table-5 style overhead breakdown.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TbeStats {
    pub anneal_calls: u64,
    pub case1_events: u64,
    pub case2_events: u64,
    pub tokens_evicted: u64,
    pub nanos: u64,
    /// Decode steps on which TBE did any work (call-rate metric).
    pub active_steps: u64,
    pub total_steps: u64,
}

impl TbeStats {
    pub fn call_rate(&self) -> f64 {
        if self.total_steps == 0 {
            0.0
        } else {
            self.active_steps as f64 / self.total_steps as f64
        }
    }
}

pub struct Tbe {
    pub cfg: TbeConfig,
    pub stats: TbeStats,
}

impl Tbe {
    pub fn new(cfg: TbeConfig) -> Tbe {
        Tbe { cfg, stats: TbeStats::default() }
    }

    /// Case 1: a transition segment `closing` just ended; anneal every
    /// segment that started before it.
    pub fn on_transition_end(&mut self, cache: &mut CtCache, closing: usize) {
        let t0 = std::time::Instant::now();
        let prior: Vec<usize> = cache
            .segments
            .iter()
            .filter(|s| s.id != closing && s.start_pos < cache.segments[closing].start_pos)
            .map(|s| s.id)
            .collect();
        let mut did = false;
        for seg in prior {
            did |= self.anneal(cache, seg);
        }
        if did {
            self.stats.case1_events += 1;
            self.stats.active_steps += 1;
        }
        self.stats.nanos += t0.elapsed().as_nanos() as u64;
    }

    /// Case 2: shrink until the live token count fits the budget (layer 0
    /// as reference, applied to all layers). Returns tokens evicted.
    pub fn ensure_budget(&mut self, cache: &mut CtCache) -> u64 {
        let t0 = std::time::Instant::now();
        let before = self.stats.tokens_evicted;
        let mut guard = 0;
        while cache.live_tokens() + cache.buf_fill() > self.cfg.budget {
            let Some(victim) = self.pick_case2_victim(cache) else {
                break;
            };
            self.anneal(cache, victim);
            guard += 1;
            if guard > 4 * cache.segments.len() + 8 {
                break;
            }
        }
        let evicted = self.stats.tokens_evicted - before;
        if evicted > 0 {
            self.stats.case2_events += 1;
            self.stats.active_steps += 1;
        }
        self.stats.nanos += t0.elapsed().as_nanos() as u64;
        evicted
    }

    /// Oldest, least-important segment whose next anneal would evict.
    /// Slots inside a read-only shared-prefix region don't count — a
    /// segment that is only "big" because of protected slots cannot
    /// shrink, so picking it would spin without progress.
    fn pick_case2_victim(&self, cache: &CtCache) -> Option<usize> {
        let last = cache.segments.len().saturating_sub(1);
        let shared = cache.shared_len();
        cache
            .segments
            .iter()
            .filter(|s| s.id != last) // never the active segment
            .filter(|s| {
                let slots = cache.tables[0].segment_slots(s.id);
                let protected = slots.iter().filter(|&&sl| sl < shared).count();
                slots.len() > self.cfg.min_keep().max(protected)
            })
            .min_by_key(|s| (s.thought.importance(), s.start_pos))
            .map(|s| s.id)
    }

    /// Anneal one segment to its next retention level across all layers.
    /// The schedule level always advances (the paper's "reduce to the next
    /// lowest retention level"); returns true if any token was evicted.
    pub fn anneal(&mut self, cache: &mut CtCache, seg: usize) -> bool {
        // "reduce to the next lowest retention level in R": size-relative,
        // so segments shorter than R[evict_level] still shrink.
        let live0 = cache.tables[0].segment_slots(seg).len();
        if live0 <= self.cfg.min_keep() {
            return false;
        }
        let keep = self.cfg.next_level_below(live0);
        let shared = cache.shared_len();
        let mut any = false;
        for l in 0..cache.cfg.layers {
            let all = cache.tables[l].segment_slots(seg);
            if all.len() <= keep {
                continue;
            }
            // slots in a read-only shared-prefix region are auto-kept (a
            // denied copy-on-write pins them); k-means selects survivors
            // among the evictable remainder only. With no shared region
            // this is exactly the previous behavior.
            let protected = all.iter().filter(|&&s| s < shared).count();
            let slots: Vec<usize> = all.into_iter().filter(|&s| s >= shared).collect();
            let keep_free = keep.saturating_sub(protected);
            if slots.len() <= keep_free {
                continue;
            }
            let keys: Vec<Vec<f32>> = slots.iter().map(|&s| cache.dequant_key(l, s)).collect();
            let keep_idx = kmeans_select(
                &keys,
                keep_free,
                self.cfg.seed ^ (seg as u64) << 8 ^ l as u64,
                self.cfg.kmeans_iters,
            );
            let keep_set: std::collections::BTreeSet<usize> = keep_idx.into_iter().collect();
            let evict: Vec<usize> = slots
                .iter()
                .enumerate()
                .filter(|(i, _)| !keep_set.contains(i))
                .map(|(_, &s)| s)
                .collect();
            self.stats.tokens_evicted += evict.len() as u64;
            cache.soft_evict_slots(l, &evict);
            any = true;
        }
        cache.segments[seg].evict_level += 1;
        if any {
            self.stats.anneal_calls += 1;
        }
        any
    }

    /// Per-step bookkeeping (call-rate denominator).
    pub fn tick(&mut self) {
        self.stats.total_steps += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::quant::Precision;
    use crate::util::rng::Rng;

    fn mk_cache(capacity: usize) -> CtCache {
        CtCache::new(CacheConfig {
            layers: 2,
            capacity,
            block_size: 8,
            hkv: 1,
            dh: 16,
            buf_slots: 16,
        })
    }

    /// Fill a segment with n tokens of `thought` starting at `pos0`.
    fn fill_segment(
        cache: &mut CtCache,
        rng: &mut Rng,
        thought: Thought,
        pos0: usize,
        n: usize,
    ) -> usize {
        let seg = cache.open_segment(thought, pos0);
        let kvd = cache.cfg.layers * cache.cfg.kv_dim();
        for i in 0..n {
            let mut k = vec![0f32; kvd];
            let mut v = vec![0f32; kvd];
            rng.fill_normal_f32(&mut k, 0.0, 1.0);
            rng.fill_normal_f32(&mut v, 0.0, 1.0);
            let full = cache.push_token(&k, &v, pos0 + i, seg, thought);
            if full {
                cache.flush_buffer(&|_| Precision::Nvfp4).unwrap();
            }
        }
        seg
    }

    #[test]
    fn retention_schedule_clamps() {
        let cfg = TbeConfig::new(1024);
        assert_eq!(cfg.keep_at(0), 64);
        assert_eq!(cfg.keep_at(4), 4);
        assert_eq!(cfg.keep_at(99), 4);
    }

    #[test]
    fn transition_anneals_prior_segments() {
        let mut cache = mk_cache(512);
        let mut rng = Rng::new(1);
        let s0 = fill_segment(&mut cache, &mut rng, Thought::Reasoning, 0, 128);
        let s1 = fill_segment(&mut cache, &mut rng, Thought::Execution, 128, 128);
        let st = fill_segment(&mut cache, &mut rng, Thought::Transition, 256, 128);
        let mut tbe = Tbe::new(TbeConfig::new(1024));
        tbe.on_transition_end(&mut cache, st);
        // prior segments annealed to R_0 = 64
        assert_eq!(cache.tables[0].segment_slots(s0).len(), 64);
        assert_eq!(cache.tables[0].segment_slots(s1).len(), 64);
        // the transition itself is untouched
        assert_eq!(cache.tables[0].segment_slots(st).len(), 128);
        assert_eq!(cache.segments[s0].evict_level, 1);
        cache.check_invariants().unwrap();
        // a second transition anneals further: 64 -> 32 (and st -> 64)
        let st2 = fill_segment(&mut cache, &mut rng, Thought::Transition, 384, 16);
        tbe.on_transition_end(&mut cache, st2);
        assert_eq!(cache.tables[0].segment_slots(s0).len(), 32);
        assert_eq!(cache.tables[0].segment_slots(st).len(), 64);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn min_retention_floor_holds() {
        let mut cache = mk_cache(512);
        let mut rng = Rng::new(2);
        let s0 = fill_segment(&mut cache, &mut rng, Thought::Reasoning, 0, 128);
        let mut tbe = Tbe::new(TbeConfig::new(1024));
        for t in 0..8 {
            let st = fill_segment(&mut cache, &mut rng, Thought::Transition, 128 + t * 16, 16);
            tbe.on_transition_end(&mut cache, st);
        }
        // after many transitions s0 bottoms out at min retention 4
        assert_eq!(cache.tables[0].segment_slots(s0).len(), 4);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn case2_budget_enforced_on_least_important_oldest() {
        let mut cache = mk_cache(512);
        let mut rng = Rng::new(3);
        let s_r = fill_segment(&mut cache, &mut rng, Thought::Reasoning, 0, 128);
        let s_e = fill_segment(&mut cache, &mut rng, Thought::Execution, 128, 128);
        let _active = fill_segment(&mut cache, &mut rng, Thought::Reasoning, 256, 32);
        let mut tbe = Tbe::new(TbeConfig::new(200));
        let evicted = tbe.ensure_budget(&mut cache);
        assert!(evicted > 0);
        assert!(cache.live_tokens() <= 200);
        // execution (importance 1) shrank before reasoning (importance 2)
        assert!(cache.segments[s_e].evict_level >= 1);
        assert_eq!(
            cache.tables[0].segment_slots(s_r).len()
                + cache.tables[0].segment_slots(s_e).len()
                + 32,
            cache.live_tokens()
        );
        cache.check_invariants().unwrap();
    }

    #[test]
    fn case2_never_touches_active_segment() {
        let mut cache = mk_cache(256);
        let mut rng = Rng::new(4);
        let _s0 = fill_segment(&mut cache, &mut rng, Thought::Execution, 0, 128);
        let active = fill_segment(&mut cache, &mut rng, Thought::Transition, 128, 64);
        let mut tbe = Tbe::new(TbeConfig::new(100));
        tbe.ensure_budget(&mut cache);
        assert_eq!(cache.tables[0].segment_slots(active).len(), 64);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn eviction_curve_is_sawtooth() {
        // Fig 10(b): live size grows within a segment, drops at transitions
        let mut cache = mk_cache(2048);
        let mut rng = Rng::new(5);
        let mut tbe = Tbe::new(TbeConfig::new(4096));
        let mut live_trace = Vec::new();
        for seg_i in 0..6 {
            let th = if seg_i % 3 == 2 { Thought::Transition } else { Thought::Reasoning };
            let seg = fill_segment(&mut cache, &mut rng, th, seg_i * 128, 128);
            live_trace.push(cache.live_tokens());
            if th == Thought::Transition {
                tbe.on_transition_end(&mut cache, seg);
                live_trace.push(cache.live_tokens());
            }
        }
        // at least one drop following a transition
        assert!(live_trace.windows(2).any(|w| w[1] < w[0]), "{live_trace:?}");
        assert!(tbe.stats.anneal_calls > 0);
    }

    #[test]
    fn stats_call_rate() {
        let mut tbe = Tbe::new(TbeConfig::new(10));
        for _ in 0..100 {
            tbe.tick();
        }
        tbe.stats.active_steps = 5;
        assert!((tbe.stats.call_rate() - 0.05).abs() < 1e-9);
    }
}
