//! K-means selection for the eviction policy π (paper §4.3 + §D.4,
//! GPU-accelerated per Kruliš & Kratochvíl in the original; Lloyd with
//! k-means++ seeding here).
//!
//! Clusters a segment's post-RoPE key embeddings into K groups and keeps
//! the slot nearest each centroid — the representative key-value pairs that
//! stay in the cache. (The paper keeps centroid keys; the nearest-member
//! representative preserves exact K/V pairing and is the standard
//! medoid-style realization — documented deviation, DESIGN §1.)

use crate::util::rng::Rng;

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Pick `k` representative indices out of `points` (row-major, `dim` wide).
/// Deterministic for a given seed. Returns ascending indices.
pub fn kmeans_select(points: &[Vec<f32>], k: usize, seed: u64, iters: usize) -> Vec<usize> {
    let n = points.len();
    if k == 0 || n == 0 {
        return Vec::new();
    }
    if k >= n {
        return (0..n).collect();
    }
    let mut rng = Rng::new(seed);

    // k-means++ seeding
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    centroids.push(points[rng.below(n)].clone());
    let mut d2: Vec<f64> = points.iter().map(|p| f64::from(dist2(p, &centroids[0]))).collect();
    while centroids.len() < k {
        let idx = rng.weighted(&d2);
        centroids.push(points[idx].clone());
        for (i, p) in points.iter().enumerate() {
            let d = dist2(p, centroids.last().unwrap()) as f64;
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    // Lloyd iterations
    let dim = points[0].len();
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0;
            let mut bd = f32::INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let d = dist2(p, cent);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        let mut sums = vec![vec![0f64; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            counts[assign[i]] += 1;
            for (s, &x) in sums[assign[i]].iter_mut().zip(p) {
                *s += f64::from(x);
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for (j, s) in sums[c].iter().enumerate() {
                    centroids[c][j] = (*s / counts[c] as f64) as f32;
                }
            }
        }
        if !changed {
            break;
        }
    }

    // representative = nearest member of each non-empty cluster
    let mut reps: Vec<usize> = Vec::with_capacity(k);
    for c in 0..k {
        let mut best: Option<(usize, f32)> = None;
        for (i, p) in points.iter().enumerate() {
            if assign[i] != c {
                continue;
            }
            let d = dist2(p, &centroids[c]);
            if best.map(|(_, bd)| d < bd).unwrap_or(true) {
                best = Some((i, d));
            }
        }
        if let Some((i, _)) = best {
            reps.push(i);
        }
    }
    // empty clusters can leave reps short: top up with farthest-from-kept
    while reps.len() < k {
        let mut far: Option<(usize, f32)> = None;
        for (i, p) in points.iter().enumerate() {
            if reps.contains(&i) {
                continue;
            }
            let dmin = reps
                .iter()
                .map(|&r| dist2(p, &points[r]))
                .fold(f32::INFINITY, f32::min);
            if far.map(|(_, fd)| dmin > fd).unwrap_or(true) {
                far = Some((i, dmin));
            }
        }
        match far {
            Some((i, _)) => reps.push(i),
            None => break,
        }
    }
    reps.sort_unstable();
    reps.dedup();
    reps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn blobs(n_per: usize, centers: &[[f32; 2]], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for c in centers {
            for _ in 0..n_per {
                out.push(vec![
                    c[0] + rng.normal_with(0.0, 0.05) as f32,
                    c[1] + rng.normal_with(0.0, 0.05) as f32,
                ]);
            }
        }
        out
    }

    #[test]
    fn selects_one_per_blob() {
        let centers = [[0.0, 0.0], [5.0, 5.0], [-5.0, 5.0]];
        let pts = blobs(20, &centers, 1);
        let reps = kmeans_select(&pts, 3, 42, 12);
        assert_eq!(reps.len(), 3);
        // each rep comes from a distinct blob
        let blobs_hit: std::collections::BTreeSet<usize> =
            reps.iter().map(|&i| i / 20).collect();
        assert_eq!(blobs_hit.len(), 3);
    }

    #[test]
    fn k_ge_n_keeps_all() {
        let pts = blobs(3, &[[0.0, 0.0]], 2);
        assert_eq!(kmeans_select(&pts, 10, 0, 5), vec![0, 1, 2]);
        assert_eq!(kmeans_select(&pts, 3, 0, 5), vec![0, 1, 2]);
    }

    #[test]
    fn k_zero_or_empty() {
        let pts = blobs(3, &[[0.0, 0.0]], 3);
        assert!(kmeans_select(&pts, 0, 0, 5).is_empty());
        assert!(kmeans_select(&[], 3, 0, 5).is_empty());
    }

    #[test]
    fn deterministic() {
        let pts = blobs(15, &[[0.0, 0.0], [3.0, 1.0]], 4);
        assert_eq!(kmeans_select(&pts, 4, 9, 10), kmeans_select(&pts, 4, 9, 10));
    }

    #[test]
    fn property_returns_k_unique_valid_indices() {
        prop::check(60, |g| {
            let n = g.usize(1, 60);
            let k = g.usize(1, 20);
            let dim = g.usize(1, 8);
            let pts: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_normal_f32(dim, 0.0, 2.0)).collect();
            let reps = kmeans_select(&pts, k, 7, 8);
            let want = k.min(n);
            if reps.len() != want {
                return Err(format!("got {} reps, want {want}", reps.len()));
            }
            let mut s = reps.clone();
            s.dedup();
            if s.len() != reps.len() {
                return Err("duplicate reps".into());
            }
            if reps.iter().any(|&i| i >= n) {
                return Err("rep out of range".into());
            }
            Ok(())
        });
    }
}
