//! ThinKV's hybrid compression: **TBQ** (Think Before you Quantize, §4.2)
//! and **TBE** (Think Before You Evict, §4.3), plus the k-means eviction
//! policy π (§D.4).

pub mod kmeans;
pub mod tbe;
pub mod tbq;

pub use kmeans::kmeans_select;
pub use tbe::{Tbe, TbeConfig, TbeStats};
pub use tbq::{PrecisionAssignment, Tbq};
