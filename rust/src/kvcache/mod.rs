//! The ThinKV paged KV cache with **Continuous Thinking** (paper §5.2).
//!
//! PagedAttention-style block tables extended with four new fields
//! (thought type, start indices, segment masks, eviction mask) so that
//! slots freed by TBE are soft-marked and **reused in place** by later
//! tokens of the same thought type — no gather-based compaction, ever.
//! Slot order never matters because attention is permutation invariant
//! (paper Theorem 1 / §C.3).
//!
//! Module map:
//! * [`block_table`] — the CT block table + slot bookkeeping per layer.
//! * [`ct`] — [`ct::CtCache`], the engine-facing quantized cache a request
//!   owns (codes/scales/tags/mask slabs + fp ring buffer + segments).
//! * [`fp32`] — the f32 paged cache used by FullKV and eviction baselines.
//! * [`backend`] — [`backend::KvBackend`], the unified trait both cache
//!   families implement (alloc/append/evict/decode-view/bytes-used/
//!   live-tokens); the serving session drives it generically. Its
//!   [`backend::BatchKey`] is the cross-session batched-decode
//!   compatibility key (same cache family + compiled capacity = same
//!   fused engine call).
//! * [`pool`] — [`pool::BlockPool`], the global physical-byte pool the
//!   memory-aware scheduler reserves against for admission control and
//!   preemption (max batch-size experiments, Tables 2/3), plus the
//!   typed byte ledger ([`pool::Lease`]/[`pool::ByteLease`]): every
//!   long-lived charge is a `#[must_use]` lease that debug-panics when
//!   dropped unsettled, and [`pool::BlockPool::audit`] checks
//!   `used == Σ live leases` at quiescent points.
//! * [`swap`] — suspend-to-host preemption: [`swap::KvSnapshot`] images
//!   produced by [`backend::KvBackend::snapshot`] and the byte-accounted
//!   host-side [`swap::SwapPool`] they live in while a preempted session
//!   waits for re-admission.
//! * [`prefix`] — cross-session prefix sharing: the scheduler-owned
//!   [`prefix::PrefixIndex`] (hash-trie over prompt token prefixes at
//!   block granularity) maps a prompt onto resident, refcounted,
//!   read-only prefill payloads; sessions attach instead of
//!   re-quantizing, pay only their delta, and privatize via
//!   copy-on-write on the first divergent write.

pub mod backend;
pub mod block_table;
pub mod ct;
pub mod fp32;
pub mod pool;
pub mod prefix;
pub mod swap;

pub use backend::{BatchKey, Fp32Backend, KvBackend, QuantBackend};
pub use block_table::{BlockEntry, LayerTable, SlotId};
pub use ct::{CacheConfig, CtCache, CtSnapshot, SegmentInfo};
pub use fp32::{Fp32Cache, Fp32CacheSnapshot};
pub use pool::{BlockPool, ByteLease, Lease, LeaseLedger, PoolAudit, PoolLike};
pub use prefix::{AttachedPrefix, PrefixGeom, PrefixIndex, PrefixPayload, PrefixStats, SharedPrefix};
pub use swap::{KvSnapshot, SnapshotPayload, SwapLease, SwapPool, SwapStats};

/// The three thought types (paper Observation 1b: T sparsest, then R, then E).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Thought {
    /// Transition: uncertainty / backtracking ("Wait", "Hmm", ...).
    Transition = 0,
    /// Execution: calculations, code emission.
    Execution = 1,
    /// Reasoning: systematic thinking.
    Reasoning = 2,
}

impl Thought {
    pub const ALL: [Thought; 3] = [Thought::Transition, Thought::Execution, Thought::Reasoning];

    /// Fallible tag decode — use this on any tag that crossed a
    /// serialization boundary (wire requests, trace files).
    pub fn try_from_u8(v: u8) -> Option<Thought> {
        match v {
            0 => Some(Thought::Transition),
            1 => Some(Thought::Execution),
            2 => Some(Thought::Reasoning),
            _ => None,
        }
    }

    /// Panicking wrapper for hot paths where the tag is internally
    /// produced and `0..=2` by construction.
    pub fn from_u8(v: u8) -> Thought {
        Thought::try_from_u8(v).unwrap_or_else(|| panic!("bad thought tag {v}"))
    }

    /// Importance score rho (paper §4.2: rho(R)=2, rho(E)=1, rho(T)=0).
    pub fn importance(self) -> u8 {
        match self {
            Thought::Reasoning => 2,
            Thought::Execution => 1,
            Thought::Transition => 0,
        }
    }

    pub fn letter(self) -> char {
        match self {
            Thought::Reasoning => 'R',
            Thought::Execution => 'E',
            Thought::Transition => 'T',
        }
    }
}

impl TryFrom<u8> for Thought {
    type Error = u8;

    fn try_from(v: u8) -> Result<Thought, u8> {
        Thought::try_from_u8(v).ok_or(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thought_tag_roundtrip() {
        for t in Thought::ALL {
            assert_eq!(Thought::try_from_u8(t as u8), Some(t));
            assert_eq!(Thought::from_u8(t as u8), t);
            assert_eq!(Thought::try_from(t as u8), Ok(t));
        }
        for bad in [3u8, 7, 255] {
            assert_eq!(Thought::try_from_u8(bad), None);
            assert_eq!(Thought::try_from(bad), Err(bad));
        }
    }

    #[test]
    #[should_panic(expected = "bad thought tag")]
    fn from_u8_panics_on_bad_tag() {
        let _ = Thought::from_u8(9);
    }
}
