//! The ThinKV paged KV cache with **Continuous Thinking** (paper §5.2).
//!
//! PagedAttention-style block tables extended with four new fields
//! (thought type, start indices, segment masks, eviction mask) so that
//! slots freed by TBE are soft-marked and **reused in place** by later
//! tokens of the same thought type — no gather-based compaction, ever.
//! Slot order never matters because attention is permutation invariant
//! (paper Theorem 1 / §C.3).
//!
//! Module map:
//! * [`block_table`] — the CT block table + slot bookkeeping per layer.
//! * [`ct`] — [`ct::CtCache`], the engine-facing quantized cache a request
//!   owns (codes/scales/tags/mask slabs + fp ring buffer + segments).
//! * [`fp32`] — the f32 paged cache used by FullKV and eviction baselines.
//! * [`pool`] — the global physical-block pool (memory accounting, max
//!   batch-size experiments).

pub mod block_table;
pub mod ct;
pub mod fp32;
pub mod pool;

pub use block_table::{BlockEntry, LayerTable, SlotId};
pub use ct::{CacheConfig, CtCache, SegmentInfo};
pub use fp32::Fp32Cache;
pub use pool::BlockPool;

/// The three thought types (paper Observation 1b: T sparsest, then R, then E).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Thought {
    /// Transition: uncertainty / backtracking ("Wait", "Hmm", ...).
    Transition = 0,
    /// Execution: calculations, code emission.
    Execution = 1,
    /// Reasoning: systematic thinking.
    Reasoning = 2,
}

impl Thought {
    pub const ALL: [Thought; 3] = [Thought::Transition, Thought::Execution, Thought::Reasoning];

    pub fn from_u8(v: u8) -> Thought {
        match v {
            0 => Thought::Transition,
            1 => Thought::Execution,
            2 => Thought::Reasoning,
            _ => panic!("bad thought {v}"),
        }
    }

    /// Importance score rho (paper §4.2: rho(R)=2, rho(E)=1, rho(T)=0).
    pub fn importance(self) -> u8 {
        match self {
            Thought::Reasoning => 2,
            Thought::Execution => 1,
            Thought::Transition => 0,
        }
    }

    pub fn letter(self) -> char {
        match self {
            Thought::Reasoning => 'R',
            Thought::Execution => 'E',
            Thought::Transition => 'T',
        }
    }
}
