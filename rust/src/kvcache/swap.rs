//! Suspend-to-host KV swapping (the preemption fast path).
//!
//! PR 1's scheduler reclaims pool bytes by preempting the youngest
//! session and *recomputing* it on re-admission. For reasoning workloads
//! the "prompt" to recompute is the whole generated CoT, so every
//! preemption replays thousands of decode steps. ThinKV's compressed
//! cache (§6: <5% of the FullKV footprint) is small enough to serialize
//! to host memory almost for free, turning preemption from
//! O(trajectory replay) into O(bytes copied). This module provides:
//!
//! * [`KvSnapshot`] — a self-contained host-side image of one request's
//!   cache + policy state, produced by [`KvBackend::snapshot`] and
//!   consumed by [`KvBackend::restore`]. For the quantized backend this
//!   is the compacted live slabs plus the CT metadata (thought tags,
//!   segment masks, eviction masks), classifier/segment state, and the
//!   B_buf full-precision residue; for the f32 backend it is the live
//!   rows plus the eviction-policy statistics. The fp32 image is 10-20x
//!   larger — exactly why R-KV-style baselines cannot swap cheaply.
//! * [`SwapPool`] — the byte-accounted host memory pool snapshots are
//!   charged against, with swap-in/out counters and restore latency the
//!   scheduler surfaces through
//!   [`SchedSnapshot`](crate::metrics::SchedSnapshot).
//!
//! The scheduler's policy is *swap when it fits, recompute otherwise*:
//! [`Session::suspend_to`](crate::coordinator::Session::suspend_to)
//! falls back to the PR 1 recompute path whenever the snapshot does not
//! fit the pool (counted in [`SwapStats::fallbacks`]).
//!
//! [`KvBackend::snapshot`]: super::KvBackend::snapshot
//! [`KvBackend::restore`]: super::KvBackend::restore

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::baselines::eviction::EvictionPolicy;
use crate::compress::tbe::TbeStats;
use crate::thought::classifier::ClassifierState;

use super::ct::CtSnapshot;
use super::fp32::Fp32CacheSnapshot;
use super::pool::{Lease, LeaseLedger, PoolAudit, PoolLike};
use super::Thought;

/// A ledgered lease of host snapshot bytes against a [`SwapPool`].
pub type SwapLease = Lease<SwapPool>;

/// Host-side image of a [`QuantBackend`](super::QuantBackend): the
/// compacted CT cache plus every piece of decode-loop policy state that
/// must survive a suspend/resume cycle bit-exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSnapshot {
    /// Compacted cache image (live codes/scales/tags, CT block tables
    /// with segment + eviction masks, B_buf residue, counters).
    pub ct: CtSnapshot,
    /// Streaming thought-classifier window (accumulator, window length,
    /// window means).
    pub classifier: ClassifierState,
    /// Thought label of the currently open segment.
    pub cur_thought: Thought,
    /// Id of the currently open segment.
    pub cur_segment: usize,
    /// TBE counters (call-rate telemetry), when TBE is enabled.
    pub tbe_stats: Option<TbeStats>,
}

/// Host-side image of an [`Fp32Backend`](super::Fp32Backend): live f32
/// rows plus the eviction policy's accumulated statistics.
pub struct Fp32Snapshot {
    /// Compacted f32 cache image (live rows, buffer residue, counters).
    pub cache: Fp32CacheSnapshot,
    /// The eviction policy, cloned with all accumulated state (H2O
    /// cumulative scores, R-KV decay tables, ...).
    pub policy: Box<dyn EvictionPolicy>,
}

/// The backend-specific payload of a [`KvSnapshot`].
pub enum SnapshotPayload {
    /// Quantized CT cache (ThinKV / KIVI / PM-KVQ sessions).
    Quant(Box<QuantSnapshot>),
    /// F32 cache (FullKV / eviction-baseline sessions).
    Fp32(Box<Fp32Snapshot>),
}

/// A suspended request's complete cache state, living in host memory
/// while the request waits for re-admission.
#[must_use = "dropping a KvSnapshot discards a session's only restorable cache image"]
pub struct KvSnapshot {
    /// Host bytes this snapshot occupies — what [`SwapPool::reserve`]
    /// charges on swap-out and [`SwapPool::release`] returns on swap-in.
    pub bytes: u64,
    /// Device-side live footprint at suspend time (packed accounting) —
    /// what the scheduler must re-reserve in the
    /// [`BlockPool`](super::BlockPool) before the session resumes.
    pub device_bytes: u64,
    /// Backend-specific cache + policy image.
    pub payload: SnapshotPayload,
}

impl KvSnapshot {
    /// Which backend family produced this snapshot.
    pub fn kind(&self) -> &'static str {
        match self.payload {
            SnapshotPayload::Quant(_) => "quant",
            SnapshotPayload::Fp32(_) => "fp32",
        }
    }
}

/// Point-in-time counters of a [`SwapPool`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SwapStats {
    pub capacity: u64,
    pub used: u64,
    pub peak: u64,
    /// Sessions suspended to host (snapshot stored).
    pub swap_outs: u64,
    /// Sessions resumed from host (snapshot restored and freed).
    pub swap_ins: u64,
    /// Total bytes copied host-ward by swap-outs.
    pub bytes_out: u64,
    /// Total bytes copied device-ward by swap-ins.
    pub bytes_in: u64,
    /// Cumulative wall time spent restoring snapshots (swap-in cost).
    pub restore_ns: u64,
    /// Preemptions that fell back to recompute because the snapshot did
    /// not fit the pool (or could not be taken).
    pub fallbacks: u64,
}

/// Byte-accounted host-memory pool for suspended KV snapshots.
///
/// The byte accounting *is* a [`BlockPool`](super::BlockPool) (bytes,
/// not slots — snapshots of mixed-precision caches differ in size);
/// `SwapPool` composes one and adds the swap-traffic counters the
/// serving stats report: swap-in/out counts, bytes moved each way,
/// restore latency, and recompute fallbacks.
#[derive(Debug)]
pub struct SwapPool {
    bytes: super::BlockPool,
    swap_outs: AtomicU64,
    swap_ins: AtomicU64,
    bytes_out: AtomicU64,
    bytes_in: AtomicU64,
    restore_ns: AtomicU64,
    fallbacks: AtomicU64,
}

impl SwapPool {
    pub fn new(capacity_bytes: u64) -> SwapPool {
        SwapPool {
            bytes: super::BlockPool::new(capacity_bytes),
            swap_outs: AtomicU64::new(0),
            swap_ins: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            restore_ns: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.bytes.capacity()
    }

    pub fn used(&self) -> u64 {
        self.bytes.used()
    }

    pub fn peak(&self) -> u64 {
        self.bytes.peak()
    }

    pub fn free(&self) -> u64 {
        self.bytes.free()
    }

    /// Try to reserve `bytes` of host memory; false if the pool would
    /// overflow (the caller must fall back to recompute preemption).
    ///
    /// Unledgered escape hatch — long-lived charges should be a
    /// [`SwapLease`] via [`SwapPool::lease`] instead.
    #[must_use = "a failed reserve means the bytes were NOT taken"]
    pub fn reserve(&self, bytes: u64) -> bool {
        self.bytes.reserve(bytes)
    }

    pub fn release(&self, bytes: u64) {
        self.bytes.release(bytes)
    }

    /// Charge `bytes` as a ledgered [`SwapLease`]; `None` if full (the
    /// caller must fall back to recompute preemption).
    pub fn lease(self: &Arc<Self>, bytes: u64) -> Option<SwapLease> {
        Lease::charge(self, bytes)
    }

    /// Conservation snapshot; see [`super::BlockPool::audit`].
    pub fn audit(&self) -> PoolAudit {
        self.bytes.audit()
    }

    /// Assert `used == Σ live-lease bytes` at a quiescent point.
    #[track_caller]
    pub fn assert_conserved(&self) {
        let a = self.audit();
        assert!(
            a.conserved(),
            "swap-pool byte-conservation violated: used={} but leases hold {} across {} leases",
            a.used,
            a.leased,
            a.live
        );
    }

    /// Record a completed swap-out of `bytes` (already reserved).
    pub fn note_swap_out(&self, bytes: u64) {
        self.swap_outs.fetch_add(1, Ordering::SeqCst);
        self.bytes_out.fetch_add(bytes, Ordering::SeqCst);
    }

    /// Record a completed swap-in of `bytes` that took `ns` to restore.
    pub fn note_swap_in(&self, bytes: u64, ns: u64) {
        self.swap_ins.fetch_add(1, Ordering::SeqCst);
        self.bytes_in.fetch_add(bytes, Ordering::SeqCst);
        self.restore_ns.fetch_add(ns, Ordering::SeqCst);
    }

    /// Record a preemption that had to fall back to recompute.
    pub fn note_fallback(&self) {
        self.fallbacks.fetch_add(1, Ordering::SeqCst);
    }

    pub fn stats(&self) -> SwapStats {
        SwapStats {
            capacity: self.capacity(),
            used: self.used(),
            peak: self.peak(),
            swap_outs: self.swap_outs.load(Ordering::SeqCst),
            swap_ins: self.swap_ins.load(Ordering::SeqCst),
            bytes_out: self.bytes_out.load(Ordering::SeqCst),
            bytes_in: self.bytes_in.load(Ordering::SeqCst),
            restore_ns: self.restore_ns.load(Ordering::SeqCst),
            fallbacks: self.fallbacks.load(Ordering::SeqCst),
        }
    }
}

impl PoolLike for SwapPool {
    fn try_reserve_raw(&self, bytes: u64) -> bool {
        self.bytes.reserve(bytes)
    }

    fn release_raw(&self, bytes: u64) {
        self.bytes.release(bytes);
    }

    fn ledger(&self) -> &LeaseLedger {
        self.bytes.ledger()
    }

    fn pool_name(&self) -> &'static str {
        "swap"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_and_counters() {
        let p = SwapPool::new(1000);
        assert!(p.reserve(600));
        p.note_swap_out(600);
        assert!(!p.reserve(600), "over-capacity reserve must fail");
        p.note_fallback();
        p.release(600);
        p.note_swap_in(600, 1234);
        assert_eq!(p.used(), 0);
        let s = p.stats();
        assert_eq!(s.peak, 600);
        assert_eq!(s.swap_outs, 1);
        assert_eq!(s.swap_ins, 1);
        assert_eq!(s.bytes_out, 600);
        assert_eq!(s.bytes_in, 600);
        assert_eq!(s.restore_ns, 1234);
        assert_eq!(s.fallbacks, 1);
    }

    #[test]
    fn concurrent_reservations_never_overflow() {
        let p = std::sync::Arc::new(SwapPool::new(5_000));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = std::sync::Arc::clone(&p);
            handles.push(std::thread::spawn(move || {
                let mut got = 0u64;
                for _ in 0..1000 {
                    if p.reserve(3) {
                        got += 3;
                    }
                }
                got
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total <= 5_000);
        assert_eq!(p.used(), total);
    }
}
